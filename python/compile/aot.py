"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Run once by `make artifacts`:

    cd python && python -m compile.aot --outdir ../artifacts

Python never runs on the request path — the Rust coordinator loads these
artifacts via PJRT (rust/src/runtime/) and is self-contained afterwards.

Interchange format is HLO TEXT, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects (`proto.id() <=
INT_MAX`). The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Besides the HLO modules this script exports:
  - seq2seq_params.bin  — GRU weights trained here on synthetic phase traces
  - dnn_init.bin        — initial application-DNN parameters
  - interval_init.bin   — initial interval-MLP parameters
  - manifest.json       — shapes/dtypes/offsets for everything above
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import block_checksum, xor_parity
from .kernels.checksum import BLOCK as CSUM_BLOCK
from .kernels.xor_parity import BLOCK_N as XOR_BLOCK_N

# Fixed AOT shapes for the data-plane kernels (Rust pads to these).
XOR_SHARDS = 4          # shards per erasure-encode call (groups fold)
XOR_CHUNK = 65536       # int32 lanes per shard per call (256 KiB)
CSUM_ROWS = 64          # checksum rows per call (64 x 16 KiB = 1 MiB)

SEQ_TRAIN_BATCH = 32
SEQ_TRAIN_STEPS = 1500


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower(fn, *specs):
    return to_hlo_text(jax.jit(fn).lower(*specs))


def write_params_bin(path, named_tensors):
    """Raw little-endian f32 blob + manifest entries (name, shape, offset)."""
    entries = []
    offset = 0
    with open(path, "wb") as f:
        for name, t in named_tensors:
            arr = np.asarray(t, dtype=np.float32)
            f.write(arr.tobytes(order="C"))
            entries.append({
                "name": name,
                "shape": list(arr.shape),
                "offset": offset,
                "len": int(arr.size),
            })
            offset += arr.size * 4
    return entries


def synth_trace(key, n):
    """Synthetic phase-structured utilization trace: iterative HPC apps
    alternate compute (high utilization) and comm/IO (low) phases — the
    repetitive behaviour paper ref [6] exploits. A fraction of traces are
    steady-state (all busy / all idle) so the model also handles the
    regimes the predictive scheduler gate probes."""
    ks = jax.random.split(key, 5)
    kind = jax.random.uniform(ks[4], ())
    period = 8 + jax.random.randint(ks[0], (), 0, 9)          # 8..16 steps
    duty = 0.4 + 0.4 * jax.random.uniform(ks[1], ())
    phase = jax.random.randint(ks[2], (), 0, 16)
    t = jnp.arange(n)
    base = ((t + phase) % period) < (duty * period).astype(jnp.int32)
    util = 0.15 + 0.7 * base.astype(jnp.float32)
    # 15% constant-busy, 15% constant-idle, 70% phase-structured.
    util = jnp.where(kind < 0.15, 0.9, jnp.where(kind < 0.3, 0.1, util))
    noise = 0.05 * jax.random.normal(ks[3], (n,))
    return jnp.clip(util + noise, 0.0, 1.0)


def train_seq2seq(seed=0):
    """Build-time training of the utilization predictor on synthetic traces."""
    key = jax.random.PRNGKey(seed)
    params = model.seq2seq_init(key)
    step = jax.jit(model.seq2seq_train)
    total = model.SEQ_WINDOW + model.SEQ_HORIZON
    lr = jnp.float32(0.05)
    loss0 = lossn = None
    for i in range(SEQ_TRAIN_STEPS):
        key, k = jax.random.split(key)
        traces = jnp.stack([
            synth_trace(kk, total)
            for kk in jax.random.split(k, SEQ_TRAIN_BATCH)
        ])
        window = traces[:, : model.SEQ_WINDOW]
        target = traces[:, model.SEQ_WINDOW:]
        out = step(*params, window, target, lr)
        params, loss = out[:-1], out[-1]
        if i == 0:
            loss0 = float(loss)
        lossn = float(loss)
    print(f"seq2seq build-time training: mse {loss0:.4f} -> {lossn:.4f}")
    assert lossn < loss0, "seq2seq training diverged"
    return params, loss0, lossn


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = {"modules": {}, "params": {}, "constants": {
        "xor_shards": XOR_SHARDS,
        "xor_chunk": XOR_CHUNK,
        "xor_block_n": XOR_BLOCK_N,
        "csum_rows": CSUM_ROWS,
        "csum_block": CSUM_BLOCK,
        "interval_features": model.INTERVAL_FEATURES,
        "interval_hidden": model.INTERVAL_HIDDEN,
        "interval_batch": model.INTERVAL_BATCH,
        "seq_window": model.SEQ_WINDOW,
        "seq_horizon": model.SEQ_HORIZON,
        "seq_hidden": model.SEQ_HIDDEN,
        "dnn_batch": model.DNN_BATCH,
        "dnn_in": model.DNN_IN,
        "dnn_h1": model.DNN_H1,
        "dnn_h2": model.DNN_H2,
        "dnn_classes": model.DNN_CLASSES,
    }}

    def emit(name, fn, specs, outputs):
        text = lower(fn, *specs)
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["modules"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "outputs": outputs,
        }
        print(f"wrote {path} ({len(text)} chars)")

    # --- L1 data-plane kernels -------------------------------------------
    emit("xor_parity", xor_parity, [i32(XOR_SHARDS, XOR_CHUNK)], 1)
    emit("checksum", block_checksum, [i32(CSUM_ROWS, CSUM_BLOCK)], 1)

    # --- interval MLP (ref [1]) ------------------------------------------
    F, H, B = model.INTERVAL_FEATURES, model.INTERVAL_HIDDEN, model.INTERVAL_BATCH
    ip = [f32(F, H), f32(H), f32(H, H), f32(H), f32(H, 1), f32(1)]
    emit("interval_mlp_fwd", model.interval_mlp_fwd, ip + [f32(B, F)], 1)
    emit("interval_mlp_train", model.interval_mlp_train,
         ip + [f32(B, F), f32(B), f32()], 7)

    # --- seq2seq predictor (ref [6]) --------------------------------------
    SH = model.SEQ_HIDDEN
    sp = [f32(1, 3 * SH), f32(SH, 3 * SH), f32(3 * SH), f32(SH, 1), f32(1)]
    emit("seq2seq_fwd", model.seq2seq_fwd, sp + [f32(1, model.SEQ_WINDOW)], 1)

    # --- application DNN (DeepFreeze workload, ref [3]) --------------------
    D, H1, H2, C, DB = (model.DNN_IN, model.DNN_H1, model.DNN_H2,
                        model.DNN_CLASSES, model.DNN_BATCH)
    dp = [f32(D, H1), f32(H1), f32(H1, H2), f32(H2), f32(H2, C), f32(C)]
    emit("dnn_train_step", model.dnn_train_step,
         dp + [f32(DB, D), i32(DB), f32()], 7)
    emit("dnn_loss", model.dnn_loss, dp + [f32(DB, D), i32(DB)], 2)

    # --- parameter blobs ---------------------------------------------------
    key = jax.random.PRNGKey(args.seed)
    k1, k2 = jax.random.split(key)

    seq_params, l0, ln = train_seq2seq(args.seed)
    names = ["w", "u", "b", "wo", "bo"]
    manifest["params"]["seq2seq"] = {
        "file": "seq2seq_params.bin",
        "tensors": write_params_bin(
            os.path.join(args.outdir, "seq2seq_params.bin"),
            list(zip(names, seq_params))),
        "train_mse_start": l0, "train_mse_end": ln,
    }

    dnn_params = model.dnn_init(k1)
    names = ["w1", "b1", "w2", "b2", "w3", "b3"]
    manifest["params"]["dnn_init"] = {
        "file": "dnn_init.bin",
        "tensors": write_params_bin(
            os.path.join(args.outdir, "dnn_init.bin"),
            list(zip(names, dnn_params))),
    }

    mlp_params = model.interval_mlp_init(k2)
    manifest["params"]["interval_init"] = {
        "file": "interval_init.bin",
        "tensors": write_params_bin(
            os.path.join(args.outdir, "interval_init.bin"),
            list(zip(names, mlp_params))),
    }

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.outdir, 'manifest.json')}")


if __name__ == "__main__":
    main()
