"""L1 Pallas kernel: blocked position-weighted checksum for integrity checks.

VeloC's integrity module checksums checkpoint chunks so that recovery can
validate a version before declaring it usable. A serial Fletcher/Adler scan
does not vectorize; instead we use a position-weighted wrapping sum per block:

    csum[i] = sum_j x[i, j] * W[j]        (int32, two's-complement wraparound)

with W[j] = 2*j + 1 (odd weights => each weight is a unit mod 2^32, so any
single-element corruption changes the checksum; position-dependence catches
swapped words, which a plain sum would miss).

One grid step per block row; the weight vector is computed in-register with
a broadcasted iota, so only the data block streams HBM->VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096  # int32 lanes per checksum block (16 KiB)


def _checksum_kernel(x_ref, o_ref):
    """x_ref: (1, BLOCK) int32; o_ref: (1,) int32."""
    blk = x_ref[...]
    w = (2 * jax.lax.broadcasted_iota(jnp.int32, blk.shape, 1) + 1)
    o_ref[...] = jnp.sum(blk * w, axis=1)


@functools.partial(jax.jit, static_argnames=())
def block_checksum(x):
    """x: (rows, BLOCK-multiple) int32 -> (rows,) int32 per-row checksum."""
    rows, n = x.shape
    assert n == BLOCK, f"compiled for fixed block width {BLOCK}, got {n}"
    return pl.pallas_call(
        _checksum_kernel,
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.int32),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        interpret=True,
    )(x)
