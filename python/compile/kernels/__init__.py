"""L1 Pallas kernels for VeloC's compute hot-spots.

- xor_parity: erasure-group parity encode (resilience level 3)
- block_checksum: integrity-module checksum
- fused_linear: MXU-shaped linear layer used by the L2 MLPs
"""

from .checksum import BLOCK, block_checksum
from .fused_linear import fused_linear
from .xor_parity import BLOCK_N, xor_parity

__all__ = [
    "BLOCK",
    "BLOCK_N",
    "block_checksum",
    "fused_linear",
    "xor_parity",
]
