"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

pytest asserts kernel-vs-ref allclose across hypothesis-driven shape/dtype
sweeps (python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def xor_parity_ref(x):
    """x: (k, n) int -> (n,) XOR reduction over the shard axis."""
    out = x[0]
    for i in range(1, x.shape[0]):
        out = jnp.bitwise_xor(out, x[i])
    return out


def block_checksum_ref(x):
    """x: (rows, blk) int32 -> (rows,) position-weighted wrapping sum."""
    w = (2 * jnp.arange(x.shape[1], dtype=jnp.int32) + 1)
    return jnp.sum(x * w[None, :], axis=1, dtype=jnp.int32)


def fused_linear_ref(x, w, b, relu=True):
    y = x @ w + b[None, :]
    return jnp.maximum(y, 0.0) if relu else y
