"""L1 Pallas kernel: fused linear layer (matmul + bias + optional ReLU).

Used by both L2 MLPs (the interval-prediction network of paper ref [1] and
the application DNN that the DeepFreeze-style experiments checkpoint).

TPU adaptation: the whole (B, In) x (In, Out) product is expressed as one
MXU-shaped matmul per output tile with the bias add and ReLU fused
in-register, instead of three separate HLO ops. Block sizes are multiples of
the (8, 128) TPU tile. A custom_vjp keeps the kernel on the *training* path:
forward runs the Pallas kernel, backward is plain jnp (standard dense-layer
gradients), so jax.grad works through it and everything lowers into one HLO
module.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, relu):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _fused_linear_impl(x, w, b, relu):
    bsz, d_in = x.shape
    d_out = w.shape[1]
    return pl.pallas_call(
        functools.partial(_fused_linear_kernel, relu=relu),
        out_shape=jax.ShapeDtypeStruct((bsz, d_out), jnp.float32),
        # Single block: the MLP layers here are small enough to sit in VMEM
        # whole (max layer 784x512 f32 = 1.6 MiB). For larger layers the
        # grid would tile (bsz, d_out) into (128, 128) MXU blocks.
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, relu=True):
    """relu(x @ w + b) (or affine only) with a Pallas forward."""
    return _fused_linear_impl(x, w, b, relu)


def _fwd(x, w, b, relu):
    y = _fused_linear_impl(x, w, b, relu)
    return y, (x, w, y)


def _bwd(relu, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0).astype(g.dtype)
    gx = g @ w.T
    gw = x.T @ g
    gb = jnp.sum(g, axis=0)
    return gx, gw, gb


fused_linear.defvjp(_fwd, _bwd)
