"""L1 Pallas kernel: XOR parity encode over a k-shard erasure group.

This is the compute hot-spot of VeloC's erasure-coding resilience level:
given k equally-sized checkpoint shards (one per group member), produce the
XOR parity shard that allows reconstructing any single lost shard.

TPU adaptation (DESIGN.md §Hardware-Adaptation): an HPC erasure library does
word-wide SIMD XOR on CPU; on TPU we tile the (k, n) shard group into
VMEM-resident blocks via BlockSpec and reduce across the shard axis with a
vectorized `bitwise_xor`, streaming HBM->VMEM block by block along n.

Lowered with interpret=True (CPU PJRT cannot run Mosaic custom-calls); the
real-TPU VMEM/MXU estimate lives in DESIGN.md / EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block width along the data axis. 512 int32 lanes = 2 KiB per shard row;
# with k<=8 shards resident the block is <=16 KiB of VMEM, far under budget,
# and a multiple of the 128-lane TPU vector width.
BLOCK_N = 512


def _xor_kernel(x_ref, o_ref):
    """x_ref: (k, BLOCK_N) int32 block; o_ref: (BLOCK_N,) int32 parity."""
    blk = x_ref[...]
    # Reduce across the shard axis. k is small and static, so an unrolled
    # lax.reduce via jnp keeps everything in registers.
    o_ref[...] = jax.lax.reduce(
        blk, jnp.int32(0), jax.lax.bitwise_xor, dimensions=(0,)
    )


@functools.partial(jax.jit, static_argnames=("block_n",))
def xor_parity(x, block_n=BLOCK_N):
    """XOR-reduce shards: x (k, n) int32 -> parity (n,) int32.

    n must be a multiple of block_n (the Rust caller pads checkpoint chunks
    to the block size; see rust/src/modules/erasure.rs).
    """
    k, n = x.shape
    assert n % block_n == 0, f"n={n} not a multiple of block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _xor_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        grid=grid,
        in_specs=[pl.BlockSpec((k, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        interpret=True,
    )(x)
