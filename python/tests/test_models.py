"""L2 model correctness: shapes, training signal, numerical sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# Interval MLP
# ---------------------------------------------------------------------------

def test_interval_mlp_fwd_shape(key):
    params = model.interval_mlp_init(key)
    x = jax.random.normal(key, (model.INTERVAL_BATCH, model.INTERVAL_FEATURES))
    (y,) = model.interval_mlp_fwd(*params, x)
    assert y.shape == (model.INTERVAL_BATCH,)
    assert np.isfinite(np.asarray(y)).all()


def test_interval_mlp_learns_young_daly(key):
    """The MLP fits a Young/Daly-like target sqrt(2*C*MTBF) from features."""
    params = model.interval_mlp_init(key)
    step = jax.jit(model.interval_mlp_train)
    lr = jnp.float32(0.01)
    k = key
    losses = []
    for i in range(200):
        k, ka, kb = jax.random.split(k, 3)
        x = jax.random.uniform(
            ka, (model.INTERVAL_BATCH, model.INTERVAL_FEATURES),
            minval=0.1, maxval=1.0)
        # target: normalized Young/Daly from features 0 (ckpt cost) and 1 (mtbf)
        y = jnp.sqrt(2.0 * x[:, 0] * x[:, 1])
        out = step(*params, x, y, lr)
        params, loss = out[:-1], out[-1]
        losses.append(float(loss))
    assert losses[-1] < 0.25 * losses[0], (losses[0], losses[-1])


def test_interval_mlp_train_preserves_shapes(key):
    params = model.interval_mlp_init(key)
    x = jax.random.normal(key, (model.INTERVAL_BATCH, model.INTERVAL_FEATURES))
    y = jax.random.normal(key, (model.INTERVAL_BATCH,))
    out = model.interval_mlp_train(*params, x, y, jnp.float32(0.01))
    assert len(out) == 7
    for p, p2 in zip(params, out[:-1]):
        assert p.shape == p2.shape


# ---------------------------------------------------------------------------
# Seq2seq GRU
# ---------------------------------------------------------------------------

def test_seq2seq_fwd_shape_and_range(key):
    params = model.seq2seq_init(key)
    window = jax.random.uniform(key, (3, model.SEQ_WINDOW))
    (pred,) = model.seq2seq_fwd(*params, window)
    assert pred.shape == (3, model.SEQ_HORIZON)
    p = np.asarray(pred)
    assert (p >= 0).all() and (p <= 1).all()  # sigmoid head


def test_seq2seq_learns_constant_signal(key):
    """Sanity: a constant utilization trace is learnable quickly."""
    params = model.seq2seq_init(key)
    step = jax.jit(model.seq2seq_train)
    window = jnp.full((8, model.SEQ_WINDOW), 0.8)
    target = jnp.full((8, model.SEQ_HORIZON), 0.8)
    lr = jnp.float32(0.1)
    first = last = None
    for i in range(60):
        out = step(*params, window, target, lr)
        params, loss = out[:-1], out[-1]
        if i == 0:
            first = float(loss)
        last = float(loss)
    assert last < 0.5 * first, (first, last)


def test_seq2seq_batch_independence(key):
    """Row i of a batched forward == forward of row i alone."""
    params = model.seq2seq_init(key)
    window = jax.random.uniform(key, (4, model.SEQ_WINDOW))
    (batched,) = model.seq2seq_fwd(*params, window)
    (single,) = model.seq2seq_fwd(*params, window[2:3])
    np.testing.assert_allclose(np.asarray(batched[2]), np.asarray(single[0]),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Application DNN
# ---------------------------------------------------------------------------

def _batch(key):
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (model.DNN_BATCH, model.DNN_IN))
    y = jax.random.randint(ky, (model.DNN_BATCH,), 0, model.DNN_CLASSES)
    return x, y


def test_dnn_loss_initial_is_chance(key):
    """Untrained model: CE loss ~= ln(10), accuracy ~= 10%."""
    params = model.dnn_init(key)
    x, y = _batch(key)
    loss, acc = model.dnn_loss(*params, x, y)
    assert abs(float(loss) - np.log(model.DNN_CLASSES)) < 2.0
    assert float(acc) < 0.5


def test_dnn_train_reduces_loss(key):
    """Overfit a single synthetic batch — loss must fall sharply."""
    params = model.dnn_init(key)
    x, y = _batch(key)
    step = jax.jit(model.dnn_train_step)
    lr = jnp.float32(0.05)
    first = last = None
    for i in range(40):
        out = step(*params, x, y, lr)
        params, loss = out[:-1], out[-1]
        if i == 0:
            first = float(loss)
        last = float(loss)
    assert last < 0.3 * first, (first, last)


def test_dnn_train_step_grad_direction(key):
    """A single step with tiny lr must not increase the loss."""
    params = model.dnn_init(key)
    x, y = _batch(key)
    out = model.dnn_train_step(*params, x, y, jnp.float32(1e-3))
    params2, loss1 = out[:-1], out[-1]
    loss2, _ = model.dnn_loss(*params2, x, y)
    assert float(loss2) <= float(loss1) + 1e-4
