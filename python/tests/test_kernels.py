"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; assert_allclose (exact for integer kernels)
against compile.kernels.ref — the CORE correctness signal for the AOT path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    BLOCK,
    block_checksum,
    fused_linear,
    xor_parity,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# xor_parity
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(min_value=2, max_value=8),
    nblocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_xor_parity_matches_ref(k, nblocks, seed):
    rng = np.random.default_rng(seed)
    n = nblocks * 512
    x = jnp.asarray(
        rng.integers(-(2**31), 2**31 - 1, size=(k, n), dtype=np.int64),
        dtype=jnp.int32,
    )
    got = xor_parity(x)
    want = ref.xor_parity_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xor_parity_self_inverse():
    """Parity XOR any k-1 shards reconstructs the missing shard."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 2**31 - 1, size=(4, 1024), dtype=np.int64),
                    dtype=jnp.int32)
    p = xor_parity(x)
    # Drop shard 2; xor of parity and remaining shards must equal it.
    rebuilt = p ^ x[0] ^ x[1] ^ x[3]
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(x[2]))


def test_xor_parity_zero_input():
    x = jnp.zeros((4, 512), dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(xor_parity(x)), np.zeros(512))


def test_xor_parity_rejects_unaligned():
    with pytest.raises(AssertionError):
        xor_parity(jnp.zeros((4, 100), dtype=jnp.int32))


# ---------------------------------------------------------------------------
# block_checksum
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_checksum_matches_ref(rows, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.integers(-(2**31), 2**31 - 1, size=(rows, BLOCK), dtype=np.int64),
        dtype=jnp.int32,
    )
    got = block_checksum(x)
    want = ref.block_checksum_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_checksum_detects_single_bitflip():
    rng = np.random.default_rng(3)
    x = np.asarray(
        rng.integers(-(2**31), 2**31 - 1, size=(2, BLOCK), dtype=np.int64),
        dtype=np.int32,
    )
    base = np.asarray(block_checksum(jnp.asarray(x)))
    x2 = x.copy()
    x2[1, 1234] ^= 1
    flipped = np.asarray(block_checksum(jnp.asarray(x2)))
    assert base[0] == flipped[0]
    assert base[1] != flipped[1]


def test_checksum_detects_swapped_words():
    """Position weighting catches transpositions a plain sum would miss."""
    x = np.zeros((1, BLOCK), dtype=np.int32)
    x[0, 10] = 111
    x[0, 20] = 222
    a = np.asarray(block_checksum(jnp.asarray(x)))
    x[0, 10], x[0, 20] = 222, 111
    b = np.asarray(block_checksum(jnp.asarray(x)))
    assert a[0] != b[0]


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=32),
    d_in=st.integers(min_value=1, max_value=64),
    d_out=st.integers(min_value=1, max_value=64),
    relu=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_linear_matches_ref(b, d_in, d_out, relu, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (b, d_in))
    w = jax.random.normal(k2, (d_in, d_out))
    bias = jax.random.normal(k3, (d_out,))
    got = fused_linear(x, w, bias, relu)
    want = ref.fused_linear_ref(x, w, bias, relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       relu=st.booleans())
def test_fused_linear_vjp_matches_ref(seed, relu):
    """custom_vjp gradients == autodiff through the pure-jnp reference."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (8, 16))
    w = jax.random.normal(k2, (16, 4))
    bias = jax.random.normal(k3, (4,))

    def loss_kernel(x, w, b):
        return jnp.sum(fused_linear(x, w, b, relu) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.fused_linear_ref(x, w, b, relu) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, bias)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, bias)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-5)


def test_fused_linear_relu_clamps():
    x = jnp.array([[-100.0, -100.0]])
    w = jnp.eye(2)
    b = jnp.zeros((2,))
    out = fused_linear(x, w, b, True)
    assert (np.asarray(out) == 0).all()
