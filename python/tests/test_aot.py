"""AOT path sanity: lowering to HLO text, manifest consistency, param blobs.

These tests exercise the same lowering recipe aot.py uses (stablehlo ->
XlaComputation -> HLO text) without re-running the full (slow) artifact
build; if artifacts/ already exists they additionally cross-check it.
"""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import xor_parity

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_roundtrippable():
    text = aot.lower(
        model.interval_mlp_fwd,
        *(
            [aot.f32(model.INTERVAL_FEATURES, model.INTERVAL_HIDDEN),
             aot.f32(model.INTERVAL_HIDDEN),
             aot.f32(model.INTERVAL_HIDDEN, model.INTERVAL_HIDDEN),
             aot.f32(model.INTERVAL_HIDDEN),
             aot.f32(model.INTERVAL_HIDDEN, 1),
             aot.f32(1),
             aot.f32(model.INTERVAL_BATCH, model.INTERVAL_FEATURES)]
        ),
    )
    assert "ENTRY" in text
    assert "HloModule" in text
    # f32[64,10] input parameter present
    assert f"f32[{model.INTERVAL_BATCH},{model.INTERVAL_FEATURES}]" in text


def test_kernel_lowering_contains_no_custom_call():
    """interpret=True Pallas must lower to plain HLO the CPU client can run."""
    text = aot.lower(xor_parity, aot.i32(4, 1024))
    assert "custom-call" not in text.lower() or "Mosaic" not in text


def test_write_params_bin(tmp_path):
    p = tmp_path / "t.bin"
    a = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    b = jnp.ones((4,), dtype=jnp.float32)
    entries = aot.write_params_bin(str(p), [("a", a), ("b", b)])
    assert entries[0] == {"name": "a", "shape": [2, 3], "offset": 0, "len": 6}
    assert entries[1]["offset"] == 24
    raw = p.read_bytes()
    assert len(raw) == 40
    vals = struct.unpack("<10f", raw)
    assert vals[:6] == (0, 1, 2, 3, 4, 5)
    assert vals[6:] == (1, 1, 1, 1)


def test_synth_trace_properties():
    phased = 0
    for seed in range(12):
        tr = aot.synth_trace(jax.random.PRNGKey(seed), 64)
        t = np.asarray(tr)
        assert t.shape == (64,)
        assert (t >= 0).all() and (t <= 1).all()
        if t.max() > 0.6 and t.min() < 0.4:
            phased += 1
    # ~70% of traces are phase-structured (both busy and idle present);
    # the rest are deliberately steady-state (see synth_trace docstring).
    assert phased >= 6, phased


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_matches_artifacts():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, mod in man["modules"].items():
        path = os.path.join(ART, mod["file"])
        assert os.path.exists(path), path
        text = open(path).read()
        assert "ENTRY" in text
    for name, blob in man["params"].items():
        path = os.path.join(ART, blob["file"])
        size = os.path.getsize(path)
        end = max(t["offset"] + 4 * t["len"] for t in blob["tensors"])
        assert size == end, (name, size, end)
    c = man["constants"]
    assert c["dnn_in"] == model.DNN_IN
    assert c["seq_window"] == model.SEQ_WINDOW


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_artifact_arg_counts():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert len(man["modules"]["dnn_train_step"]["args"]) == 9   # 6 params+x+y+lr
    assert man["modules"]["dnn_train_step"]["outputs"] == 7
    assert len(man["modules"]["xor_parity"]["args"]) == 1
    assert len(man["modules"]["seq2seq_fwd"]["args"]) == 6
