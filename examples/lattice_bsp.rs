//! Lattice-style BSP workload under VeloC — the LatticeQCD-shaped ECP
//! application pattern (paper §4): halo-exchange supersteps over the rank
//! ring, collectively-agreed checkpoint versions (allreduce-min), failure
//! injection and consistent restart.
//!
//! Demonstrates the `cluster::comm` substrate (point-to-point + barrier +
//! allreduce) driving the same VeloC client API the other workloads use.
//!
//! Run: `cargo run --release --example lattice_bsp [-- --steps 60]`

use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::app::BspApp;
use veloc::cluster::{CommWorld, FailureScope};
use veloc::pipeline::level_name;
use veloc::util::cli::Cli;

fn main() -> Result<()> {
    let cli = Cli::new("lattice_bsp", "BSP lattice app under VeloC")
        .opt("nodes", "8", "nodes (1 rank each)")
        .opt("steps", "60", "supersteps")
        .opt("ckpt-every", "10", "collective checkpoint interval")
        .opt("cells", "64", "lattice cells per rank")
        .parse();
    let nodes = cli.get_usize("nodes");
    let steps = cli.get_u64("steps");
    let every = cli.get_u64("ckpt-every").max(1);
    let cells = cli.get_usize("cells");

    let mut cfg = VelocConfig::default().with_nodes(nodes, 1);
    cfg.stack.erasure_group = if nodes % 4 == 0 { 4 } else { 0 };
    let rt = VelocRuntime::new(cfg)?;
    let comm = CommWorld::new(nodes);
    let timeout = Duration::from_secs(30);

    println!(
        "lattice: {nodes} ranks x {cells} cells, {steps} supersteps, ckpt every {every}"
    );

    // Phase 1: run to completion with periodic collective checkpoints.
    let handles: Vec<_> = (0..nodes)
        .map(|rank| {
            let rt: Arc<VelocRuntime> = Arc::clone(&rt);
            let comm = comm.clone();
            std::thread::spawn(move || -> Result<f64> {
                let client = rt.client(rank);
                let mut app =
                    BspApp::new(&client, comm.endpoint(rank), "lattice", cells, timeout);
                while app.superstep < steps {
                    app.superstep()?;
                    if app.superstep % every == 0 {
                        let v = app.collective_checkpoint(&client)?;
                        client.checkpoint_wait_done("lattice", v)?;
                        if rank == 0 {
                            println!(
                                "  superstep {:>4}: collective checkpoint v{v}, field sum {:.3}",
                                app.superstep,
                                app.field_sum()
                            );
                        }
                    }
                }
                Ok(app.field_sum())
            })
        })
        .collect();
    let mut mass = 0.0;
    for h in handles {
        mass += h.join().unwrap()?;
    }
    rt.drain();
    println!("completed: conserved field mass = {mass:.6} (expected 1000)");

    // Phase 2: lose two adjacent nodes (a partner pair) and restart all
    // ranks from the agreed version.
    println!("\n!! injecting multi-node failure: nodes 2+3 down");
    rt.inject_failure(&FailureScope::MultiNode(vec![2, 3]));
    rt.revive_all();
    let comm2 = CommWorld::new(nodes);
    let mut restored = Vec::new();
    for rank in 0..nodes {
        let client = rt.client(rank);
        let mut app = BspApp::new(&client, comm2.endpoint(rank), "lattice", cells, timeout);
        let step = app
            .restart(&client)?
            .expect("collective checkpoint must be restorable");
        restored.push(step);
    }
    let m = rt.metrics();
    println!("all ranks restored to superstep {}", restored[0]);
    assert!(restored.iter().all(|&s| s == restored[0]), "consistent cut");
    for l in 1..=5u8 {
        let c = m.counter_with("restart.by_level", &[("level", level_name(l))]);
        if c > 0 {
            println!("  {:>8} restores from level {} ({})", c, l, level_name(l));
        }
    }
    println!("OK: consistent collective restart after partner-pair loss");
    Ok(())
}
