//! End-to-end validation driver (DESIGN.md): train the application DNN
//! through the full three-layer stack — Rust coordinator -> PJRT -> AOT
//! JAX/Pallas train step — under VeloC checkpointing, inject a node
//! failure mid-run, restart from the best surviving level, and log a loss
//! curve that continues smoothly across the failure.
//!
//! This is the paper's §3 "productive checkpointing" scenario (DeepFreeze
//! [3]): the model's parameter tensors are critical memory regions,
//! captured fine-grained after each optimizer update.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example dnn_training [-- --steps 300]

use anyhow::Result;
use std::sync::Arc;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::app::{CaptureMode, DnnTrainer};
use veloc::cluster::FailureScope;
use veloc::pipeline::level_name;
use veloc::runtime::PjrtEngine;
use veloc::util::cli::Cli;

fn main() -> Result<()> {
    let cli = Cli::new(
        "dnn_training",
        "end-to-end: DNN training under VeloC with failure + restart",
    )
    .opt("steps", "300", "total training steps")
    .opt("ckpt-every", "25", "checkpoint every N steps")
    .opt("fail-at", "150", "inject a node failure after this step (0=off)")
    .opt("lr", "0.05", "SGD learning rate")
    .parse();
    let steps = cli.get_u64("steps");
    let every = cli.get_u64("ckpt-every").max(1);
    let fail_at = cli.get_u64("fail-at");
    let lr = cli.get_f64("lr") as f32;

    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.use_kernels = true; // checksum digests through the Pallas kernel
    cfg.stack.use_kernels = true;
    // Only this rank checkpoints, so the group-collective erasure level
    // stays off; partner replication + PFS flush protect the model.
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg)?;
    let engine = PjrtEngine::load(&rt.config().artifacts_dir())?;
    engine.warm(&["dnn_train_step", "dnn_loss"])?;

    // Rank 0 trains; the other ranks exist so partner/erasure levels have
    // real failure domains to land on. (Data-parallel replicas would each
    // run this same loop.)
    let client = rt.client(0);
    let mut trainer = DnnTrainer::new(
        &client,
        Arc::clone(&engine),
        "dnn",
        lr,
        CaptureMode::FineGrained,
        42,
    )?;
    println!(
        "model: {} parameters; capture=fine-grained; ckpt every {every} steps",
        trainer.param_count()
    );
    println!("{:>6} {:>10} {:>8}  note", "step", "loss", "acc");

    let mut losses: Vec<(u64, f32)> = Vec::new();
    let mut injected = false;
    while trainer.step < steps {
        let loss = trainer.train_step()?;
        losses.push((trainer.step, loss));
        if trainer.step % every == 0 {
            let v = trainer.checkpoint(&client)?;
            client.checkpoint_wait_done("dnn", v)?;
            let (eval_loss, acc) = trainer.evaluate()?;
            println!(
                "{:>6} {:>10.4} {:>8.3}  checkpoint v{v}",
                trainer.step, eval_loss, acc
            );
        }
        if !injected && fail_at > 0 && trainer.step >= fail_at {
            injected = true;
            rt.drain();
            println!("!! node 0 failure injected at step {}", trainer.step);
            rt.inject_failure(&FailureScope::Node(0));
            rt.revive_all();
            // Respawned process: fresh trainer, restore via VeloC.
            let client2 = rt.client(0);
            let mut t2 = DnnTrainer::new(
                &client2,
                Arc::clone(&engine),
                "dnn",
                lr,
                CaptureMode::FineGrained,
                42,
            )?;
            let restored = t2.restart(&client2)?.expect("restart must succeed");
            // Which level served it?
            let m = rt.metrics();
            let lvl = (1..=5)
                .find(|&l| {
                    m.counter_with("restart.by_level", &[("level", level_name(l as u8))]) > 0
                })
                .unwrap_or(0);
            println!(
                "   restarted from v{restored} (level {lvl} = {}), resuming at step {}",
                level_name(lvl as u8),
                t2.step
            );
            trainer = t2;
        }
    }
    rt.drain();

    let (final_loss, final_acc) = trainer.evaluate()?;
    println!("\nfinal: step {} loss {:.4} acc {:.3}", trainer.step, final_loss, final_acc);

    // Loss-curve sanity for EXPERIMENTS.md: model learned, and the curve
    // continued (no blow-up after restart).
    let first = losses.first().unwrap().1;
    let last = losses.last().unwrap().1;
    println!(
        "loss curve: start {:.4} -> end {:.4} ({} recorded steps, failure {})",
        first,
        last,
        losses.len(),
        if injected { "injected+recovered" } else { "none" }
    );
    assert!(last < first, "training must reduce loss");
    println!("OK: end-to-end three-layer stack validated");
    Ok(())
}
