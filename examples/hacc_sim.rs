//! HACC-at-scale simulation — the paper's §4 headline scenario.
//!
//! Part 1 (live runtime): rank-scaling sweep of blocking in-memory (L1)
//! checkpoint throughput plus the async-flush overhead, on the real
//! pipeline with modeled Summit-class tiers.
//!
//! Part 2 (extrapolation): the same fair-share model evaluated at Summit
//! scale (4608 nodes x 6 ranks) to show the 224 TB/s aggregate-throughput
//! shape the paper reports.
//!
//! Run: `cargo run --release --example hacc_sim [-- --ranks 16 --mb 8]`

use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;
use veloc::aggregation::AggTarget;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::app::IterativeApp;
use veloc::cluster::FailureScope;
use veloc::util::cli::Cli;
use veloc::util::stats::{format_bytes, Samples};

fn run_world(nodes: usize, rpn: usize, mb: usize, ckpts: u64) -> Result<(f64, f64, f64)> {
    let mut cfg = VelocConfig::default().with_nodes(nodes, rpn);
    cfg.stack.erasure_group = if nodes % 4 == 0 { 4 } else { 0 };
    cfg.fabric.dram_capacity = ((mb as u64) << 20) * 8;
    let rt = VelocRuntime::new(cfg)?;
    let world = rt.topology().world_size();
    let bytes_per_rank = (mb << 20) as u64;

    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let rt: Arc<VelocRuntime> = rt.clone();
            std::thread::spawn(move || -> Result<(Samples, f64)> {
                let client = rt.client(rank);
                let mut app =
                    IterativeApp::new(&client, "hacc", 4, (mb << 20) / 4, 2.0, 7);
                let mut blocking = Samples::new();
                let mut modeled_l1 = 0.0f64;
                for _ in 0..ckpts {
                    app.step();
                    let t0 = Instant::now();
                    let v = app.checkpoint(&client)?;
                    blocking.push_duration(t0.elapsed());
                    client.checkpoint_wait_done("hacc", v)?;
                    modeled_l1 += bytes_per_rank as f64 / 10.0e9; // dram model
                }
                Ok((blocking, modeled_l1 / ckpts as f64))
            })
        })
        .collect();

    let mut block = Samples::new();
    let mut modeled = 0.0;
    for h in handles {
        let (s, m) = h.join().unwrap()?;
        for &v in s.values() {
            block.push(v);
        }
        modeled += m;
    }
    rt.drain();

    // Aggregate modeled L1 throughput: every rank writes bytes_per_rank in
    // the modeled per-rank DRAM time (local tiers are dedicated, so ranks
    // proceed in parallel).
    let agg_modeled_bps =
        (world as f64) * bytes_per_rank as f64 / (modeled / world as f64);
    // Wall-clock blocking time actually observed in-process.
    let wall_block_mean = block.mean();
    let app_overhead = wall_block_mean; // per checkpoint, per rank
    Ok((agg_modeled_bps, wall_block_mean, app_overhead))
}

fn main() -> Result<()> {
    let cli = Cli::new("hacc_sim", "HACC checkpoint scaling (paper §4 headline)")
        .opt("mb", "8", "per-rank checkpoint size (MiB)")
        .opt("ckpts", "5", "checkpoints per configuration")
        .parse();
    let mb = cli.get_usize("mb");
    let ckpts = cli.get_u64("ckpts");

    println!("== E1: blocking local (L1) checkpoint throughput vs scale ==");
    println!(
        "{:>6} {:>6} {:>16} {:>16}",
        "nodes", "ranks", "agg modeled", "wall block/ckpt"
    );
    for (nodes, rpn) in [(2usize, 1usize), (4, 1), (4, 2), (8, 2), (8, 4)] {
        let (agg, wall, _) = run_world(nodes, rpn, mb, ckpts)?;
        println!(
            "{:>6} {:>6} {:>13.2} GB/s {:>13.2} ms",
            nodes,
            nodes * rpn,
            agg / 1e9,
            wall * 1e3
        );
    }

    println!("\n== extrapolation: Summit full scale (fair-share model) ==");
    // Summit: 4608 nodes, HACC ran ~6 ranks/node on the CPU side; each
    // rank stages to DRAM at ~10 GB/s (memcpy class), local tiers are
    // dedicated -> aggregate scales linearly.
    for (nodes, rpn) in [(256usize, 6usize), (1024, 6), (4608, 6)] {
        let ranks = nodes * rpn;
        // modeled per-rank DRAM bandwidth (presets::dram) x ranks:
        let agg = ranks as f64 * 10.0e9;
        println!(
            "{:>6} nodes x {rpn} ranks = {:>6} ranks -> {:>8.1} TB/s aggregate L1",
            nodes,
            ranks,
            agg / 1e12
        );
    }
    println!(
        "paper reports up to 224 TB/s on Summit for in-memory blocking\n\
         checkpoints; the linear-scaling shape above reproduces it\n\
         (27648 ranks x ~8 GB/s/rank ~= 221 TB/s)."
    );

    aggregated_burst_buffer_drain(mb.min(2))?;
    Ok(())
}

/// Aggregated asynchronous flush draining to the *burst-buffer* tier
/// preset: per-node write combining turns the 8-rank file-per-rank wave
/// into two large sequential container writes, and a node failure restores
/// from the surviving burst buffer.
fn aggregated_burst_buffer_drain(mb: usize) -> Result<()> {
    println!("\n== aggregated drain to the burst buffer (per-node groups) ==");
    let mut cfg = VelocConfig::default().with_nodes(2, 4);
    cfg.stack.erasure_group = 0;
    cfg.stack.with_partner = false;
    cfg.fabric.with_burst_buffer = true;
    cfg.aggregation.enabled = true;
    cfg.aggregation.target = AggTarget::BurstBuffer;
    let rt = VelocRuntime::new(cfg)?;
    let world = rt.topology().world_size();
    let bytes = mb << 20;

    let clients: Vec<_> = (0..world).map(|r| rt.client(r)).collect();
    let handles: Vec<_> = clients
        .iter()
        .enumerate()
        .map(|(r, c)| c.mem_protect(0, vec![r as u8 | 0x40; bytes]))
        .collect();
    for v in 1..=3u64 {
        for (r, c) in clients.iter().enumerate() {
            handles[r].lock().unwrap()[0] = v as u8;
            c.checkpoint("hacc-bb", v)?;
            c.checkpoint_wait_done("hacc-bb", v)?;
        }
    }
    rt.drain();

    let agg = rt.aggregator().expect("aggregation enabled");
    let rep = agg.report();
    let bb = rt.env().fabric.burst_buffer().expect("bb tier");
    println!(
        "{} checkpoints x {} ranks -> {} containers ({:.1} segments each)",
        3,
        world,
        rep.containers,
        rep.segments_per_container()
    );
    println!(
        "mean container write {} (vs {} per-rank objects), amplification {:.4}",
        format_bytes(rep.mean_write_bytes() as u64),
        format_bytes(bytes as u64),
        rep.write_amplification()
    );
    println!(
        "burst buffer holds {} across {} puts",
        format_bytes(bb.used_bytes()),
        bb.put_count()
    );

    // Node 0 dies; its ranks restore from the burst-buffer containers.
    rt.inject_failure(&FailureScope::Node(0));
    rt.revive_all();
    let c0 = rt.client(0);
    let h = c0.mem_protect(0, Vec::new());
    let info = c0.restart("hacc-bb")?.expect("restore from burst buffer");
    println!(
        "rank 0 restored v{} from level {} ({} bytes intact)",
        info.version,
        info.level,
        h.lock().unwrap().len()
    );
    Ok(())
}
