//! ML-optimized checkpoint intervals (paper §2 + ref [1], experiment E6).
//!
//! Pipeline: DES-label random failure scenarios -> train the AOT interval
//! MLP *from Rust through PJRT* -> compare against Young, Daly and a
//! pure-Rust random forest on held-out scenarios. Reported metric: mean
//! efficiency loss vs the DES optimum (how much machine time each policy
//! wastes), plus label-space MAE.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example interval_tuning [-- --train 120 --test 30]

use anyhow::Result;
use veloc::interval::{
    self, dataset, interval_of, NnOptimizer, RandomForest,
};
use veloc::runtime::PjrtEngine;
use veloc::util::cli::Cli;

fn main() -> Result<()> {
    let cli = Cli::new("interval_tuning", "NN vs RF vs Young/Daly (E6)")
        .opt("train", "120", "training scenarios")
        .opt("test", "30", "held-out scenarios")
        .opt("grid", "10", "DES interval grid points per label")
        .opt("trials", "4", "DES trials per grid point")
        .opt("epochs", "200", "NN training epochs")
        .parse();
    let n_train = cli.get_usize("train");
    let n_test = cli.get_usize("test");
    let grid = cli.get_usize("grid");
    let trials = cli.get_usize("trials");
    let epochs = cli.get_usize("epochs");

    println!("generating {} DES-labelled scenarios...", n_train + n_test);
    let data = dataset::generate(n_train + n_test, grid, trials, 31);
    let (train, test) = dataset::split(data, n_test as f64 / (n_train + n_test) as f64);

    // --- NN (AOT MLP, trained through PJRT) -----------------------------
    let engine = PjrtEngine::load(&veloc::runtime::default_artifacts_dir())?;
    let mut nn = NnOptimizer::new(engine)?;
    let hist = nn.fit(&train, epochs, 0.02, 7)?;
    println!(
        "NN trained: loss {:.4} -> {:.4} over {} epochs",
        hist.first().unwrap(),
        hist.last().unwrap(),
        hist.len()
    );

    // --- Random forest baseline -----------------------------------------
    let xs: Vec<[f32; 10]> = train.iter().map(|e| e.features).collect();
    let ys: Vec<f32> = train.iter().map(|e| e.label).collect();
    let rf = RandomForest::fit(&xs, &ys, 40, 8, 13);

    // --- Evaluation -------------------------------------------------------
    // For each held-out scenario, compute each policy's interval and its
    // DES efficiency; report the mean efficiency gap to the DES optimum.
    let mut rows: Vec<(&str, f64, f64)> = Vec::new(); // (policy, mae, eff gap)
    let policies: Vec<(&str, Box<dyn Fn(&dataset::Example) -> f64>)> = vec![
        (
            "young",
            Box::new(|e: &dataset::Example| {
                interval::young(e.scenario.l1_cost, e.scenario.mtbf)
            }),
        ),
        (
            "daly",
            Box::new(|e: &dataset::Example| {
                interval::daly(e.scenario.l1_cost, e.scenario.mtbf)
            }),
        ),
        (
            "forest",
            Box::new(|e: &dataset::Example| interval_of(rf.predict(&e.features))),
        ),
        (
            "nn",
            Box::new(|e: &dataset::Example| {
                nn.predict_interval(&e.features).unwrap_or(1.0)
            }),
        ),
    ];
    for (name, policy) in &policies {
        let mut mae = 0.0f64;
        let mut gap = 0.0f64;
        for e in &test {
            let w = policy(e).max(1.0);
            mae += (w.log10() - e.label as f64).abs();
            let eff = interval::mean_efficiency(&e.scenario, w, trials, 99);
            gap += (e.best_eff - eff).max(0.0);
        }
        rows.push((name, mae / test.len() as f64, gap / test.len() as f64));
    }

    println!("\n== E6: interval policy quality on {} held-out scenarios ==", test.len());
    println!("{:<8} {:>12} {:>18}", "policy", "MAE(log10 W)", "eff. loss vs DES");
    for (name, mae, gap) in &rows {
        println!("{name:<8} {mae:>12.3} {:>17.1}%", gap * 100.0);
    }
    let nn_row = rows.iter().find(|r| r.0 == "nn").unwrap();
    let rf_row = rows.iter().find(|r| r.0 == "forest").unwrap();
    println!(
        "\npaper [1] reports NN outperforming random forest: NN gap {:.2}% vs RF gap {:.2}% -> {}",
        nn_row.2 * 100.0,
        rf_row.2 * 100.0,
        if nn_row.2 <= rf_row.2 { "reproduced" } else { "NOT reproduced on this draw" }
    );
    Ok(())
}
