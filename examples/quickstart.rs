//! Quickstart: the VeloC user-facing API in ~60 lines.
//!
//! 1. build a runtime (4 nodes x 2 ranks, async engine),
//! 2. declare critical memory regions,
//! 3. take a collective checkpoint (blocks only for the local capture),
//! 4. kill a node, restart from the surviving levels,
//! 5. print the module pipeline (paper Figure 1).
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::cluster::FailureScope;
use veloc::pipeline::level_name;

fn main() -> Result<()> {
    // 4 nodes x 2 ranks, default module stack (checksum < local < partner
    // < erasure(k=4) < transfer < version), async active backend.
    let cfg = VelocConfig::default().with_nodes(4, 2);
    let rt = VelocRuntime::new(cfg)?;
    println!("== pipeline (paper Figure 1) ==");
    print!("{}", rt.engine(0).describe());

    // Every rank declares its critical regions and checkpoints v1.
    let world = rt.topology().world_size();
    let mut handles = Vec::new();
    for rank in 0..world {
        let rt = rt.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let client = rt.client(rank);
            // Two regions: a header and a payload unique to this rank.
            client.mem_protect(0, format!("header-of-rank-{rank}").into_bytes());
            client.mem_protect(1, vec![rank as u8; 1 << 20]);
            client.checkpoint("quickstart", 1)?;
            // Returns when all levels settled (local copy already safe
            // when checkpoint() itself returned).
            client.checkpoint_wait_done("quickstart", 1)?;
            Ok(())
        }));
    }
    for h in handles {
        h.join().unwrap()?;
    }
    rt.drain();
    println!("\ncheckpoint v1 complete on {world} ranks");

    // Disaster: node 1 dies (ranks 2,3 lose their node-local copies).
    rt.inject_failure(&FailureScope::Node(1));
    rt.revive_all();
    println!("injected failure: node 1 down\n");

    for rank in rt.topology().ranks_of_node(1) {
        let client = rt.client(rank);
        let header = client.mem_protect(0, Vec::new());
        let payload = client.mem_protect(1, Vec::new());
        let info = client
            .restart("quickstart")?
            .expect("a surviving level must serve the restart");
        println!(
            "rank {rank}: restored v{} from level {} ({}); header={:?}, payload ok={}",
            info.version,
            info.level,
            level_name(info.level),
            String::from_utf8_lossy(&header.lock().unwrap()),
            *payload.lock().unwrap() == vec![rank as u8; 1 << 20],
        );
    }

    println!("\nmetrics:\n{}", rt.metrics().to_json().to_pretty());
    Ok(())
}
