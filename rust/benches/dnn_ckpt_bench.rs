//! E7 — DeepFreeze-style DNN checkpointing (paper §3 / ref [3]): training
//! iteration cost with (a) no checkpointing, (b) synchronous monolithic
//! capture + sync pipeline, (c) fine-grained capture overlapped with the
//! async pipeline.
//!
//! Shape to reproduce: fine-grained async checkpointing adds minimal
//! overhead per iteration versus the blocking monolithic approach
//! ("a full checkpoint of the DNN model ... with minimal impact on the
//! learning performance").
//!
//! Requires `make artifacts` (self-skips otherwise).

#[path = "harness.rs"]
mod harness;

use std::time::Instant;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::app::{CaptureMode, DnnTrainer};
use veloc::pipeline::EngineMode;
use veloc::runtime::{default_artifacts_dir, PjrtEngine};
use veloc::util::stats::Samples;

fn run(mode: CaptureMode, engine_mode: EngineMode, ckpt: bool) -> (f64, f64) {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.engine_mode = engine_mode;
    // Single-trainer productive checkpointing: the erasure level needs
    // whole-group checkpoints and stays off; partner + PFS protect the
    // model (same stack the dnn_training example uses).
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    let engine = PjrtEngine::load(&default_artifacts_dir()).unwrap();
    engine.warm(&["dnn_train_step"]).unwrap();
    let client = rt.client(0);
    let mut trainer =
        DnnTrainer::new(&client, engine, "e7", 0.05, mode, 11).unwrap();
    let steps = harness::scaled(30) as u64;
    let mut iter_s = Samples::new();
    let mut ckpt_s = Samples::new();
    while trainer.step < steps {
        let t0 = Instant::now();
        trainer.train_step().unwrap();
        iter_s.push_duration(t0.elapsed());
        if ckpt && trainer.step % 5 == 0 {
            let t1 = Instant::now();
            trainer.checkpoint(&client).unwrap();
            ckpt_s.push_duration(t1.elapsed());
        }
    }
    rt.drain();
    (iter_s.mean(), if ckpt { ckpt_s.mean() } else { 0.0 })
}

fn main() {
    if !default_artifacts_dir().join("manifest.json").exists() {
        println!("E7 skipped: run `make artifacts` first");
        return;
    }
    harness::section("E7: DNN training under checkpointing (0.5M params, ckpt every 5 steps)");
    println!(
        "{:<34} {:>14} {:>16}",
        "mode", "iter mean", "blocking/ckpt"
    );
    let (base_iter, _) = run(CaptureMode::Monolithic, EngineMode::Sync, false);
    println!(
        "{:<34} {:>11.2} ms {:>16}",
        "no checkpointing",
        base_iter * 1e3,
        "-"
    );
    for (label, mode, em) in [
        (
            "monolithic + sync pipeline",
            CaptureMode::Monolithic,
            EngineMode::Sync,
        ),
        (
            "monolithic + async pipeline",
            CaptureMode::Monolithic,
            EngineMode::Async,
        ),
        (
            "fine-grained + async (DeepFreeze)",
            CaptureMode::FineGrained,
            EngineMode::Async,
        ),
    ] {
        let (iter, ckpt) = run(mode, em, true);
        println!(
            "{:<34} {:>11.2} ms {:>13.2} ms",
            label,
            iter * 1e3,
            ckpt * 1e3,
        );
    }
    let _ = base_iter;
    println!(
        "\npaper [3] shape: the application-visible blocking per checkpoint\n\
         shrinks monotonically from monolithic+sync to fine-grained+async\n\
         (DeepFreeze); per-iteration means carry PJRT train-step variance\n\
         (~10-20%), so blocking/ckpt is the decisive column."
    );
}
