//! E4 — background-flush interference vs scheduling policy (paper §2's
//! two mitigation strategies).
//!
//! The app runs CPU-bound iterations while the active backend flushes
//! checkpoints; ranks are oversubscribed relative to backend threads so
//! contention is real. Shape to reproduce: greedy flushing slows the
//! application the most; low-priority throttling and predictive (idle-
//! phase) scheduling recover most of the lost iteration time, at the cost
//! of a longer flush tail.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Instant;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::app::IterativeApp;
use veloc::scheduler::SchedulerPolicy;
use veloc::util::stats::Samples;

/// Returns (mean iteration seconds, flush drain seconds).
fn run(policy: SchedulerPolicy, mb: usize) -> (f64, f64) {
    let mut cfg = VelocConfig::default().with_nodes(4, 2);
    cfg.scheduler = policy;
    cfg.calibrate_interference = policy == SchedulerPolicy::LowPriority;
    cfg.stack.erasure_group = 4;
    cfg.stack.flush_chunk = 256 << 10;
    cfg.backend_threads = 2;
    let rt = VelocRuntime::new(cfg).unwrap();
    let world = rt.topology().world_size();
    let iters = harness::scaled(40) as u64;

    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let rt: Arc<VelocRuntime> = Arc::clone(&rt);
            std::thread::spawn(move || {
                let client = rt.client(rank);
                let mut app =
                    IterativeApp::new(&client, "e4", 2, (mb << 20) / 2, 2.0, 5);
                let mut iter_s = Samples::new();
                while app.iteration < iters {
                    let d = app.step();
                    iter_s.push_duration(d);
                    // Phase-structured utilization for the predictor:
                    // busy during compute, idle entering the ckpt window.
                    client.report_utilization(if app.iteration % 5 < 4 { 0.9 } else { 0.1 });
                    if app.iteration % 5 == 0 {
                        let _v = app.checkpoint(&client).unwrap();
                    }
                }
                iter_s.mean()
            })
        })
        .collect();
    let mut iter_mean = 0.0;
    for h in handles {
        iter_mean += h.join().unwrap() / world as f64;
    }
    let t0 = Instant::now();
    rt.drain();
    (iter_mean, t0.elapsed().as_secs_f64())
}

fn main() {
    let mb = 8usize;
    harness::section("E4: app slowdown vs flush scheduling policy (8 ranks, 2 backend threads)");

    // Baseline: all 8 ranks computing concurrently, no checkpointing —
    // isolates the *flush* interference from plain rank-vs-rank
    // contention.
    let base = {
        let mut cfg = VelocConfig::default().with_nodes(4, 2);
        cfg.stack.with_transfer = false;
        cfg.stack.with_partner = false;
        cfg.stack.erasure_group = 0;
        let rt = VelocRuntime::new(cfg).unwrap();
        let handles: Vec<_> = (0..rt.topology().world_size())
            .map(|rank| {
                let rt: Arc<VelocRuntime> = Arc::clone(&rt);
                std::thread::spawn(move || {
                    let client = rt.client(rank);
                    let mut app =
                        IterativeApp::new(&client, "base", 2, (mb << 20) / 2, 2.0, 5);
                    let mut s = Samples::new();
                    for _ in 0..harness::scaled(40) {
                        s.push_duration(app.step());
                    }
                    s.mean()
                })
            })
            .collect();
        let mut m = 0.0;
        let n = handles.len();
        for h in handles {
            m += h.join().unwrap() / n as f64;
        }
        m
    };

    println!(
        "{:<22} {:>16} {:>12} {:>14}",
        "policy", "iter mean", "slowdown", "drain tail"
    );
    println!(
        "{:<22} {:>13.2} ms {:>12} {:>14}",
        "no checkpointing",
        base * 1e3,
        "1.00x",
        "-"
    );
    for (name, policy) in [
        ("greedy flush", SchedulerPolicy::Greedy),
        ("low-priority", SchedulerPolicy::LowPriority),
        ("predictive (seq2seq)", SchedulerPolicy::Predictive),
    ] {
        let (iter_mean, drain) = run(policy, mb);
        println!(
            "{:<22} {:>13.2} ms {:>11.2}x {:>12.2} s",
            name,
            iter_mean * 1e3,
            iter_mean / base,
            drain
        );
    }
    println!(
        "\npaper shape: mitigated policies trade a longer background tail\n\
         for lower application interference (greedy slows iterations the\n\
         most; low-priority / predictive approach the no-ckpt iteration\n\
         time)."
    );
}
