//! E5 — heterogeneous tier selection under I/O concurrency (paper [4]:
//! "there are non-obvious producer-consumer patterns that form under I/O
//! concurrency, for which using the fastest storage may be suboptimal").
//!
//! Scenario: the async flush (consumer) reads the previous checkpoint back
//! from the NVMe tier while the application (producer) captures the next
//! checkpoint. FastestFirst always targets NVMe and collides with the
//! drain; ConcurrencyAware sees the active transfers and lands on the idle
//! SSD when the modeled service time is better.
//!
//! Shape to reproduce: under concurrency, concurrency-aware selection
//! yields lower capture service time than fastest-first, although SSD is
//! nominally 4x slower.

#[path = "harness.rs"]
mod harness;

use veloc::storage::{presets, StorageTier, TierKind, TimeMode};

/// Modeled capture service time for one checkpoint under `readers`
/// concurrent flush-readbacks on the NVMe tier.
fn capture_service(
    nvme: &StorageTier,
    ssd: &StorageTier,
    bytes: u64,
    readers: usize,
    concurrency_aware: bool,
) -> (TierKind, f64) {
    // Flush readers hold the NVMe bandwidth pool.
    let score = |t: &StorageTier, extra: usize| {
        let n = if t.spec().shared {
            t.active_transfers() + extra + 1
        } else {
            1
        };
        t.spec().latency.as_secs_f64() + bytes as f64 * n as f64 / t.spec().write_bw
    };
    let (nv_s, ss_s) = (score(nvme, readers), score(ssd, 0));
    if concurrency_aware && ss_s < nv_s {
        (TierKind::Ssd, ss_s)
    } else {
        (TierKind::Nvme, nv_s)
    }
}

fn main() {
    let bytes: u64 = 256 << 20; // 256 MiB checkpoint per node
    let nvme = StorageTier::memory(presets::nvme(u64::MAX / 2), TimeMode::Model);
    let ssd = StorageTier::memory(presets::ssd(u64::MAX / 2), TimeMode::Model);

    harness::section("E5: capture target + service time vs concurrent flush readers");
    println!(
        "{:>8} | {:>10} {:>12} | {:>10} {:>12} | {:>7}",
        "readers", "fastest", "service", "conc-aware", "service", "gain"
    );
    for readers in [0usize, 1, 2, 4, 8] {
        let (t1, s1) = capture_service(&nvme, &ssd, bytes, readers, false);
        let (t2, s2) = capture_service(&nvme, &ssd, bytes, readers, true);
        println!(
            "{:>8} | {:>10} {:>9.0} ms | {:>10} {:>9.0} ms | {:>6.2}x",
            readers,
            t1.name(),
            s1 * 1e3,
            t2.name(),
            s2 * 1e3,
            s1 / s2
        );
    }

    harness::section("E5b: live tiers — modeled put durations under held transfers");
    // Hold flush transfers on the NVMe pool for real and measure the
    // tier-model outputs the policy consumes.
    println!("{:>8} {:>14} {:>14}", "held", "nvme put", "ssd put");
    let payload = vec![0u8; 4 << 20];
    for held in [0usize, 2, 6] {
        // A held transfer = an in-flight flush readback.
        let _guards: Vec<_> = (0..held).map(|_| nvme.hold_transfer()).collect();
        let nv = nvme.put(&format!("k{held}"), &payload).unwrap();
        let ss = ssd.put(&format!("k{held}"), &payload).unwrap();
        println!(
            "{:>8} {:>14} {:>14}",
            held,
            harness::fmt_secs(nv.modeled.as_secs_f64()),
            harness::fmt_secs(ss.modeled.as_secs_f64())
        );
    }
    println!(
        "\npaper [4] shape: past ~4 concurrent flush readers the nominally\n\
         4x-slower SSD beats the contended NVMe for the blocking capture,\n\
         so fastest-first is suboptimal — ConcurrencyAware picks SSD."
    );
}
