//! E5 — heterogeneous tier selection under I/O concurrency (paper [4]:
//! "there are non-obvious producer-consumer patterns that form under I/O
//! concurrency, for which using the fastest storage may be suboptimal").
//!
//! Scenario: the async flush (consumer) reads the previous checkpoint back
//! from the NVMe tier while the application (producer) captures the next
//! checkpoint. FastestFirst always targets NVMe and collides with the
//! drain; ConcurrencyAware sees the active transfers and lands on the idle
//! SSD when the modeled service time is better.
//!
//! Shape to reproduce: under concurrency, concurrency-aware selection
//! yields lower capture service time than fastest-first, although SSD is
//! nominally 4x slower.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use veloc::storage::{
    presets, PlacementConfig, PlacementEngine, PlacementPolicy, StorageTier, TierKind,
    TimeMode,
};

/// Modeled capture service time for one checkpoint under `readers`
/// concurrent flush-readbacks on the NVMe tier.
fn capture_service(
    nvme: &StorageTier,
    ssd: &StorageTier,
    bytes: u64,
    readers: usize,
    concurrency_aware: bool,
) -> (TierKind, f64) {
    // Flush readers hold the NVMe bandwidth pool.
    let score = |t: &StorageTier, extra: usize| {
        let n = if t.spec().shared {
            t.active_transfers() + extra + 1
        } else {
            1
        };
        t.spec().latency.as_secs_f64() + bytes as f64 * n as f64 / t.spec().write_bw
    };
    let (nv_s, ss_s) = (score(nvme, readers), score(ssd, 0));
    if concurrency_aware && ss_s < nv_s {
        (TierKind::Ssd, ss_s)
    } else {
        (TierKind::Nvme, nv_s)
    }
}

fn main() {
    let bytes: u64 = 256 << 20; // 256 MiB checkpoint per node
    let nvme = StorageTier::memory(presets::nvme(u64::MAX / 2), TimeMode::Model);
    let ssd = StorageTier::memory(presets::ssd(u64::MAX / 2), TimeMode::Model);

    harness::section("E5: capture target + service time vs concurrent flush readers");
    println!(
        "{:>8} | {:>10} {:>12} | {:>10} {:>12} | {:>7}",
        "readers", "fastest", "service", "conc-aware", "service", "gain"
    );
    for readers in [0usize, 1, 2, 4, 8] {
        let (t1, s1) = capture_service(&nvme, &ssd, bytes, readers, false);
        let (t2, s2) = capture_service(&nvme, &ssd, bytes, readers, true);
        println!(
            "{:>8} | {:>10} {:>9.0} ms | {:>10} {:>9.0} ms | {:>6.2}x",
            readers,
            t1.name(),
            s1 * 1e3,
            t2.name(),
            s2 * 1e3,
            s1 / s2
        );
    }

    harness::section("E5b: live tiers — modeled put durations under held transfers");
    // Hold flush transfers on the NVMe pool for real and measure the
    // tier-model outputs the policy consumes.
    println!("{:>8} {:>14} {:>14}", "held", "nvme put", "ssd put");
    let payload = vec![0u8; 4 << 20];
    for held in [0usize, 2, 6] {
        // A held transfer = an in-flight flush readback.
        let _guards: Vec<_> = (0..held).map(|_| nvme.hold_transfer()).collect();
        let nv = nvme.put(&format!("k{held}"), &payload).unwrap();
        let ss = ssd.put(&format!("k{held}"), &payload).unwrap();
        println!(
            "{:>8} {:>14} {:>14}",
            held,
            harness::fmt_secs(nv.modeled.as_secs_f64()),
            harness::fmt_secs(ss.modeled.as_secs_f64())
        );
    }
    println!(
        "\npaper [4] shape: past ~4 concurrent flush readers the nominally\n\
         4x-slower SSD beats the contended NVMe for the blocking capture,\n\
         so fastest-first is suboptimal — ConcurrencyAware picks SSD."
    );

    placement_mode();
}

/// Fresh shared-tier pool: a 5 GB/s PFS (the static primary) and a
/// 20 GB/s burst buffer (the tier adaptive placement should discover).
fn placement_pool() -> Vec<Arc<StorageTier>> {
    vec![
        StorageTier::memory(presets::pfs(u64::MAX / 2, 5.0e9), TimeMode::Model),
        StorageTier::memory(presets::burst_buffer(u64::MAX / 2, 20.0e9), TimeMode::Model),
    ]
}

fn placement_engine(policy: PlacementPolicy) -> Arc<PlacementEngine> {
    PlacementEngine::new(
        placement_pool(),
        PlacementConfig {
            enabled: true,
            policy,
            ..Default::default()
        },
        None,
    )
    .expect("non-empty pool")
}

/// Modeled seconds to flush `flushes` objects of `bytes` through an
/// engine (sequential flush tail, model time mode).
fn modeled_flush_secs(engine: &PlacementEngine, bytes: usize, flushes: usize) -> f64 {
    let payload = Arc::new(vec![0u8; bytes]);
    (0..flushes)
        .map(|i| {
            let (_, stat) = engine
                .put(&format!("ckpt.v{i}"), &payload)
                .expect("flush must not fail");
            stat.modeled.as_secs_f64()
        })
        .sum()
}

/// E5c — adaptive placement vs static worst-tier routing, plus the
/// mid-run outage demonstration (ISSUE 4 acceptance: fastest-eligible
/// >= 1.5x over static routing pinned to the slow tier; an outage
/// degrades throughput instead of failing the checkpoint).
fn placement_mode() {
    harness::section("E5c: placement — fastest-eligible vs static worst-tier routing");
    let flushes = 8;
    println!(
        "{:>10} | {:>12} {:>12} | {:>6}",
        "size", "static", "fastest", "gain"
    );
    let mut gain_at_64m = 0.0;
    for mb in [1usize, 16, 64, 256] {
        let bytes = mb << 20;
        // Static routing with the slow tier configured primary — exactly
        // the hard-wired destination the paper argues against.
        let static_secs =
            modeled_flush_secs(&placement_engine(PlacementPolicy::Static), bytes, flushes);
        let fastest_secs = modeled_flush_secs(
            &placement_engine(PlacementPolicy::FastestEligible),
            bytes,
            flushes,
        );
        let gain = static_secs / fastest_secs;
        if mb == 64 {
            gain_at_64m = gain;
        }
        println!(
            "{:>7} MiB | {:>12} {:>12} | {:>5.2}x",
            mb,
            harness::fmt_secs(static_secs),
            harness::fmt_secs(fastest_secs),
            gain
        );
    }
    assert!(
        gain_at_64m >= 1.5,
        "fastest-eligible placement must beat static worst-tier routing \
         by >= 1.5x at 64 MiB (measured {gain_at_64m:.2}x)"
    );
    println!("asserted: fastest-eligible >= 1.5x over static worst-tier routing");

    harness::section("E5d: placement — mid-run tier outage degrades instead of failing");
    let engine = placement_engine(PlacementPolicy::FastestEligible);
    let bytes = 64 << 20;
    let payload = Arc::new(vec![0u8; bytes]);
    let mut before = 0.0f64;
    let mut after = 0.0f64;
    println!("{:>6} {:>14} {:>14}", "flush", "tier", "modeled");
    for i in 0..8 {
        if i == 4 {
            // The burst buffer drops off mid-run.
            engine
                .tier("burst-buffer")
                .expect("pool has a burst buffer")
                .set_down(true);
            println!("  -- burst-buffer outage --");
        }
        let (dest, stat) = engine
            .put(&format!("out.v{i}"), &payload)
            .expect("outage must fail over, not fail the checkpoint");
        if i < 4 {
            before += stat.modeled.as_secs_f64();
        } else {
            after += stat.modeled.as_secs_f64();
        }
        println!(
            "{:>6} {:>14} {:>14}",
            i,
            dest,
            harness::fmt_secs(stat.modeled.as_secs_f64())
        );
    }
    assert!(
        after > before,
        "post-outage flushes should be slower (PFS), not absent: \
         {before:.4}s -> {after:.4}s"
    );
    assert!(
        engine.failover_count() >= 1,
        "the outage must be served by failover"
    );
    println!(
        "outage absorbed: throughput degraded {:.2}x, zero failed checkpoints \
         ({} failovers)",
        after / before.max(1e-9),
        engine.failover_count()
    );
}
