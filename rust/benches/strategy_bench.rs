//! E2 — application overhead per checkpoint strategy (paper [4], Figs 5-7
//! class of result): synchronous direct-to-PFS vs blocking multi-level vs
//! asynchronous multi-level (VeloC).
//!
//! Shape to reproduce: sync-PFS >> blocking multi-level > async
//! multi-level; the async engine's application-visible cost approaches
//! the L1 capture alone.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Instant;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::app::IterativeApp;
use veloc::pipeline::EngineMode;
use veloc::storage::TimeMode;

/// Run the iterative app under a config; return (mean blocking s/ckpt,
/// app wall seconds for the fixed work).
fn run(cfg: VelocConfig, label: &str, mb: usize, iters: u64, every: u64) -> (f64, f64) {
    let rt = VelocRuntime::new(cfg).unwrap();
    let world = rt.topology().world_size();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let rt: Arc<VelocRuntime> = Arc::clone(&rt);
            std::thread::spawn(move || {
                let client = rt.client(rank);
                let mut app = IterativeApp::new(&client, "e2", 2, (mb << 20) / 2, 1.0, 3);
                let mut blocking = 0.0f64;
                let mut ckpts = 0u64;
                let t0 = Instant::now();
                while app.iteration < iters {
                    app.step();
                    if app.iteration % every == 0 {
                        let tc = Instant::now();
                        let v = app.checkpoint(&client).unwrap();
                        blocking += tc.elapsed().as_secs_f64();
                        ckpts += 1;
                        let _ = v;
                    }
                }
                (blocking / ckpts.max(1) as f64, t0.elapsed().as_secs_f64())
            })
        })
        .collect();
    let mut block = 0.0;
    let mut wall = 0.0f64;
    for h in handles {
        let (b, w) = h.join().unwrap();
        block += b / world as f64;
        wall = wall.max(w);
    }
    rt.drain();
    println!("  [{label}] measured");
    (block, wall)
}

fn main() {
    let mb = 4usize;
    let iters = harness::scaled(12) as u64;
    let every = 4u64;
    // Emulate modeled I/O in real time (scale 1.0) with a deliberately
    // scarce PFS (0.25 GB/s aggregate for 4 writers), the regime the
    // paper targets: PFS writes dominate everything else. The greedy gate
    // keeps scheduling effects out of this experiment (that is E4).
    let emulate = TimeMode::Emulate { scale: 1.0 };

    let base = || {
        let mut cfg = VelocConfig::default().with_nodes(4, 1);
        cfg.fabric.time_mode = emulate;
        cfg.fabric.pfs_bw = 0.25e9;
        cfg.scheduler = veloc::scheduler::SchedulerPolicy::Greedy;
        cfg.stack.erasure_group = 4;
        cfg
    };

    harness::section("E2: app-visible cost per strategy (4 ranks, 4 MiB/rank)");
    let mut rows = Vec::new();

    // (a) sync direct-to-PFS: no local levels at all.
    let mut cfg = base();
    cfg.engine_mode = EngineMode::Sync;
    cfg.stack.with_partner = false;
    cfg.stack.erasure_group = 0;
    cfg.stack.with_checksum = false;
    // local module still captures to DRAM; model "direct PFS" by making
    // the flush the only extra level and counting its sync cost.
    let (b, w) = run(cfg, "sync direct PFS", mb, iters, every);
    rows.push(("sync direct-to-PFS", b, w));

    // (b) blocking multi-level: all levels, sync engine.
    let mut cfg = base();
    cfg.engine_mode = EngineMode::Sync;
    let (b, w) = run(cfg, "sync multi-level", mb, iters, every);
    rows.push(("blocking multi-level", b, w));

    // (c) VeloC: async multi-level.
    let cfg = base();
    let (b, w) = run(cfg, "async multi-level", mb, iters, every);
    rows.push(("async multi-level (VeloC)", b, w));

    println!(
        "\n{:<28} {:>16} {:>14}",
        "strategy", "blocking/ckpt", "app wall"
    );
    for (name, b, w) in &rows {
        println!("{:<28} {:>13.2} ms {:>12.2} s", name, b * 1e3, w);
    }
    let sync_pfs = rows[0].1;
    let async_ml = rows[2].1;
    println!(
        "\nasync multi-level blocks {:.1}x less per checkpoint than sync\n\
         direct-to-PFS (paper: async VeloC makes checkpointing overhead\n\
         'negligible' next to direct PFS writes).",
        sync_pfs / async_ml.max(1e-9)
    );
}
