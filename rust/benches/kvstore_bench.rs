//! E11 — KV object repository vs PFS (paper §4 DAOS module).
//!
//! Two comparisons:
//! (a) fine-grained layout (one object per region, what the lineage /
//!     data-states access pattern wants): per-op latency dominates for
//!     many small regions -> the DAOS-like KV store wins;
//! (b) monolithic layout (one blob per checkpoint, the classic PFS flush):
//!     bandwidth dominates -> the repositories converge.
//!
//! Both repositories get the same aggregate bandwidth; the experimental
//! variable is per-op latency (DAOS-like 30 µs vs Lustre-like 2 ms).

#[path = "harness.rs"]
mod harness;

use std::time::Duration;
use veloc::storage::{presets, StorageTier, TimeMode};

/// Total modeled time to store a checkpoint as `regions` objects of
/// `bytes` each on the given tier.
fn store(tier: &StorageTier, regions: usize, bytes: usize, tag: &str) -> f64 {
    let payload = vec![0xA5u8; bytes];
    let mut total = 0.0;
    for i in 0..regions {
        let stat = tier.put(&format!("{tag}.obj{i}"), &payload).unwrap();
        total += stat.modeled.as_secs_f64();
    }
    total
}

fn main() {
    let pfs = StorageTier::memory(presets::pfs(u64::MAX / 2, 5e9), TimeMode::Model);
    let kv = StorageTier::memory(presets::kv_store(u64::MAX / 2, 5e9), TimeMode::Model);

    harness::section("E11a: fine-grained layout (object per region, modeled)");
    println!(
        "{:<26} {:>12} {:>12} {:>8}",
        "workload", "pfs", "kv store", "kv gain"
    );
    for (label, regions, bytes) in [
        ("1 x 16 MiB blob", 1usize, 16 << 20),
        ("16 x 1 MiB tensors", 16, 1 << 20),
        ("128 x 64 KiB tensors", 128, 64 << 10),
        ("512 x 4 KiB objects", 512, 4 << 10),
    ] {
        let p = store(&pfs, regions, bytes, &format!("p{regions}"));
        let k = store(&kv, regions, bytes, &format!("k{regions}"));
        println!(
            "{:<26} {:>12} {:>12} {:>7.2}x",
            label,
            harness::fmt_secs(p),
            harness::fmt_secs(k),
            p / k
        );
    }

    harness::section("E11b: restore a 64 KiB subset out of a 64 MiB checkpoint");
    // Fine-grained get: KV fetches one object; the monolithic PFS blob
    // forces reading the whole container.
    let region = 64 << 10;
    let regions = 1024; // 64 MiB total
    store(&kv, regions, region, "sub");
    let blob = vec![1u8; regions * region];
    pfs.put("blob", &blob).unwrap();
    let (_, kv_stat) = kv.get("sub.obj17").unwrap();
    let (_, pfs_stat) = pfs.get("blob").unwrap();
    println!(
        "kv single-object get : {}",
        harness::fmt_secs(kv_stat.modeled.as_secs_f64())
    );
    println!(
        "pfs whole-blob read  : {}",
        harness::fmt_secs(pfs_stat.modeled.as_secs_f64())
    );
    println!(
        "-> {:.0}x cheaper to revisit one tensor from the KV lineage\n\
        (the data-states / introspection use case of paper §1 and [2])",
        pfs_stat.modeled.as_secs_f64() / kv_stat.modeled.as_secs_f64()
    );

    // Keep the latency knob visible in the output.
    println!(
        "\nlatency model: pfs {:?}/op vs kv {:?}/op at equal 5 GB/s aggregate",
        Duration::from_millis(2),
        Duration::from_micros(30)
    );
}
