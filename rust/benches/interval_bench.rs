//! E6 — checkpoint-interval optimization quality (paper §2 + ref [1]):
//! Young vs Daly vs random forest vs the runtime-trained NN, measured as
//! efficiency loss against the DES optimum on held-out scenarios.
//!
//! Shape to reproduce: the closed forms drift away from the multi-level
//! DES optimum; the learned models track it, with the NN at least matching
//! the random forest (the paper's [1] finding).

#[path = "harness.rs"]
mod harness;

use veloc::interval::{self, dataset, interval_of, NnOptimizer, RandomForest};
use veloc::runtime::{default_artifacts_dir, PjrtEngine};

fn main() {
    let n_train = harness::scaled(100);
    let n_test = harness::scaled(24);
    let grid = 10;
    let trials = 4;

    println!("generating {} DES-labelled scenarios...", n_train + n_test);
    let data = dataset::generate(n_train + n_test, grid, trials, 31);
    let (train, test) =
        dataset::split(data, n_test as f64 / (n_train + n_test) as f64);

    let xs: Vec<[f32; 10]> = train.iter().map(|e| e.features).collect();
    let ys: Vec<f32> = train.iter().map(|e| e.label).collect();
    let rf = RandomForest::fit(&xs, &ys, 40, 8, 13);

    let nn = match PjrtEngine::load(&default_artifacts_dir()) {
        Ok(engine) => {
            let mut nn = NnOptimizer::new(engine).unwrap();
            let hist = nn.fit(&train, harness::scaled(200), 0.02, 7).unwrap();
            println!(
                "NN: loss {:.4} -> {:.4}",
                hist.first().unwrap(),
                hist.last().unwrap()
            );
            Some(nn)
        }
        Err(e) => {
            println!("NN skipped (artifacts unavailable: {e})");
            None
        }
    };

    harness::section("E6: policy quality on held-out scenarios");
    println!(
        "{:<10} {:>14} {:>20}",
        "policy", "MAE(log10 W)", "efficiency loss"
    );
    let eval = |pred: &dyn Fn(&dataset::Example) -> f64| -> (f64, f64) {
        let mut mae = 0.0;
        let mut gap = 0.0;
        for e in &test {
            let w = pred(e).max(1.0);
            mae += (w.log10() - e.label as f64).abs();
            let eff = interval::mean_efficiency(&e.scenario, w, trials, 99);
            gap += (e.best_eff - eff).max(0.0);
        }
        (mae / test.len() as f64, gap / test.len() as f64)
    };

    let (mae, gap) =
        eval(&|e| interval::young(e.scenario.l1_cost, e.scenario.mtbf));
    println!("{:<10} {:>14.3} {:>19.2}%", "young", mae, gap * 100.0);
    let (mae, gap) =
        eval(&|e| interval::daly(e.scenario.l1_cost, e.scenario.mtbf));
    println!("{:<10} {:>14.3} {:>19.2}%", "daly", mae, gap * 100.0);
    let (mae_rf, gap_rf) = eval(&|e| interval_of(rf.predict(&e.features)));
    println!("{:<10} {:>14.3} {:>19.2}%", "forest", mae_rf, gap_rf * 100.0);
    if let Some(nn) = &nn {
        let (mae_nn, gap_nn) =
            eval(&|e| nn.predict_interval(&e.features).unwrap_or(1.0));
        println!("{:<10} {:>14.3} {:>19.2}%", "nn", mae_nn, gap_nn * 100.0);
        println!(
            "\nNN vs forest efficiency loss: {:.2}% vs {:.2}% -> {}",
            gap_nn * 100.0,
            gap_rf * 100.0,
            if gap_nn <= gap_rf * 1.2 {
                "NN competitive/better (paper [1] shape)"
            } else {
                "forest ahead on this draw"
            }
        );
    }
}
