//! E1 — aggregate local (L1) checkpoint throughput vs rank count, plus the
//! Summit-scale extrapolation of the paper's §4 headline (224 TB/s).
//!
//! Shape to reproduce: L1 scales linearly with ranks (dedicated DRAM
//! staging), while direct-PFS throughput saturates at the shared aggregate
//! bandwidth — the gap that motivates multi-level checkpointing.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::pipeline::CkptStatus;
use veloc::storage::contention::fair_share_secs;
use std::time::Duration;

fn world_checkpoint(rt: &Arc<VelocRuntime>, version: u64, bytes: usize) -> f64 {
    let world = rt.topology().world_size();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let rt = Arc::clone(rt);
            std::thread::spawn(move || {
                let client = rt.client(rank);
                client.mem_protect(0, vec![rank as u8; bytes]);
                let t0 = std::time::Instant::now();
                client.checkpoint("e1", version).unwrap();
                let blocking = t0.elapsed().as_secs_f64();
                let st = client.checkpoint_wait("e1", version).unwrap();
                assert!(matches!(st, CkptStatus::Done(_)));
                blocking
            })
        })
        .collect();
    let mut max_block = 0.0f64;
    for h in handles {
        max_block = max_block.max(h.join().unwrap());
    }
    rt.drain();
    max_block
}

fn main() {
    let mb = 4usize;
    let bytes = mb << 20;

    harness::section("E1a: live runtime — blocking L1 capture vs ranks");
    println!(
        "{:>6} {:>14} {:>20}",
        "ranks", "max block", "aggregate (wall)"
    );
    for (nodes, rpn) in [(2usize, 1usize), (4, 1), (4, 2), (8, 2)] {
        let mut cfg = VelocConfig::default().with_nodes(nodes, rpn);
        cfg.stack.erasure_group = 0; // isolate L1+partner+flush
        cfg.fabric.dram_capacity = (bytes as u64) * 8;
        let rt = VelocRuntime::new(cfg).unwrap();
        let world = nodes * rpn;
        // warmup + 3 measured collective checkpoints
        world_checkpoint(&rt, 1, bytes);
        let mut blocks = veloc::util::stats::Samples::new();
        for v in 2..5u64 {
            blocks.push(world_checkpoint(&rt, v, bytes));
        }
        let agg_gbps = (world * bytes) as f64 / blocks.mean() / 1e9;
        println!(
            "{:>6} {:>11.2} ms {:>17.2} GB/s",
            world,
            blocks.mean() * 1e3,
            agg_gbps
        );
    }

    harness::section("E1b: model — L1 (linear) vs direct PFS (saturating)");
    println!(
        "{:>8} {:>16} {:>16} {:>8}",
        "ranks", "L1 aggregate", "PFS aggregate", "ratio"
    );
    let dram_bw = 10.0e9; // presets::dram per-rank
    let pfs_bw = 5.0e9; // FabricConfig::default aggregate
    for ranks in [8usize, 64, 512, 4096, 27648] {
        let l1 = ranks as f64 * dram_bw;
        let pfs_t = fair_share_secs(bytes as u64, pfs_bw, ranks, Duration::from_millis(2));
        let pfs = ranks as f64 * bytes as f64 / (pfs_t * ranks as f64).max(1e-12);
        println!(
            "{:>8} {:>13.1} TB/s {:>13.4} TB/s {:>7.0}x",
            ranks,
            l1 / 1e12,
            pfs / 1e12,
            l1 / pfs
        );
    }
    println!(
        "\nSummit headline: 27648 ranks x ~8-10 GB/s DRAM staging\n\
         => 221-276 TB/s aggregate blocking L1 — the paper's 224 TB/s\n\
         sits inside this band; PFS saturates at its aggregate bandwidth\n\
         regardless of rank count (motivating multi-level checkpointing)."
    );
}
