//! E1 — aggregate local (L1) checkpoint throughput vs rank count, plus the
//! Summit-scale extrapolation of the paper's §4 headline (224 TB/s).
//!
//! Shape to reproduce: L1 scales linearly with ranks (dedicated DRAM
//! staging), while direct-PFS throughput saturates at the shared aggregate
//! bandwidth — the gap that motivates multi-level checkpointing.
//!
//! E1c gates the CRC32 kernel: slice-by-16 [`crc32_wide`] must beat the
//! byte-serial table baseline by >= 3x. E1d gates the observability
//! plane: the same collective wave with span tracing enabled — and then
//! with the crash-durable flight recorder mirroring every closed span to
//! disk — must each cost <= 5% over the untraced baseline. The run emits
//! `BENCH_throughput.json` when `VELOC_BENCH_JSON_DIR` is set.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::pipeline::CkptStatus;
use veloc::storage::contention::fair_share_secs;
use veloc::util::kernels::{crc32_scalar, crc32_wide};

fn world_checkpoint(rt: &Arc<VelocRuntime>, version: u64, bytes: usize) -> f64 {
    let world = rt.topology().world_size();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let rt = Arc::clone(rt);
            std::thread::spawn(move || {
                let client = rt.client(rank);
                client.mem_protect(0, vec![rank as u8; bytes]);
                let t0 = std::time::Instant::now();
                client.checkpoint("e1", version).unwrap();
                let blocking = t0.elapsed().as_secs_f64();
                let st = client.checkpoint_wait("e1", version).unwrap();
                assert!(matches!(st, CkptStatus::Done(_)));
                blocking
            })
        })
        .collect();
    let mut max_block = 0.0f64;
    for h in handles {
        max_block = max_block.max(h.join().unwrap());
    }
    rt.drain();
    max_block
}

fn main() {
    let mb = 4usize;
    let bytes = mb << 20;
    let mut report = harness::Report::new("throughput");

    harness::section("E1a: live runtime — blocking L1 capture vs ranks");
    println!(
        "{:>6} {:>14} {:>20}",
        "ranks", "max block", "aggregate (wall)"
    );
    for (nodes, rpn) in [(2usize, 1usize), (4, 1), (4, 2), (8, 2)] {
        let mut cfg = VelocConfig::default().with_nodes(nodes, rpn);
        cfg.stack.erasure_group = 0; // isolate L1+partner+flush
        cfg.fabric.dram_capacity = (bytes as u64) * 8;
        let rt = VelocRuntime::new(cfg).unwrap();
        let world = nodes * rpn;
        // warmup + 3 measured collective checkpoints
        world_checkpoint(&rt, 1, bytes);
        let mut blocks = veloc::util::stats::Samples::new();
        for v in 2..5u64 {
            blocks.push(world_checkpoint(&rt, v, bytes));
        }
        let agg_gbps = (world * bytes) as f64 / blocks.mean() / 1e9;
        report.scalar(&format!("l1_agg_gbps_{world}"), agg_gbps);
        println!(
            "{:>6} {:>11.2} ms {:>17.2} GB/s",
            world,
            blocks.mean() * 1e3,
            agg_gbps
        );
    }

    harness::section("E1b: model — L1 (linear) vs direct PFS (saturating)");
    println!(
        "{:>8} {:>16} {:>16} {:>8}",
        "ranks", "L1 aggregate", "PFS aggregate", "ratio"
    );
    let dram_bw = 10.0e9; // presets::dram per-rank
    let pfs_bw = 5.0e9; // FabricConfig::default aggregate
    for ranks in [8usize, 64, 512, 4096, 27648] {
        let l1 = ranks as f64 * dram_bw;
        let pfs_t = fair_share_secs(bytes as u64, pfs_bw, ranks, Duration::from_millis(2));
        let pfs = ranks as f64 * bytes as f64 / (pfs_t * ranks as f64).max(1e-12);
        println!(
            "{:>8} {:>13.1} TB/s {:>13.4} TB/s {:>7.0}x",
            ranks,
            l1 / 1e12,
            pfs / 1e12,
            l1 / pfs
        );
    }
    println!(
        "\nSummit headline: 27648 ranks x ~8-10 GB/s DRAM staging\n\
         => 221-276 TB/s aggregate blocking L1 — the paper's 224 TB/s\n\
         sits inside this band; PFS saturates at its aggregate bandwidth\n\
         regardless of rank count (motivating multi-level checkpointing)."
    );

    harness::section("E1c: CRC32 kernel — slice-by-16 vs byte-serial table");
    harness::table_header();
    let crc_len = 16usize << 20;
    let buf: Vec<u8> = (0..crc_len)
        .map(|i| ((i as u32).wrapping_mul(2_654_435_761) >> 13) as u8)
        .collect();
    assert_eq!(crc32_wide(&buf), crc32_scalar(&buf), "kernels must agree");
    let reps = harness::scaled(16);
    let r_scalar = harness::bench_bytes("crc32 scalar (byte table)", crc_len as u64, 1, reps, || {
        std::hint::black_box(crc32_scalar(std::hint::black_box(&buf)));
    });
    harness::row(&r_scalar);
    let r_wide = harness::bench_bytes("crc32 wide (slice-by-16)", crc_len as u64, 1, reps, || {
        std::hint::black_box(crc32_wide(std::hint::black_box(&buf)));
    });
    harness::row(&r_wide);
    let speedup = r_scalar.samples.p50() / r_wide.samples.p50().max(1e-12);
    println!("crc32 kernel speedup: {speedup:.1}x (gate: >= 3x)");
    report.add(&r_scalar);
    report.add(&r_wide);
    report.scalar("crc32_speedup", speedup);
    assert!(
        speedup >= 3.0,
        "acceptance: crc32_wide must be >= 3x the scalar baseline, got {speedup:.2}x"
    );

    harness::section("E1d: observability overhead — untraced vs traced vs traced+flight");
    let wave_bytes = 1usize << 20;
    let pid = std::process::id();
    let flight_dir = std::env::temp_dir().join(format!("veloc-bench-flight-{pid}"));
    let _ = std::fs::remove_dir_all(&flight_dir);
    let mut wave_secs = [
        veloc::util::stats::Samples::new(), // [0] tracing off
        veloc::util::stats::Samples::new(), // [1] tracing on
        veloc::util::stats::Samples::new(), // [2] tracing + flight recorder
    ];
    // Interleave the modes across reps so machine drift cancels out
    // of the comparison instead of landing on one side.
    for _rep in 0..harness::scaled(6).max(2) {
        for (slot, trace, flight) in [(0usize, false, false), (1, true, false), (2, true, true)] {
            let mut cfg = VelocConfig::default().with_nodes(2, 2);
            cfg.stack.erasure_group = 0;
            cfg.obs.trace = trace;
            if flight {
                cfg.obs.flight_dir = Some(flight_dir.clone());
            }
            cfg.fabric.dram_capacity = (wave_bytes as u64) * 8;
            let rt = VelocRuntime::new(cfg).unwrap();
            world_checkpoint(&rt, 1, wave_bytes); // warmup
            let t0 = std::time::Instant::now();
            for v in 2..5u64 {
                world_checkpoint(&rt, v, wave_bytes);
            }
            wave_secs[slot].push(t0.elapsed().as_secs_f64());
            if trace {
                rt.tracer()
                    .validate()
                    .expect("traced bench waves must yield a well-formed timeline");
            }
        }
    }
    // The flight dump itself must read back clean before it is deleted.
    {
        let scans = veloc::obs::flight::read_dir(&flight_dir)
            .expect("flight dump readable after bench waves");
        veloc::obs::flight::verify(&scans)
            .unwrap_or_else(|e| panic!("bench flight dump failed verify: {e}"));
    }
    let _ = std::fs::remove_dir_all(&flight_dir);
    let (off_p50, on_p50, fl_p50) = (wave_secs[0].p50(), wave_secs[1].p50(), wave_secs[2].p50());
    let ratio = on_p50 / off_p50.max(1e-12);
    let fl_ratio = fl_p50 / off_p50.max(1e-12);
    println!(
        "untraced p50 {:.2} ms | traced p50 {:.2} ms ({:+.2}%) | traced+flight p50 {:.2} ms \
         ({:+.2}%) (gate: <= 5% each)",
        off_p50 * 1e3,
        on_p50 * 1e3,
        (ratio - 1.0) * 100.0,
        fl_p50 * 1e3,
        (fl_ratio - 1.0) * 100.0
    );
    report.scalar("wave_untraced_p50_ms", off_p50 * 1e3);
    report.scalar("wave_traced_p50_ms", on_p50 * 1e3);
    report.scalar("trace_overhead_ratio", ratio);
    report.scalar("wave_flight_p50_ms", fl_p50 * 1e3);
    report.scalar("flight_overhead_ratio", fl_ratio);
    // Sub-millisecond absolute slack absorbs timer jitter on waves this
    // short; anything past it must stay inside the 5% budget.
    assert!(
        ratio <= 1.05 || on_p50 - off_p50 <= 1e-3,
        "acceptance: span tracing must cost <= 5% of the wave, got {:+.2}% \
         ({:.2} ms -> {:.2} ms)",
        (ratio - 1.0) * 100.0,
        off_p50 * 1e3,
        on_p50 * 1e3
    );
    assert!(
        fl_ratio <= 1.05 || fl_p50 - off_p50 <= 1e-3,
        "acceptance: the flight recorder must cost <= 5% of the wave, got {:+.2}% \
         ({:.2} ms -> {:.2} ms)",
        (fl_ratio - 1.0) * 100.0,
        off_p50 * 1e3,
        fl_p50 * 1e3
    );
    report.write();
}
