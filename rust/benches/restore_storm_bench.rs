//! Restart-storm bench — the restore-side serving plane under fire.
//!
//! Two shapes to reproduce:
//! - **Storm collapse**: 32 clients cold-restoring one rank's checkpoint
//!   off the PFS must consume ≤ 1/8 the tier reads of the cache-disabled
//!   path and finish ≥ 2x faster (read-through cache + single-flight).
//! - **Depth, not length**: restoring the tip of a 16-version delta chain
//!   through a fresh incarnation (empty chunk store, so every hop is a
//!   real PFS read) gets faster as `prefetch_depth` grows — latency
//!   scales with the configured depth, not the chain length.
//!
//! Tier I/O runs under `TimeMode::Emulate`, so the modeled PFS round-trip
//! (~2 ms) is charged as wall-clock sleep and the ratios above are
//! measured, not inferred.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Instant;
use veloc::api::{SimHooks, VelocConfig, VelocRuntime};
use veloc::app::IterativeApp;
use veloc::cluster::FailureScope;
use veloc::storage::{StorageFabric, TimeMode};
use veloc::util::stats::Samples;

/// Cold clients hammering one container — the paper's restart-storm shape.
const STORM: usize = 32;

fn storm_config(cache_on: bool) -> VelocConfig {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    // No partner copy: once node 0's local tiers are wiped, the PFS is
    // the only surviving source — the storm's worst case.
    cfg.stack.with_partner = false;
    cfg.stack.keep_versions = 32;
    cfg.restore.enabled = cache_on;
    // Charge modeled tier time as wall-clock sleep so the speedup is a
    // measured duration, not a counter-derived estimate.
    cfg.fabric.time_mode = TimeMode::Emulate { scale: 1.0 };
    cfg
}

/// Build a runtime on an externally held fabric (the daemon-restart
/// idiom: storage outlives the serving incarnation).
fn build(cfg: &VelocConfig) -> (Arc<StorageFabric>, Arc<VelocRuntime>) {
    let fabric = Arc::new(StorageFabric::build(&cfg.fabric).unwrap());
    let hooks = SimHooks {
        fabric: Some(Arc::clone(&fabric)),
        ..SimHooks::default()
    };
    let rt = VelocRuntime::new_with_hooks(cfg.clone(), hooks).unwrap();
    (fabric, rt)
}

/// One full storm: `STORM` fresh clients cold-restore rank 0's only
/// checkpoint, each verified bit-for-bit. Returns (elapsed, pfs reads).
fn run_storm(
    rt: &Arc<VelocRuntime>,
    fabric: &Arc<StorageFabric>,
    version: u64,
    shadow: &[Vec<u8>],
) -> (std::time::Duration, u64) {
    let reads0 = fabric.pfs().get_count();
    let t0 = Instant::now();
    for _ in 0..STORM {
        let client = rt.client(0);
        let app = IterativeApp::new(&client, "storm", 1, 256 << 10, 0.0, 11);
        let info = client
            .restart_version("storm", version)
            .unwrap()
            .expect("storm restore must be served");
        assert_eq!(info.version, version);
        assert!(app.diff_snapshot(shadow).is_empty(), "restore not bit-for-bit");
    }
    (t0.elapsed(), fabric.pfs().get_count() - reads0)
}

fn main() {
    let mut report = harness::Report::new("restore_storm");
    let reps = harness::scaled(4);

    harness::section("restart storm: 32 cold clients, one container, PFS-only");
    harness::table_header();
    let mut means = [0.0f64; 2];
    let mut reads = [0u64; 2];
    for (slot, cache_on) in [(0usize, true), (1usize, false)] {
        let cfg = storm_config(cache_on);
        let (fabric, rt) = build(&cfg);
        let client = rt.client(0);
        let mut app = IterativeApp::new(&client, "storm", 1, 256 << 10, 0.0, 11);
        app.step();
        let version = app.checkpoint(&client).unwrap();
        client.checkpoint_wait_done("storm", version).unwrap();
        rt.drain();
        let shadow = app.snapshot();
        // Wipe node 0's local copies: every restore below is a cold read
        // of the surviving PFS object.
        rt.inject_failure(&FailureScope::Node(0));
        rt.revive_all();

        let mut samples = Samples::new();
        for _ in 0..reps {
            // Each rep is a fresh storm: the serving cache starts cold.
            if let Some(eng) = rt.restore_engine() {
                eng.invalidate_all();
            }
            let (elapsed, pfs_reads) = run_storm(&rt, &fabric, version, &shadow);
            samples.push_duration(elapsed);
            reads[slot] += pfs_reads;
        }
        let label = if cache_on {
            format!("storm-{STORM} cache+singleflight")
        } else {
            format!("storm-{STORM} cache disabled")
        };
        let r = harness::BenchResult {
            label,
            samples,
            bytes_per_iter: (STORM as u64) * (256 << 10),
        };
        harness::row(&r);
        means[slot] = r.mean();
        report.add(&r);
    }
    println!(
        "pfs reads: {} (cached) vs {} (direct) over {reps} storm(s)",
        reads[0], reads[1]
    );
    let read_ratio = reads[1] as f64 / reads[0].max(1) as f64;
    let speedup = means[1] / means[0];
    println!("tier-read ratio {read_ratio:.1}x, storm speedup {speedup:.1}x");
    assert!(
        reads[0] * 8 <= reads[1],
        "cache+singleflight must collapse tier reads to <= 1/8 of direct \
         ({} vs {})",
        reads[0],
        reads[1]
    );
    assert!(
        speedup >= 2.0,
        "cached storm must be >= 2x faster (got {speedup:.2}x)"
    );
    report.scalar("storm_clients", STORM as f64);
    report.scalar("storm_tier_read_ratio", read_ratio);
    report.scalar("storm_speedup", speedup);

    harness::section("delta-chain restore: prefetch depth sweep (chain = 16)");
    let mut cfg = storm_config(true);
    cfg.delta.enabled = true;
    cfg.delta.min_chunk = 64;
    cfg.delta.avg_chunk = 256;
    cfg.delta.max_chunk = 1024;
    cfg.delta.max_chain = 16;
    let (fabric, writer) = build(&cfg);
    let client = writer.client(0);
    let mut app = IterativeApp::new(&client, "chain", 2, 8 << 10, 0.0, 23);
    let mut tip = 0;
    for _ in 0..16 {
        app.step();
        tip = app.checkpoint(&client).unwrap();
        client.checkpoint_wait_done("chain", tip).unwrap();
    }
    writer.drain();
    let shadow = app.snapshot();
    // Wipe the writer node: chain hops must come off the PFS, where each
    // fetch costs a full emulated round-trip.
    writer.inject_failure(&FailureScope::Node(0));
    writer.revive_all();

    harness::table_header();
    let sweep_reps = harness::scaled(3);
    let mut sweep_means: Vec<f64> = Vec::new();
    for depth in [1usize, 2, 4, 8] {
        let mut dcfg = cfg.clone();
        dcfg.restore.prefetch_depth = depth;
        let mut samples = Samples::new();
        for _ in 0..sweep_reps {
            // A fresh incarnation per rep: empty chunk store and cold
            // cache, exactly like a restarted daemon serving the storm.
            let hooks = SimHooks {
                fabric: Some(Arc::clone(&fabric)),
                ..SimHooks::default()
            };
            let rt = VelocRuntime::new_with_hooks(dcfg.clone(), hooks).unwrap();
            let c = rt.client(0);
            let app2 = IterativeApp::new(&c, "chain", 2, 8 << 10, 0.0, 23);
            let t0 = Instant::now();
            let info = c
                .restart_version("chain", tip)
                .unwrap()
                .expect("chain restore must be served");
            samples.push_duration(t0.elapsed());
            assert_eq!(info.version, tip);
            assert!(app2.diff_snapshot(&shadow).is_empty(), "chain restore not bit-for-bit");
            assert!(
                rt.metrics().counter("restore.plan.hops") >= 8,
                "tip restore must actually walk the chain"
            );
        }
        let r = harness::BenchResult {
            label: format!("chain-16 prefetch depth {depth}"),
            samples,
            bytes_per_iter: 0,
        };
        harness::row(&r);
        sweep_means.push(r.mean());
        report.add(&r);
    }
    let scaling = sweep_means[0] / sweep_means[sweep_means.len() - 1];
    println!("depth-1 / depth-8 latency ratio: {scaling:.1}x");
    assert!(
        scaling >= 1.5,
        "chain latency must scale with prefetch depth, not chain length \
         (depth-1/depth-8 = {scaling:.2}x)"
    );
    report.scalar("prefetch_scaling_d1_over_d8", scaling);
    report.write();
}
