//! E8 / F1 — pipeline modularity: per-module cost breakdown, dispatch
//! overhead of the engine itself, and the cost of enabling the custom
//! modules (compression, checksum) the paper lists as pipeline extensions.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::pipeline::{CkptContext, CkptStatus};
use veloc::util::bytes::Checkpoint;
use veloc::util::rng::Rng;
use veloc::util::stats::Samples;

fn ctx(bytes: usize, version: u64, rng: &mut Rng) -> CkptContext {
    let mut data = vec![0u8; bytes];
    rng.fill_bytes(&mut data);
    let mut c = Checkpoint::new("e8", 0, version);
    c.push_region(0, data);
    CkptContext::new("e8", 0, 0, version, c)
}

fn main() {
    let bytes = 1 << 20;
    let mut rng = Rng::new(4);

    // --- per-module breakdown (sync, driven module by module) ----------
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.stack.erasure_group = 4;
    cfg.stack.with_compression = true;
    let rt = VelocRuntime::new(cfg).unwrap();
    let engine = rt.engine(0);

    harness::section("E8a: per-module cost (1 MiB checkpoint, sync drive)");
    println!("{:<12} {:>6} {:>12} {:>12}", "module", "prio", "mean", "p95");
    let reps = harness::scaled(20);
    let mut version = 0;
    // Warm the group: erasure needs all members' local copies; drive the
    // other ranks' local modules directly.
    for m in engine.modules() {
        let mut s = Samples::new();
        for _ in 0..reps {
            version += 1;
            // Provide group members' local copies so erasure can run.
            for peer in 1..4 {
                let mut pc = ctx(bytes, version, &mut rng);
                pc.rank = peer;
                pc.node = peer;
                rt.engine(peer)
                    .module_named("local")
                    .unwrap()
                    .process(&mut pc)
                    .unwrap();
            }
            let mut c = ctx(bytes, version, &mut rng);
            // Prior stages must have run for later stages to make sense.
            for prior in engine.modules() {
                if prior.priority() >= m.priority() {
                    break;
                }
                prior.process(&mut c).unwrap();
            }
            let (_, d) = veloc::util::stats::time_it(|| {
                m.process(&mut c).unwrap();
            });
            s.push_duration(d);
        }
        println!(
            "{:<12} {:>6} {:>12} {:>12}",
            m.name(),
            m.priority(),
            harness::fmt_secs(s.mean()),
            harness::fmt_secs(s.p95())
        );
    }

    // --- engine dispatch overhead ---------------------------------------
    harness::section("E8b: engine dispatch overhead (empty-ish command)");
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    cfg.stack.with_transfer = false;
    cfg.stack.with_partner = false;
    cfg.stack.with_checksum = false;
    let rt2 = VelocRuntime::new(cfg).unwrap();
    let client = rt2.client(0);
    client.mem_protect(0, vec![0u8; 64]);
    let r = harness::bench("local-only checkpoint", 10, harness::scaled(300), || {
        version += 1;
        client.checkpoint("tiny", version).unwrap();
        let st = client.checkpoint_wait("tiny", version).unwrap();
        assert!(matches!(st, CkptStatus::Done(_)));
    });
    harness::table_header();
    harness::row(&r);

    // --- toggling custom modules -----------------------------------------
    harness::section("E8c: end-to-end cost with custom modules toggled");
    println!("{:<30} {:>12}", "stack", "mean/ckpt");
    for (label, compression, checksum) in [
        ("base (no checksum/compress)", false, false),
        ("+ checksum", false, true),
        ("+ compression", true, false),
        ("+ both", true, true),
    ] {
        let mut cfg = VelocConfig::default().with_nodes(4, 1);
        cfg.stack.erasure_group = 0;
        cfg.stack.with_compression = compression;
        cfg.stack.with_checksum = checksum;
        let rt3 = VelocRuntime::new(cfg).unwrap();
        let client = rt3.client(0);
        // Compressible payload so the compression stage has real work.
        client.mem_protect(0, vec![7u8; bytes]);
        let mut v = 0u64;
        let mut s = Samples::new();
        for _ in 0..harness::scaled(30) {
            v += 1;
            let (_, d) = veloc::util::stats::time_it(|| {
                client.checkpoint("t", v).unwrap();
                client.checkpoint_wait_done("t", v).unwrap();
            });
            s.push_duration(d);
        }
        let rt3: Arc<VelocRuntime> = rt3;
        rt3.drain();
        println!("{:<30} {:>12}", label, harness::fmt_secs(s.mean()));
    }
}
