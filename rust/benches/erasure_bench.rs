//! E10 — erasure-encode backend ablation (DESIGN.md): XOR parity fold via
//! the Pallas kernel through PJRT vs the native u64-wide fold vs the naive
//! scalar loop, across payload sizes.
//!
//! Also reports the modeled TPU picture for the kernel (DESIGN.md
//! §Hardware-Adaptation): VMEM bytes per grid step and the arithmetic
//! intensity, since interpret-mode wallclock is a CPU-numpy number, not a
//! TPU proxy.

#[path = "harness.rs"]
mod harness;

use veloc::modules::{xor_fold, XorBackend};
use veloc::runtime::{default_artifacts_dir, PjrtEngine};
use veloc::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(10);
    let k = 4usize;

    let kernel = PjrtEngine::load(&default_artifacts_dir()).ok();
    if kernel.is_none() {
        println!("(kernel rows skipped: run `make artifacts`)");
    }

    harness::section("E10: XOR parity fold, k=4 shards");
    harness::table_header();
    for mb in [1usize, 4, 16] {
        let len = mb << 20;
        let bufs: Vec<Vec<u8>> = (0..k)
            .map(|_| {
                let mut b = vec![0u8; len];
                rng.fill_bytes(&mut b);
                b
            })
            .collect();
        let refs: Vec<&[u8]> = bufs.iter().map(|b| b.as_slice()).collect();
        let total = (k * len) as u64;

        let reps = harness::scaled(if mb >= 16 { 4 } else { 10 });
        let r = harness::bench_bytes(
            &format!("{mb} MiB/shard scalar"),
            total,
            1,
            reps,
            || {
                std::hint::black_box(
                    xor_fold(&refs, &XorBackend::NativeScalar).unwrap(),
                );
            },
        );
        harness::row(&r);
        let r = harness::bench_bytes(
            &format!("{mb} MiB/shard wide(u64)"),
            total,
            1,
            reps,
            || {
                std::hint::black_box(
                    xor_fold(&refs, &XorBackend::NativeWide).unwrap(),
                );
            },
        );
        harness::row(&r);
        if let Some(engine) = &kernel {
            let be = XorBackend::Kernel(engine.clone());
            let r = harness::bench_bytes(
                &format!("{mb} MiB/shard pallas-pjrt"),
                total,
                1,
                reps.min(4),
                || {
                    std::hint::black_box(xor_fold(&refs, &be).unwrap());
                },
            );
            harness::row(&r);
        }
    }

    harness::section("E10b: kernel TPU model (DESIGN.md §Hardware-Adaptation)");
    if let Some(engine) = &kernel {
        let rows = engine.manifest().constant("xor_shards").unwrap();
        let chunk = engine.manifest().constant("xor_chunk").unwrap();
        let block_n = engine.manifest().constant("xor_block_n").unwrap();
        let vmem_in = rows * block_n * 4;
        let vmem_out = block_n * 4;
        println!("grid step: ({rows} x {block_n}) i32 block");
        println!("VMEM per step: {} B in + {} B out (budget 16 MiB)", vmem_in, vmem_out);
        println!("lanes per call: {rows} x {chunk} = {} i32", rows * chunk);
        println!(
            "arithmetic intensity: {} XOR ops / {} B moved = {:.3} op/B\n\
             -> memory-bound; roofline = HBM bandwidth; the (8,128)-aligned\n\
             512-lane block streams full vector registers per cycle.",
            (rows - 1) * block_n,
            (rows + 1) * block_n * 4,
            ((rows - 1) * block_n) as f64 / (((rows + 1) * block_n * 4) as f64)
        );
        println!(
            "\nnote: pallas interpret=True wallclock above is a CPU-numpy\n\
             emulation figure (expected orders slower); the production L3\n\
             path uses the native wide fold, the kernel is the TPU artifact."
        );
    }
}
