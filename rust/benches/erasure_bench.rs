//! E10 — erasure-encode backend ablation (DESIGN.md): XOR parity fold via
//! the Pallas kernel through PJRT vs the native u64-wide fold vs the naive
//! scalar loop, across payload sizes.
//!
//! Also reports the modeled TPU picture for the kernel (DESIGN.md
//! §Hardware-Adaptation): VMEM bytes per grid step and the arithmetic
//! intensity, since interpret-mode wallclock is a CPU-numpy number, not a
//! TPU proxy.

#[path = "harness.rs"]
mod harness;

use veloc::modules::{xor_fold, xor_into, xor_into_scalar, XorBackend};
use veloc::runtime::{default_artifacts_dir, PjrtEngine};
use veloc::util::gf::{gf_mul_slice_scalar, gf_mul_slice_wide};
use veloc::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(10);
    let k = 4usize;
    let mut report = harness::Report::new("erasure");

    let kernel = PjrtEngine::load(&default_artifacts_dir()).ok();
    if kernel.is_none() {
        println!("(kernel rows skipped: run `make artifacts`)");
    }

    harness::section("E10: XOR parity fold, k=4 shards");
    harness::table_header();
    for mb in [1usize, 4, 16] {
        let len = mb << 20;
        let bufs: Vec<Vec<u8>> = (0..k)
            .map(|_| {
                let mut b = vec![0u8; len];
                rng.fill_bytes(&mut b);
                b
            })
            .collect();
        let refs: Vec<&[u8]> = bufs.iter().map(|b| b.as_slice()).collect();
        let total = (k * len) as u64;

        let reps = harness::scaled(if mb >= 16 { 4 } else { 10 });
        let r = harness::bench_bytes(
            &format!("{mb} MiB/shard scalar"),
            total,
            1,
            reps,
            || {
                std::hint::black_box(
                    xor_fold(&refs, &XorBackend::NativeScalar).unwrap(),
                );
            },
        );
        harness::row(&r);
        report.add(&r);
        let r = harness::bench_bytes(
            &format!("{mb} MiB/shard wide(u64)"),
            total,
            1,
            reps,
            || {
                std::hint::black_box(
                    xor_fold(&refs, &XorBackend::NativeWide).unwrap(),
                );
            },
        );
        harness::row(&r);
        report.add(&r);
        if let Some(engine) = &kernel {
            let be = XorBackend::Kernel(engine.clone());
            let r = harness::bench_bytes(
                &format!("{mb} MiB/shard pallas-pjrt"),
                total,
                1,
                reps.min(4),
                || {
                    std::hint::black_box(xor_fold(&refs, &be).unwrap());
                },
            );
            harness::row(&r);
            report.add(&r);
        }
    }

    harness::section("E10c: xor_into accumulate — u64-wide vs byte-serial");
    harness::table_header();
    let acc_len = 8usize << 20;
    let mut src = vec![0u8; acc_len];
    rng.fill_bytes(&mut src);
    {
        let mut a = vec![0u8; acc_len];
        let mut b = vec![0u8; acc_len];
        xor_into(&mut a, &src);
        xor_into_scalar(&mut b, &src);
        assert_eq!(a, b, "xor kernels must agree");
    }
    let reps = harness::scaled(16);
    let mut acc = vec![0u8; acc_len];
    let r_scalar = harness::bench_bytes("xor_into scalar", acc_len as u64, 1, reps, || {
        xor_into_scalar(std::hint::black_box(&mut acc), std::hint::black_box(&src));
    });
    harness::row(&r_scalar);
    let r_wide = harness::bench_bytes("xor_into wide (u64)", acc_len as u64, 1, reps, || {
        xor_into(std::hint::black_box(&mut acc), std::hint::black_box(&src));
    });
    harness::row(&r_wide);
    let xor_speedup = r_scalar.samples.p50() / r_wide.samples.p50().max(1e-12);
    println!("xor_into kernel speedup: {xor_speedup:.1}x (gate: >= 3x)");
    report.add(&r_scalar);
    report.add(&r_wide);
    report.scalar("xor_into_speedup", xor_speedup);
    assert!(
        xor_speedup >= 3.0,
        "acceptance: xor_into must be >= 3x the byte-serial baseline, got {xor_speedup:.2}x"
    );

    harness::section("E10d: GF(2^8) multiply-accumulate — 8-lane vs byte-serial");
    harness::table_header();
    let c = 0x1D; // mid-popcount coefficient: neither the c==1 nor c==0 shortcut
    {
        let mut a = vec![0u8; acc_len];
        let mut b = vec![0u8; acc_len];
        gf_mul_slice_wide(&mut a, &src, c);
        gf_mul_slice_scalar(&mut b, &src, c);
        assert_eq!(a, b, "gf kernels must agree");
    }
    let r_scalar = harness::bench_bytes("gf_mul_slice scalar", acc_len as u64, 1, reps, || {
        gf_mul_slice_scalar(std::hint::black_box(&mut acc), std::hint::black_box(&src), c);
    });
    harness::row(&r_scalar);
    let r_wide = harness::bench_bytes("gf_mul_slice wide (u64)", acc_len as u64, 1, reps, || {
        gf_mul_slice_wide(std::hint::black_box(&mut acc), std::hint::black_box(&src), c);
    });
    harness::row(&r_wide);
    let gf_speedup = r_scalar.samples.p50() / r_wide.samples.p50().max(1e-12);
    // Reported with a loose floor: the wide path's win depends on the
    // coefficient's popcount (shift-and-add steps), so 3x is not a stable
    // cross-machine gate the way the pure-XOR fold is.
    println!("gf_mul_slice kernel speedup: {gf_speedup:.1}x (floor: >= 1.2x)");
    report.add(&r_scalar);
    report.add(&r_wide);
    report.scalar("gf_mul_speedup", gf_speedup);
    assert!(
        gf_speedup >= 1.2,
        "gf_mul_slice_wide regressed below the scalar baseline: {gf_speedup:.2}x"
    );

    harness::section("E10b: kernel TPU model (DESIGN.md §Hardware-Adaptation)");
    if let Some(engine) = &kernel {
        let rows = engine.manifest().constant("xor_shards").unwrap();
        let chunk = engine.manifest().constant("xor_chunk").unwrap();
        let block_n = engine.manifest().constant("xor_block_n").unwrap();
        let vmem_in = rows * block_n * 4;
        let vmem_out = block_n * 4;
        println!("grid step: ({rows} x {block_n}) i32 block");
        println!("VMEM per step: {} B in + {} B out (budget 16 MiB)", vmem_in, vmem_out);
        println!("lanes per call: {rows} x {chunk} = {} i32", rows * chunk);
        println!(
            "arithmetic intensity: {} XOR ops / {} B moved = {:.3} op/B\n\
             -> memory-bound; roofline = HBM bandwidth; the (8,128)-aligned\n\
             512-lane block streams full vector registers per cycle.",
            (rows - 1) * block_n,
            (rows + 1) * block_n * 4,
            ((rows - 1) * block_n) as f64 / (((rows + 1) * block_n * 4) as f64)
        );
        println!(
            "\nnote: pallas interpret=True wallclock above is a CPU-numpy\n\
             emulation figure (expected orders slower); the production L3\n\
             path uses the native wide fold, the kernel is the TPU artifact."
        );
    }
    report.write();
}
