//! E-ipc — client-side blocking cost of the out-of-process active
//! backend vs the in-process async path.
//!
//! N concurrent clients each submit M checkpoints of one region. The
//! measured quantity is what the *application* pays per `checkpoint()`
//! call (the blocking time): the in-process path runs the blocking
//! pipeline prefix inline (checksum + fastest-tier capture); the daemon
//! path encodes, stages the payload on the local tier, and waits for the
//! fsynced-journal ack over the Unix socket — all post-processing happens
//! in the daemon.
//!
//! The acceptance shape: daemon-mode mean client blocking within 1.5x of
//! the in-process async path at 4 clients x 1 MiB (and wall-clock
//! throughput in the same ballpark).

#[path = "harness.rs"]
mod harness;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::backend::{BackendClient, BackendDaemon};
use veloc::pipeline::CkptStatus;
use veloc::util::stats::Samples;

const CLIENTS: usize = 4;
const WAVES: u64 = 16;
const REGION: usize = 1 << 20;

static DIRS: AtomicU64 = AtomicU64::new(0);

fn config() -> VelocConfig {
    let mut cfg = VelocConfig::default().with_nodes(CLIENTS, 1);
    cfg.stack.erasure_group = 0;
    cfg
}

/// Prefer a tmpfs home for the daemon (the deployment shape: staging and
/// journal live on the node-local fast tier, not on spinning scratch).
fn daemon_dir() -> std::path::PathBuf {
    let base = if std::path::Path::new("/dev/shm").is_dir() {
        std::path::PathBuf::from("/dev/shm")
    } else {
        std::env::temp_dir()
    };
    base.join(format!(
        "veloc-ipc-bench-{}-{}",
        std::process::id(),
        DIRS.fetch_add(1, Ordering::SeqCst)
    ))
}

/// Run one mode: `mk_client(rank)` builds the per-rank client; returns
/// (per-call blocking samples, wall seconds for the whole run).
fn run_mode<F>(mk_client: F) -> (Samples, f64)
where
    F: Fn(usize) -> veloc::api::VelocClient + Sync,
{
    let samples = Mutex::new(Vec::<f64>::new());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for rank in 0..CLIENTS {
            let samples = &samples;
            let mk_client = &mk_client;
            s.spawn(move || {
                let client = mk_client(rank);
                client.mem_protect(0, vec![rank as u8; REGION]);
                let mut local = Vec::with_capacity(WAVES as usize);
                for v in 1..=WAVES {
                    let t = Instant::now();
                    client.checkpoint("bench", v).expect("submit");
                    local.push(t.elapsed().as_secs_f64());
                    let st = client.checkpoint_wait("bench", v).expect("wait");
                    assert!(matches!(st, CkptStatus::Done(_)), "v{v}: {st:?}");
                }
                samples.lock().unwrap().extend(local);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut s = Samples::new();
    for v in samples.into_inner().unwrap() {
        s.push(v);
    }
    (s, wall)
}

fn main() {
    harness::section(&format!(
        "ipc: client blocking, {CLIENTS} clients x {WAVES} waves x {} MiB",
        REGION >> 20
    ));

    // Baseline: linked-in runtime, async engine.
    let rt = VelocRuntime::new(config()).unwrap();
    let (inproc, inproc_wall) = run_mode(|rank| rt.client(rank));
    rt.drain();
    drop(rt);

    // Daemon mode over the real socket: register, staged handoff,
    // fsync-before-ack journal.
    let mut cfg = config();
    cfg.backend.dir = daemon_dir();
    cfg.backend.queue_depth = CLIENTS * WAVES as usize + 8;
    let dir = cfg.backend.dir.clone();
    let socket = cfg.backend.socket_path();
    let daemon = BackendDaemon::start(cfg).unwrap();
    let server = {
        let d = Arc::clone(&daemon);
        std::thread::spawn(move || d.serve())
    };
    let bind_deadline = Instant::now() + Duration::from_secs(10);
    while !socket.exists() {
        assert!(
            Instant::now() < bind_deadline,
            "daemon never bound {}",
            socket.display()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let backend = BackendClient::connect(socket);
    let (daemon_s, daemon_wall) = run_mode(|rank| {
        backend.client(&format!("bench{rank}"), rank).expect("connect")
    });
    assert!(daemon.drain(Duration::from_secs(60)));
    backend.shutdown().unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);

    let total_bytes = (CLIENTS as u64) * WAVES * REGION as u64;
    println!(
        "{:<28} {:>12} {:>12} {:>10}",
        "mode", "mean block", "p95 block", "wall"
    );
    for (label, s, wall) in [
        ("in-process async", &inproc, inproc_wall),
        ("daemon (socket+journal)", &daemon_s, daemon_wall),
    ] {
        println!(
            "{label:<28} {:>12} {:>12} {:>10} ({:.2} GB/s end-to-end)",
            harness::fmt_secs(s.mean()),
            harness::fmt_secs(s.p95()),
            harness::fmt_secs(wall),
            total_bytes as f64 / wall / 1e9,
        );
    }
    let ratio = daemon_s.mean() / inproc.mean().max(1e-12);
    println!(
        "\nclient-side blocking: daemon mode is {ratio:.2}x the in-process async path\n\
         (the app pays staging + fsynced ack; checksum and every resilience\n\
         level moved into the daemon — the paper's active-backend split)"
    );
    assert!(
        ratio <= 1.5,
        "acceptance: daemon-mode client blocking within 1.5x of in-process, got {ratio:.2}x"
    );
}
