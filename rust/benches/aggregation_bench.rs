//! E-agg — aggregated vs file-per-rank PFS flush (model time mode).
//!
//! Sweeps rank count x per-rank checkpoint size and compares the modeled
//! aggregate flush throughput of the file-per-rank pattern (one PFS object
//! per rank, paying the per-op latency every time) against the aggregated
//! containers (per-group write combining; few large sequential writes).
//! The acceptance shape: >= 2x at 64 ranks x 1 MiB.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;
use veloc::aggregation::{AggregationConfig, Aggregator};
use veloc::cluster::Topology;
use veloc::storage::{FabricConfig, StorageFabric};

fn fabric() -> Arc<StorageFabric> {
    Arc::new(
        StorageFabric::build(&FabricConfig {
            nodes: 8,
            ..Default::default()
        })
        .unwrap(),
    )
}

/// Modeled time for one collective flush wave, file-per-rank.
fn file_per_rank_secs(ranks: usize, bytes: usize) -> f64 {
    let f = fabric();
    let data = Arc::new(vec![0xABu8; bytes]);
    let mut total = Duration::ZERO;
    for r in 0..ranks {
        let stat = f
            .pfs()
            .put_shared(&format!("pfs.app.r{r}.v1"), &data)
            .unwrap();
        total += stat.modeled;
    }
    total.as_secs_f64()
}

/// Modeled time for the same wave through the aggregator; also returns
/// (containers, mean write bytes, write amplification).
fn aggregated_secs(ranks: usize, bytes: usize, group: usize) -> (f64, u64, f64, f64) {
    let data = veloc::util::bufpool::Bytes::from(vec![0xABu8; bytes]);
    let agg = Aggregator::new(
        Topology::new(ranks, 1),
        fabric(),
        AggregationConfig {
            enabled: true,
            group_ranks: group,
            ..Default::default()
        },
        None,
        None,
    );
    let mut total = Duration::ZERO;
    for r in 0..ranks {
        let stat = agg.submit("app", 1, r, "raw", data.clone()).unwrap();
        total += stat.modeled;
    }
    total += agg.flush_all().unwrap().modeled;
    let rep = agg.report();
    (
        total.as_secs_f64(),
        rep.containers,
        rep.mean_write_bytes(),
        rep.write_amplification(),
    )
}

fn main() {
    harness::section("E-agg: file-per-rank vs aggregated PFS flush (model)");
    println!(
        "{:>6} {:>9} {:>6} {:>13} {:>13} {:>8} {:>6} {:>12} {:>7}",
        "ranks", "size", "group", "fpr agg-bw", "agg agg-bw", "speedup", "conts", "mean write", "amplif"
    );
    let group = 8usize;
    for &ranks in &[8usize, 64, 256] {
        for &kib in &[256usize, 1024, 4096] {
            let bytes = kib << 10;
            let total_bytes = (ranks * bytes) as f64;
            let fpr = file_per_rank_secs(ranks, bytes);
            let (agg, containers, mean_write, amplif) =
                aggregated_secs(ranks, bytes, group);
            let speedup = fpr / agg.max(1e-12);
            println!(
                "{:>6} {:>8}K {:>6} {:>10.2} GB/s {:>10.2} GB/s {:>7.1}x {:>6} {:>9.1} MiB {:>7.4}",
                ranks,
                kib,
                group,
                total_bytes / fpr / 1e9,
                total_bytes / agg / 1e9,
                speedup,
                containers,
                mean_write / (1 << 20) as f64,
                amplif
            );
            if ranks == 64 && kib == 1024 {
                assert!(
                    speedup >= 2.0,
                    "acceptance: >= 2x at 64 ranks x 1 MiB, got {speedup:.2}x"
                );
            }
        }
    }
    println!(
        "\nshape: per-op PFS latency dominates small per-rank objects; packing\n\
         a group's wave into one sequential container amortizes it. The win\n\
         shrinks as per-rank checkpoints grow (bandwidth-bound regime)."
    );
}
