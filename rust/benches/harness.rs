//! Shared mini-bench harness (criterion replacement): warmup + timed
//! repetitions, mean/p50/p95, paper-style table printing.
//!
//! Included by each bench target via `#[path = "harness.rs"] mod harness;`.

#![allow(dead_code)]

use std::time::{Duration, Instant};
use veloc::util::stats::Samples;

pub struct BenchResult {
    pub label: String,
    pub samples: Samples,
    pub bytes_per_iter: u64,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        self.samples.mean()
    }

    pub fn throughput_gbps(&self) -> f64 {
        if self.bytes_per_iter == 0 {
            return 0.0;
        }
        self.bytes_per_iter as f64 / self.mean() / 1e9
    }
}

/// Time `iters` runs of `f` after `warmup` runs.
pub fn bench<F: FnMut()>(label: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push_duration(t0.elapsed());
    }
    BenchResult {
        label: label.to_string(),
        samples,
        bytes_per_iter: 0,
    }
}

pub fn bench_bytes<F: FnMut()>(
    label: &str,
    bytes: u64,
    warmup: usize,
    iters: usize,
    f: F,
) -> BenchResult {
    let mut r = bench(label, warmup, iters, f);
    r.bytes_per_iter = bytes;
    r
}

pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.2} µs", s * 1e6)
    }
}

/// Print a header for a bench section.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print one table row: label, mean, p95, optional throughput.
pub fn row(r: &BenchResult) {
    if r.bytes_per_iter > 0 {
        println!(
            "{:<34} {:>12} {:>12} {:>10.2} GB/s",
            r.label,
            fmt_secs(r.mean()),
            fmt_secs(r.samples.p95()),
            r.throughput_gbps()
        );
    } else {
        println!(
            "{:<34} {:>12} {:>12}",
            r.label,
            fmt_secs(r.mean()),
            fmt_secs(r.samples.p95())
        );
    }
}

pub fn table_header() {
    println!(
        "{:<34} {:>12} {:>12} {:>15}",
        "case", "mean", "p95", "throughput"
    );
}

/// Quick-mode guard: `VELOC_BENCH_QUICK=1` shrinks iteration counts so
/// `cargo bench` finishes fast in CI.
pub fn quick() -> bool {
    std::env::var("VELOC_BENCH_QUICK").map_or(false, |v| v == "1")
}

pub fn scaled(n: usize) -> usize {
    if quick() {
        (n / 4).max(1)
    } else {
        n
    }
}

/// Machine-readable bench output: collects results and named scalars,
/// and — when `VELOC_BENCH_JSON_DIR` is set (the CI bench job) — writes
/// them as `BENCH_<name>.json` into that directory so per-PR runs can be
/// diffed. Without the env var, `write` is a no-op beyond the tables the
/// bench already printed.
pub struct Report {
    name: String,
    results: Vec<veloc::util::json::Json>,
    scalars: Vec<(String, f64)>,
}

impl Report {
    pub fn new(name: &str) -> Self {
        Report {
            name: name.to_string(),
            results: Vec::new(),
            scalars: Vec::new(),
        }
    }

    /// Record one timed case (label, mean/p50/p95 seconds, bytes moved).
    pub fn add(&mut self, r: &BenchResult) {
        self.results.push(
            veloc::util::json::Json::obj()
                .set("label", r.label.as_str())
                .set("mean_s", r.mean())
                .set("p50_s", r.samples.p50())
                .set("p95_s", r.samples.p95())
                .set("bytes_per_iter", r.bytes_per_iter),
        );
    }

    /// Record one derived headline number (a speedup, a ratio, a count).
    pub fn scalar(&mut self, key: &str, value: f64) {
        self.scalars.push((key.to_string(), value));
    }

    /// Write `BENCH_<name>.json` into `$VELOC_BENCH_JSON_DIR` (if set).
    pub fn write(&self) {
        let Ok(dir) = std::env::var("VELOC_BENCH_JSON_DIR") else {
            return;
        };
        if dir.is_empty() {
            return;
        }
        let mut scalars = veloc::util::json::Json::obj();
        for (k, v) in &self.scalars {
            scalars = scalars.set(k, *v);
        }
        let j = veloc::util::json::Json::obj()
            .set("bench", self.name.as_str())
            .set("results", veloc::util::json::Json::Arr(self.results.clone()))
            .set("scalars", scalars);
        let path = std::path::Path::new(&dir).join(format!("BENCH_{}.json", self.name));
        let _ = std::fs::create_dir_all(&dir);
        match std::fs::write(&path, j.to_pretty()) {
            Ok(()) => println!("bench report: {}", path.display()),
            Err(e) => eprintln!("bench report {} not written: {e}", path.display()),
        }
    }
}

/// Best-effort total time limiter for sweep loops.
pub struct Budget {
    deadline: Instant,
}

impl Budget {
    pub fn new(d: Duration) -> Self {
        Budget {
            deadline: Instant::now() + d,
        }
    }

    pub fn ok(&self) -> bool {
        Instant::now() < self.deadline
    }
}
