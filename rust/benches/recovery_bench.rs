//! E3 + E9 — multi-level recovery: survival under escalating failure
//! severities, recovery-level distribution under the default severity mix,
//! and restart latency per level.
//!
//! Shape to reproduce: every single-group-loss failure recovers; most
//! recoveries come from the cheap levels (the multi-level premise); and
//! restart latency is ordered local < partner < erasure < PFS.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Instant;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::cluster::{FailureInjector, FailureScope};
use veloc::pipeline::level_name;
use veloc::util::rng::Rng;
use veloc::util::stats::Samples;

fn runtime() -> Arc<VelocRuntime> {
    let mut cfg = VelocConfig::default().with_nodes(8, 1);
    cfg.stack.erasure_group = 4;
    VelocRuntime::new(cfg).unwrap()
}

fn checkpoint_world(rt: &Arc<VelocRuntime>, v: u64, bytes: usize) {
    let world = rt.topology().world_size();
    let hs: Vec<_> = (0..world)
        .map(|rank| {
            let rt = Arc::clone(rt);
            std::thread::spawn(move || {
                let client = rt.client(rank);
                client.mem_protect(0, vec![(rank as u8).wrapping_add(v as u8); bytes]);
                client.checkpoint("e3", v).unwrap();
                client.checkpoint_wait_done("e3", v).unwrap();
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    rt.drain();
}

fn main() {
    let bytes = 64 << 10;
    let trials = harness::scaled(60);
    let mut report = harness::Report::new("recovery");

    harness::section("E3: recovery under the default severity mix");
    let rt = runtime();
    let topo = rt.topology();
    let inj = FailureInjector::new(topo, 100.0);
    let mut rng = Rng::new(99);
    let mut level_hist = [0usize; 6];
    let mut failed = 0usize;
    let mut latency: Vec<Samples> = (0..6).map(|_| Samples::new()).collect();
    let mut version = 0u64;
    for _ in 0..trials {
        version += 1;
        checkpoint_world(&rt, version, bytes);
        // One failure event per trial, drawn from the paper-family mix.
        let scope = {
            let evs = inj.schedule(&mut rng, 1e9);
            evs.into_iter().next().unwrap().scope
        };
        rt.inject_failure(&scope);
        rt.revive_all();
        for rank in inj.affected_ranks(&scope) {
            let client = rt.client(rank);
            client.mem_protect(0, Vec::new());
            let t0 = Instant::now();
            match client.restart("e3").unwrap() {
                Some(info) => {
                    level_hist[info.level as usize] += 1;
                    latency[info.level as usize].push_duration(t0.elapsed());
                }
                None => failed += 1,
            }
        }
    }
    println!(
        "{:>10} {:>8} {:>14}",
        "level", "count", "restart mean"
    );
    for l in 1..6 {
        if level_hist[l] > 0 {
            println!(
                "{:>10} {:>8} {:>14}",
                level_name(l as u8),
                level_hist[l],
                harness::fmt_secs(latency[l].mean())
            );
        }
    }
    println!("unrecovered rank-restores: {failed}");
    let total: usize = level_hist.iter().sum();
    println!(
        "recovered {}/{} affected ranks ({:.1}%)",
        total,
        total + failed,
        100.0 * total as f64 / (total + failed).max(1) as f64
    );
    report.scalar("recovered_ranks", total as f64);
    report.scalar("unrecovered_ranks", failed as f64);

    harness::section("E9: restart latency per level (forced)");
    println!("{:>10} {:>14} {:>14}", "level", "mean", "p95");
    let cases: Vec<(&str, FailureScope)> = vec![
        ("local", FailureScope::Rank(0)),
        ("partner", FailureScope::Node(0)),
        ("erasure", FailureScope::MultiNode(vec![0, 1])),
        ("pfs", FailureScope::System),
    ];
    for (label, scope) in cases {
        let rt = runtime();
        let mut s = Samples::new();
        let reps = harness::scaled(8);
        for v in 1..=reps as u64 {
            checkpoint_world(&rt, v, bytes);
            rt.inject_failure(&scope);
            rt.revive_all();
            let client = rt.client(0);
            client.mem_protect(0, Vec::new());
            let t0 = Instant::now();
            let info = client.restart("e3").unwrap().expect("must recover");
            s.push_duration(t0.elapsed());
            assert_eq!(level_name(info.level), label, "wrong level served");
        }
        println!(
            "{:>10} {:>14} {:>14}",
            label,
            harness::fmt_secs(s.mean()),
            harness::fmt_secs(s.p95())
        );
        report.add(&harness::BenchResult {
            label: format!("restart-{label}"),
            samples: s,
            bytes_per_iter: bytes as u64,
        });
    }
    report.write();
}
