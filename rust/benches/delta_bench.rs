//! E-delta — full vs incremental checkpointing (model time mode).
//!
//! An iterative workload mutates a fixed fraction of its protected state
//! per step and checkpoints every step. The full pipeline moves the whole
//! snapshot to the PFS each time; the delta pipeline moves one forced
//! full plus thin containers (manifest + novel chunks). The acceptance
//! shape: >= 5x reduction in physical PFS bytes at 1% mutation.
//!
//! Physical bytes are read off the PFS tier itself (`used_bytes` with GC
//! disabled), so the comparison measures exactly what hit the shared
//! tier, container/manifest overhead included.
//!
//! The kernel sections gate the 4-lane fingerprint hash (>= 3x over the
//! byte-serial FNV-1a baseline it replaced) and report the unrolled gear
//! cut against its scalar reference; `BENCH_delta.json` is emitted when
//! `VELOC_BENCH_JSON_DIR` is set.

#[path = "harness.rs"]
mod harness;

use std::time::Instant;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::delta::Chunker;
use veloc::pipeline::CkptStatus;
use veloc::util::kernels::{fnv1a64, fp_hash64, fp_hash64_scalar};
use veloc::util::rng::Rng;
use veloc::util::stats::format_bytes;

struct RunResult {
    pfs_bytes: u64,
    secs: f64,
    logical: u64,
}

/// One mode run: `world` ranks, `waves` checkpoints, mutating `rate` of
/// the state (one contiguous run per rank) between checkpoints.
fn run_mode(delta: bool, rate: f64, waves: u64, state_bytes: usize) -> RunResult {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.stack.with_partner = false;
    cfg.stack.erasure_group = 0;
    cfg.stack.keep_versions = 64; // no GC: PFS bytes accumulate per wave
    if delta {
        cfg.delta.enabled = true;
        cfg.delta.min_chunk = 2 << 10;
        cfg.delta.avg_chunk = 8 << 10;
        cfg.delta.max_chunk = 64 << 10;
        cfg.delta.max_chain = 16;
    }
    let rt = VelocRuntime::new(cfg).unwrap();
    let world = rt.topology().world_size();
    let mut rng = Rng::new(0xBE9C);
    let mut states: Vec<Vec<u8>> = (0..world)
        .map(|_| {
            let mut d = vec![0u8; state_bytes];
            rng.fill_bytes(&mut d);
            d
        })
        .collect();
    let run = ((state_bytes as f64 * rate) as usize).max(1);
    let t0 = Instant::now();
    for version in 1..=waves {
        for (rank, state) in states.iter_mut().enumerate() {
            let span = state.len() - run.min(state.len() - 1);
            let off = (version as usize)
                .wrapping_mul(2_654_435_761)
                .wrapping_add(rank * 7919)
                % span;
            for b in &mut state[off..off + run.min(state.len() - off)] {
                *b = b.wrapping_add(1);
            }
            let client = rt.client(rank);
            client.mem_protect(0, state.clone());
            client.checkpoint("bench", version).unwrap();
            let st = client.checkpoint_wait("bench", version).unwrap();
            assert!(matches!(st, CkptStatus::Done(_)), "rank {rank}: {st:?}");
        }
    }
    rt.drain();
    RunResult {
        pfs_bytes: rt.env().fabric.pfs().used_bytes(),
        secs: t0.elapsed().as_secs_f64(),
        logical: waves * world as u64 * state_bytes as u64,
    }
}

/// Total bytes chunked by walking boundaries with the given cut function.
fn walk_cuts(data: &[u8], cut: impl Fn(&[u8]) -> usize) -> usize {
    let mut d = data;
    let mut chunks = 0usize;
    while !d.is_empty() {
        let c = cut(d);
        d = &d[c..];
        chunks += 1;
    }
    chunks
}

fn main() {
    let mut report = harness::Report::new("delta");
    let mut rng = Rng::new(0xD17A);
    let kernel_len = 8usize << 20;
    let mut buf = vec![0u8; kernel_len];
    rng.fill_bytes(&mut buf);

    harness::section("E-delta-k1: fingerprint hash — 4-lane vs byte-serial");
    harness::table_header();
    assert_eq!(fp_hash64(&buf), fp_hash64_scalar(&buf), "lanes must agree");
    let reps = harness::scaled(16);
    let r_fnv =
        harness::bench_bytes("fnv1a64 (legacy byte-serial)", kernel_len as u64, 1, reps, || {
            std::hint::black_box(fnv1a64(std::hint::black_box(&buf)));
        });
    harness::row(&r_fnv);
    let r_lane_ref =
        harness::bench_bytes("fp_hash64 scalar reference", kernel_len as u64, 1, reps, || {
            std::hint::black_box(fp_hash64_scalar(std::hint::black_box(&buf)));
        });
    harness::row(&r_lane_ref);
    let r_fp = harness::bench_bytes("fp_hash64 (4-lane)", kernel_len as u64, 1, reps, || {
        std::hint::black_box(fp_hash64(std::hint::black_box(&buf)));
    });
    harness::row(&r_fp);
    let fp_speedup = r_fnv.samples.p50() / r_fp.samples.p50().max(1e-12);
    println!("fingerprint hash speedup vs legacy: {fp_speedup:.1}x (gate: >= 3x)");
    report.add(&r_fnv);
    report.add(&r_lane_ref);
    report.add(&r_fp);
    report.scalar("fp_hash_speedup", fp_speedup);
    assert!(
        fp_speedup >= 3.0,
        "acceptance: fp_hash64 must be >= 3x the byte-serial baseline, got {fp_speedup:.2}x"
    );

    harness::section("E-delta-k2: gear cut — unrolled vs byte-serial");
    harness::table_header();
    let ch = Chunker::new(2 << 10, 8 << 10, 64 << 10).unwrap();
    assert_eq!(
        walk_cuts(&buf, |d| ch.cut(d)),
        walk_cuts(&buf, |d| ch.cut_scalar(d)),
        "unrolled cut must produce identical boundaries"
    );
    let r_cut_scalar = harness::bench_bytes("gear cut scalar", kernel_len as u64, 1, reps, || {
        std::hint::black_box(walk_cuts(std::hint::black_box(&buf), |d| ch.cut_scalar(d)));
    });
    harness::row(&r_cut_scalar);
    let r_cut = harness::bench_bytes("gear cut unrolled x4", kernel_len as u64, 1, reps, || {
        std::hint::black_box(walk_cuts(std::hint::black_box(&buf), |d| ch.cut(d)));
    });
    harness::row(&r_cut);
    let cut_speedup = r_cut_scalar.samples.p50() / r_cut.samples.p50().max(1e-12);
    // Reported, not gated: the gear recurrence is serial, so unrolling
    // buys loop/mask overhead back (~1.5-2x), not a lane-parallel 3x.
    println!("gear cut speedup: {cut_speedup:.2}x (reported)");
    report.add(&r_cut_scalar);
    report.add(&r_cut);
    report.scalar("gear_cut_speedup", cut_speedup);

    harness::section("E-delta: full vs incremental checkpoint traffic");
    let state_bytes = 4 << 20; // per rank
    // Fixed wave count: the 5x acceptance ratio amortizes one forced full
    // over the chain, so shrinking waves would shrink the ratio itself.
    let waves = 10u64;
    println!(
        "{:>9} {:>6} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "mutation", "mode", "logical", "pfs bytes", "reduction", "time", "dedup"
    );
    for &rate in &[0.01f64, 0.10, 0.50] {
        let full = run_mode(false, rate, waves, state_bytes);
        let delta = run_mode(true, rate, waves, state_bytes);
        let reduction = full.pfs_bytes as f64 / delta.pfs_bytes.max(1) as f64;
        for (label, r) in [("full", &full), ("delta", &delta)] {
            println!(
                "{:>8.0}% {:>6} {:>12} {:>12} {:>11} {:>9.2}s {:>9.1}x",
                rate * 100.0,
                label,
                format_bytes(r.logical),
                format_bytes(r.pfs_bytes),
                if label == "delta" {
                    format!("{reduction:.1}x")
                } else {
                    "-".to_string()
                },
                r.secs,
                r.logical as f64 / r.pfs_bytes.max(1) as f64,
            );
        }
        if (rate - 0.01).abs() < 1e-9 {
            report.scalar("reduction_1pct", reduction);
            assert!(
                reduction >= 5.0,
                "acceptance: >= 5x physical-byte reduction at 1% mutation, got {reduction:.2}x"
            );
        }
    }
    println!(
        "\nshape: at low mutation rates the physical traffic collapses to one\n\
         forced full per chain plus manifests and novel chunks; as the\n\
         mutation fraction grows the delta containers converge back to full\n\
         snapshots and the reduction fades — the chunk/diff CPU cost only\n\
         pays for itself below that crossover."
    );
    report.write();
}
