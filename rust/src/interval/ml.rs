//! Neural-network interval optimizer (paper ref [1]): the AOT-compiled
//! interval MLP is *trained at runtime from Rust* on the DES-labelled
//! scenario dataset, entirely through PJRT — Python never runs.

use crate::interval::dataset::{interval_of, Example};
use crate::runtime::{PjrtEngine, Tensor};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

pub struct NnOptimizer {
    engine: Arc<PjrtEngine>,
    params: Vec<Tensor>, // w1,b1,w2,b2,w3,b3
    batch: usize,
    features: usize,
}

impl NnOptimizer {
    /// Fresh optimizer from the exported initial weights.
    pub fn new(engine: Arc<PjrtEngine>) -> Result<Self> {
        let man = engine.manifest();
        let params = man
            .load_params("interval_init")?
            .iter()
            .map(Tensor::from)
            .collect();
        let batch = man.constant("interval_batch")?;
        let features = man.constant("interval_features")?;
        Ok(NnOptimizer {
            engine,
            params,
            batch,
            features,
        })
    }

    /// SGD on (features -> log10 interval). Returns per-epoch mean loss.
    pub fn fit(&mut self, data: &[Example], epochs: usize, lr: f32, seed: u64) -> Result<Vec<f32>> {
        assert!(!data.is_empty());
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..data.len()).collect();
        let mut history = Vec::with_capacity(epochs);
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            let mut losses = Vec::new();
            for chunk in order.chunks(self.batch) {
                // Pad the mini-batch to the compiled batch size by
                // repeating examples (gradient weighting shift is tiny and
                // vanishes with shuffling).
                let mut x = Vec::with_capacity(self.batch * self.features);
                let mut y = Vec::with_capacity(self.batch);
                for i in 0..self.batch {
                    let ex = &data[chunk[i % chunk.len()]];
                    x.extend_from_slice(&ex.features);
                    y.push(ex.label);
                }
                let mut args = self.params.clone();
                args.push(Tensor::f32(&[self.batch, self.features], x));
                args.push(Tensor::f32(&[self.batch], y));
                args.push(Tensor::scalar_f32(lr));
                let out = self.engine.run("interval_mlp_train", &args)?;
                losses.push(out[6].as_f32()?[0]);
                for (i, t) in out.into_iter().take(6).enumerate() {
                    self.params[i] = t;
                }
            }
            history.push(losses.iter().sum::<f32>() / losses.len() as f32);
        }
        Ok(history)
    }

    /// Predict log10-interval labels for a feature batch.
    pub fn predict_labels(&self, feats: &[[f32; 10]]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(feats.len());
        for chunk in feats.chunks(self.batch) {
            let mut x = Vec::with_capacity(self.batch * self.features);
            for i in 0..self.batch {
                x.extend_from_slice(&chunk[i.min(chunk.len() - 1)]);
            }
            let mut args = self.params.clone();
            args.push(Tensor::f32(&[self.batch, self.features], x));
            let res = self.engine.run("interval_mlp_fwd", &args)?;
            out.extend_from_slice(&res[0].as_f32()?[..chunk.len()]);
        }
        Ok(out)
    }

    /// Predict the checkpoint interval (seconds) for one scenario.
    pub fn predict_interval(&self, features: &[f32; 10]) -> Result<f64> {
        Ok(interval_of(self.predict_labels(&[*features])?[0]))
    }

    /// Mean absolute error in label (log10) space.
    pub fn mae(&self, data: &[Example]) -> Result<f32> {
        let feats: Vec<[f32; 10]> = data.iter().map(|e| e.features).collect();
        let preds = self.predict_labels(&feats)?;
        Ok(preds
            .iter()
            .zip(data)
            .map(|(p, e)| (p - e.label).abs())
            .sum::<f32>()
            / data.len() as f32)
    }
}
