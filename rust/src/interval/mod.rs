//! Checkpoint-interval optimization (paper §2, "ML-Optimized Checkpoint
//! Intervals"): closed-form baselines, the DES ground truth, the scenario
//! dataset, the runtime-trained NN optimizer and the random-forest
//! comparator.

pub mod dataset;
pub mod forest;
pub mod ml;
pub mod simulator;
pub mod young_daly;

pub use dataset::{generate, interval_of, label_of, split, Example};
pub use forest::RandomForest;
pub use ml::NnOptimizer;
pub use simulator::{mean_efficiency, optimal_interval, simulate, Scenario, SimResult};
pub use young_daly::{daly, efficiency_first_order, young};
