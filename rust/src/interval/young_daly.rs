//! Closed-form checkpoint interval baselines.
//!
//! Young's first-order formula and Daly's higher-order refinement give the
//! optimal interval for a *single-level, fixed-cost* checkpoint system.
//! The paper's point (§2, "ML-Optimized Checkpoint Intervals") is exactly
//! that these break down for asynchronous multi-level systems — which the
//! E6 experiment demonstrates against the DES ground truth.

/// Young 1974: W* = sqrt(2 * C * MTBF), C = checkpoint cost (s).
pub fn young(ckpt_cost: f64, mtbf: f64) -> f64 {
    assert!(ckpt_cost > 0.0 && mtbf > 0.0);
    (2.0 * ckpt_cost * mtbf).sqrt()
}

/// Daly 2006 higher-order estimate (valid for C < 2*MTBF):
/// W* = sqrt(2*C*M) * [1 + 1/3 sqrt(C/(2M)) + C/(9*2M)] - C
pub fn daly(ckpt_cost: f64, mtbf: f64) -> f64 {
    assert!(ckpt_cost > 0.0 && mtbf > 0.0);
    let c = ckpt_cost;
    let m = mtbf;
    if c >= 2.0 * m {
        return m; // formula out of range; degenerate regime
    }
    let s = (2.0 * c * m).sqrt();
    (s * (1.0 + (c / (2.0 * m)).sqrt() / 3.0 + c / (18.0 * m)) - c).max(c)
}

/// Expected efficiency (useful-work fraction) of periodic checkpointing at
/// interval `w` under exponential failures — the classic first-order
/// model used to sanity-check the DES.
pub fn efficiency_first_order(w: f64, ckpt_cost: f64, restart_cost: f64, mtbf: f64) -> f64 {
    // fraction of time spent on checkpoints:
    let ckpt_overhead = ckpt_cost / (w + ckpt_cost);
    // expected rework per failure ~ w/2 + restart
    let failure_rate = 1.0 / mtbf;
    let rework = failure_rate * (w / 2.0 + restart_cost);
    ((1.0 - ckpt_overhead) * (1.0 - rework)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn young_formula_exact() {
        assert!((young(10.0, 2000.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn daly_close_to_young_when_cheap() {
        // C << MTBF: Daly ~ Young
        let y = young(1.0, 100_000.0);
        let d = daly(1.0, 100_000.0);
        assert!((d - y).abs() / y < 0.02, "young {y} daly {d}");
    }

    #[test]
    fn daly_below_young_for_expensive_ckpts() {
        let y = young(100.0, 1000.0);
        let d = daly(100.0, 1000.0);
        assert!(d < y);
        assert!(d > 0.0);
    }

    #[test]
    fn efficiency_peaks_near_young() {
        let (c, r, m) = (10.0, 20.0, 2000.0);
        let w_star = young(c, m);
        let e_star = efficiency_first_order(w_star, c, r, m);
        for w in [w_star / 8.0, w_star * 8.0] {
            assert!(efficiency_first_order(w, c, r, m) < e_star, "w={w}");
        }
    }
}
