//! Random-forest regression baseline (pure Rust) — the comparator the
//! paper's ref [1] reports the neural network *outperforming* (E6).
//!
//! Standard CART regression trees: bootstrap sampling per tree, random
//! feature subset per split, variance-reduction splitting, mean-leaf
//! prediction, ensemble averaging.

use crate::util::rng::Rng;

const F: usize = 10; // feature dimensionality (matches Scenario::features)

enum Node {
    Leaf(f32),
    Split {
        feat: usize,
        thresh: f32,
        left: Box<Node>,
        right: Box<Node>,
    },
}

impl Node {
    fn predict(&self, x: &[f32; F]) -> f32 {
        match self {
            Node::Leaf(v) => *v,
            Node::Split {
                feat,
                thresh,
                left,
                right,
            } => {
                if x[*feat] <= *thresh {
                    left.predict(x)
                } else {
                    right.predict(x)
                }
            }
        }
    }

    fn depth(&self) -> usize {
        match self {
            Node::Leaf(_) => 1,
            Node::Split { left, right, .. } => 1 + left.depth().max(right.depth()),
        }
    }
}

fn mean(ys: &[f32], idx: &[usize]) -> f32 {
    idx.iter().map(|&i| ys[i]).sum::<f32>() / idx.len().max(1) as f32
}

fn sse(ys: &[f32], idx: &[usize]) -> f32 {
    let m = mean(ys, idx);
    idx.iter().map(|&i| (ys[i] - m) * (ys[i] - m)).sum()
}

fn build(
    xs: &[[f32; F]],
    ys: &[f32],
    idx: &mut Vec<usize>,
    depth: usize,
    max_depth: usize,
    min_leaf: usize,
    rng: &mut Rng,
) -> Node {
    if depth >= max_depth || idx.len() < 2 * min_leaf || sse(ys, idx) < 1e-8 {
        return Node::Leaf(mean(ys, idx));
    }
    // Random sqrt-subset of features.
    let mut feats: Vec<usize> = (0..F).collect();
    rng.shuffle(&mut feats);
    let n_try = (F as f64).sqrt().ceil() as usize;
    let mut best: Option<(f32, usize, f32)> = None; // (score, feat, thresh)
    let parent = sse(ys, idx);
    for &f in feats.iter().take(n_try) {
        // Candidate thresholds: a handful of random sample values.
        for _ in 0..8 {
            let pivot = xs[idx[rng.range_usize(0, idx.len())]][f];
            let (mut li, mut ri): (Vec<usize>, Vec<usize>) = (vec![], vec![]);
            for &i in idx.iter() {
                if xs[i][f] <= pivot {
                    li.push(i)
                } else {
                    ri.push(i)
                }
            }
            if li.len() < min_leaf || ri.len() < min_leaf {
                continue;
            }
            let score = sse(ys, &li) + sse(ys, &ri);
            if score < parent && best.map_or(true, |(b, _, _)| score < b) {
                best = Some((score, f, pivot));
            }
        }
    }
    let Some((_, feat, thresh)) = best else {
        return Node::Leaf(mean(ys, idx));
    };
    let (mut li, mut ri): (Vec<usize>, Vec<usize>) = (vec![], vec![]);
    for &i in idx.iter() {
        if xs[i][feat] <= thresh {
            li.push(i)
        } else {
            ri.push(i)
        }
    }
    Node::Split {
        feat,
        thresh,
        left: Box::new(build(xs, ys, &mut li, depth + 1, max_depth, min_leaf, rng)),
        right: Box::new(build(xs, ys, &mut ri, depth + 1, max_depth, min_leaf, rng)),
    }
}

/// The forest.
pub struct RandomForest {
    trees: Vec<Node>,
}

impl RandomForest {
    /// Fit on features/labels.
    pub fn fit(
        xs: &[[f32; F]],
        ys: &[f32],
        n_trees: usize,
        max_depth: usize,
        seed: u64,
    ) -> Self {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let mut rng = Rng::new(seed);
        let trees = (0..n_trees)
            .map(|t| {
                let mut trng = rng.fork(t as u64);
                // Bootstrap sample.
                let mut idx: Vec<usize> = (0..xs.len())
                    .map(|_| trng.range_usize(0, xs.len()))
                    .collect();
                build(xs, ys, &mut idx, 0, max_depth, 2, &mut trng)
            })
            .collect();
        RandomForest { trees }
    }

    pub fn predict(&self, x: &[f32; F]) -> f32 {
        self.trees.iter().map(|t| t.predict(x)).sum::<f32>() / self.trees.len() as f32
    }

    pub fn max_depth(&self) -> usize {
        self.trees.iter().map(|t| t.depth()).max().unwrap_or(0)
    }

    /// Mean absolute error on a labelled set.
    pub fn mae(&self, xs: &[[f32; F]], ys: &[f32]) -> f32 {
        xs.iter()
            .zip(ys)
            .map(|(x, &y)| (self.predict(x) - y).abs())
            .sum::<f32>()
            / xs.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// y = 2*x0 + x3, noise-free.
    fn toy(n: usize, seed: u64) -> (Vec<[f32; F]>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let mut x = [0f32; F];
            for v in x.iter_mut() {
                *v = rng.f32();
            }
            xs.push(x);
            ys.push(2.0 * x[0] + x[3]);
        }
        (xs, ys)
    }

    #[test]
    fn learns_toy_function() {
        let (xs, ys) = toy(400, 1);
        let rf = RandomForest::fit(&xs, &ys, 30, 8, 2);
        let (xt, yt) = toy(100, 3);
        let mae = rf.mae(&xt, &yt);
        // Baseline: predicting the mean gives MAE ~0.45.
        assert!(mae < 0.25, "mae {mae}");
    }

    #[test]
    fn beats_constant_predictor() {
        let (xs, ys) = toy(300, 5);
        let rf = RandomForest::fit(&xs, &ys, 20, 8, 6);
        let mean_y = ys.iter().sum::<f32>() / ys.len() as f32;
        let mae_const = ys.iter().map(|y| (y - mean_y).abs()).sum::<f32>() / ys.len() as f32;
        assert!(rf.mae(&xs, &ys) < mae_const * 0.6);
    }

    #[test]
    fn respects_max_depth() {
        let (xs, ys) = toy(200, 7);
        let rf = RandomForest::fit(&xs, &ys, 5, 3, 9);
        assert!(rf.max_depth() <= 4); // root at depth 1 + 3 splits
    }

    #[test]
    fn deterministic() {
        let (xs, ys) = toy(100, 11);
        let a = RandomForest::fit(&xs, &ys, 5, 5, 13).predict(&xs[0]);
        let b = RandomForest::fit(&xs, &ys, 5, 5, 13).predict(&xs[0]);
        assert_eq!(a, b);
    }
}
