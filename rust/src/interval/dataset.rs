//! Failure-scenario dataset for the ML interval optimizer (paper ref [1]:
//! sample representative scenarios, label each with the DES optimum, train
//! a model to fill the gaps of the search space).

use crate::cluster::failure::SeverityMix;
use crate::interval::simulator::{optimal_interval, Scenario};
use crate::util::rng::Rng;

/// One labelled example.
#[derive(Clone, Debug)]
pub struct Example {
    pub scenario: Scenario,
    pub features: [f32; 10],
    /// DES-optimal interval, log10-scaled for regression stability.
    pub label: f32,
    /// Efficiency at the optimum (diagnostics).
    pub best_eff: f64,
}

/// Label transform: intervals span decades, regress on log10.
pub fn label_of(interval: f64) -> f32 {
    (interval.max(1.0)).log10() as f32
}

pub fn interval_of(label: f32) -> f64 {
    10f64.powf(label as f64)
}

/// Draw a random but realistic scenario.
pub fn random_scenario(rng: &mut Rng) -> Scenario {
    let mtbf = 10f64.powf(rng.range_f64(2.3, 4.3)); // 200 s .. 20k s
    let l1_cost = 10f64.powf(rng.range_f64(-0.5, 1.5)); // 0.3 .. 30 s
    let rank_p = rng.range_f64(0.6, 0.9);
    let node_p = rng.range_f64(0.05, 0.2);
    let multi_p = rng.range_f64(0.02, 0.1);
    let sys_p = (1.0 - rank_p - node_p - multi_p).max(0.01);
    let norm = rank_p + node_p + multi_p + sys_p;
    Scenario {
        mtbf,
        l1_cost,
        l23_lag: l1_cost * rng.range_f64(1.0, 4.0),
        l4_lag: l1_cost * rng.range_f64(5.0, 40.0),
        restart_fast: l1_cost * rng.range_f64(1.0, 5.0),
        restart_pfs: l1_cost * rng.range_f64(10.0, 50.0),
        work: mtbf * rng.range_f64(10.0, 30.0),
        mix: SeverityMix {
            rank: rank_p / norm,
            node: node_p / norm,
            multi_node: multi_p / norm,
            system: sys_p / norm,
        },
    }
}

/// Generate a labelled dataset. `grid`/`trials` control DES label quality.
pub fn generate(n: usize, grid: usize, trials: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let mut srng = rng.fork(i as u64);
            let scenario = random_scenario(&mut srng);
            let (w, e) = optimal_interval(&scenario, grid, trials, seed ^ (i as u64) << 1);
            Example {
                features: scenario.features(),
                scenario,
                label: label_of(w),
                best_eff: e,
            }
        })
        .collect()
}

/// Split into (train, test).
pub fn split(data: Vec<Example>, test_fraction: f64) -> (Vec<Example>, Vec<Example>) {
    let n_test = ((data.len() as f64) * test_fraction).round() as usize;
    let n_train = data.len() - n_test;
    let mut it = data.into_iter();
    let train: Vec<Example> = it.by_ref().take(n_train).collect();
    let test: Vec<Example> = it.collect();
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_roundtrip() {
        for w in [1.0, 10.0, 123.0, 5000.0] {
            assert!((interval_of(label_of(w)) - w).abs() / w < 1e-4);
        }
    }

    #[test]
    fn scenarios_realistic() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let s = random_scenario(&mut rng);
            assert!(s.mtbf >= 100.0);
            assert!(s.l1_cost > 0.0);
            assert!(s.l4_lag > s.l23_lag);
            assert!(s.restart_pfs > s.restart_fast);
            let total = s.mix.rank + s.mix.node + s.mix.multi_node + s.mix.system;
            assert!((total - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn generate_is_deterministic_and_labelled() {
        let a = generate(3, 6, 2, 11);
        let b = generate(3, 6, 2, 11);
        assert_eq!(a.len(), 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label, y.label);
            assert!(x.best_eff > 0.0);
            assert!(x.label > 0.0); // intervals > 1 s
        }
    }

    #[test]
    fn split_sizes() {
        let d = generate(10, 4, 1, 5);
        let (tr, te) = split(d, 0.3);
        assert_eq!(tr.len(), 7);
        assert_eq!(te.len(), 3);
    }
}
