//! Discrete-event simulator of multi-level asynchronous checkpointing.
//!
//! Serves three roles:
//! 1. Ground truth for the checkpoint-interval experiments (E6): sweep
//!    intervals, pick the efficiency-maximizing one per scenario.
//! 2. Training-set generator for the ML optimizer (paper ref [1]:
//!    "sampling a subset of representative failure scenarios").
//! 3. Scale extrapolation for the E1 Summit headline: the same fair-share
//!    bandwidth model as the live `storage` stack, at 4k+ nodes.
//!
//! The model: an application runs for `work` seconds of useful compute,
//! checkpointing every `interval` seconds. A checkpoint blocks for the
//! level-1 (local) cost, then the deeper levels complete asynchronously.
//! Failures arrive as a Poisson process with severity levels; a failure is
//! recoverable from level L only if a checkpoint at level >= L *finished*
//! before the failure; rework = time since that checkpoint, plus the
//! level's restart cost.

use crate::cluster::failure::SeverityMix;
use crate::util::rng::Rng;

/// Scenario parameters (one row of the ML dataset).
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    /// System-level MTBF (s).
    pub mtbf: f64,
    /// Blocking (level-1 local) checkpoint cost (s).
    pub l1_cost: f64,
    /// Async completion lag of partner/erasure levels after L1 (s).
    pub l23_lag: f64,
    /// Async completion lag of the PFS flush after L1 (s).
    pub l4_lag: f64,
    /// Restart cost from local/partner/erasure (s).
    pub restart_fast: f64,
    /// Restart cost from the PFS (s).
    pub restart_pfs: f64,
    /// Total useful work to complete (s).
    pub work: f64,
    /// Failure severity mix.
    pub mix: SeverityMix,
}

impl Scenario {
    /// Normalized feature vector for the ML optimizer (10 features,
    /// matching `interval_features` in the AOT manifest).
    pub fn features(&self) -> [f32; 10] {
        [
            (self.mtbf / 10_000.0) as f32,
            (self.l1_cost / 100.0) as f32,
            (self.l23_lag / 100.0) as f32,
            (self.l4_lag / 1000.0) as f32,
            (self.restart_fast / 100.0) as f32,
            (self.restart_pfs / 1000.0) as f32,
            (self.work / 100_000.0) as f32,
            self.mix.rank as f32,
            self.mix.node as f32,
            (self.mix.multi_node + self.mix.system) as f32,
        ]
    }
}

/// Result of one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    /// Wall-clock to finish all work (s).
    pub makespan: f64,
    /// work / makespan.
    pub efficiency: f64,
    pub failures: usize,
    /// Failures that needed the PFS level.
    pub pfs_recoveries: usize,
}

/// Simulate one run at a fixed checkpoint interval.
pub fn simulate(s: &Scenario, interval: f64, rng: &mut Rng) -> SimResult {
    let mut t = 0.0; // wall clock
    let mut done = 0.0; // completed useful work
    let mut failures = 0usize;
    let mut pfs_recoveries = 0usize;

    // Last *completed* checkpoint per level class: (work_done, wall_done)
    let mut last_fast: Option<(f64, f64)> = None; // levels 1-3
    let mut last_pfs: Option<(f64, f64)> = None; // level 4

    let mut next_failure = t + rng.exponential(1.0 / s.mtbf);

    let sample_level = |rng: &mut Rng, mix: &SeverityMix| -> u8 {
        let x = rng.f64();
        if x < mix.rank {
            1
        } else if x < mix.rank + mix.node {
            2
        } else if x < mix.rank + mix.node + mix.multi_node {
            3
        } else {
            4
        }
    };

    let max_steps = 2_000_000;
    let mut steps = 0;
    while done < s.work && steps < max_steps {
        steps += 1;
        // Next segment: compute until the next checkpoint or completion.
        let seg = interval.min(s.work - done);
        let seg_end = t + seg;
        if next_failure <= seg_end {
            // Failure mid-segment.
            t = next_failure;
            failures += 1;
            let min_level = sample_level(rng, &s.mix);
            // Which saved state can serve this severity? Fast levels
            // survive severities 1-3 (partner/erasure by construction);
            // system failures need the PFS copy.
            let (saved, restart_cost) = if min_level <= 3 {
                match (last_fast, last_pfs) {
                    (Some(f), _) => (Some(f), s.restart_fast),
                    (None, Some(p)) => (Some(p), s.restart_pfs),
                    (None, None) => (None, s.restart_fast),
                }
            } else {
                pfs_recoveries += 1;
                (last_pfs, s.restart_pfs)
            };
            match saved {
                Some((w, _)) => {
                    done = w;
                }
                None => {
                    done = 0.0;
                }
            }
            t += restart_cost;
            next_failure = t + rng.exponential(1.0 / s.mtbf);
            continue;
        }
        // Segment completed.
        t = seg_end;
        done += seg;
        if done >= s.work {
            break;
        }
        // Take a checkpoint: block for L1, deeper levels complete later.
        t += s.l1_cost;
        let fast_ready = t + s.l23_lag;
        let pfs_ready = t + s.l4_lag;
        // A failure between now and *_ready leaves the previous copy as
        // the newest usable one; model by committing the new checkpoint
        // only when its completion time has passed the next failure check.
        if next_failure > fast_ready {
            last_fast = Some((done, fast_ready));
        }
        if next_failure > pfs_ready {
            last_pfs = Some((done, pfs_ready));
        }
    }
    let makespan = t.max(1e-9);
    SimResult {
        makespan,
        efficiency: (s.work / makespan).min(1.0),
        failures,
        pfs_recoveries,
    }
}

/// Average efficiency over `trials` random failure draws.
pub fn mean_efficiency(s: &Scenario, interval: f64, trials: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut sum = 0.0;
    for t in 0..trials {
        let mut r = rng.fork(t as u64);
        sum += simulate(s, interval, &mut r).efficiency;
    }
    sum / trials as f64
}

/// Sweep a log-spaced interval grid, return (best_interval, best_eff).
pub fn optimal_interval(
    s: &Scenario,
    grid: usize,
    trials: usize,
    seed: u64,
) -> (f64, f64) {
    // Interval range: from ~2x the blocking cost up to MTBF.
    let lo = (2.0 * s.l1_cost).max(1.0);
    let hi = (s.mtbf * 2.0).max(lo * 4.0);
    let mut best = (lo, -1.0);
    for g in 0..grid {
        let f = g as f64 / (grid - 1).max(1) as f64;
        let w = lo * (hi / lo).powf(f);
        let e = mean_efficiency(s, w, trials, seed ^ g as u64);
        if e > best.1 {
            best = (w, e);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interval::young_daly::young;

    fn scenario() -> Scenario {
        Scenario {
            mtbf: 2000.0,
            l1_cost: 5.0,
            l23_lag: 10.0,
            l4_lag: 60.0,
            restart_fast: 15.0,
            restart_pfs: 120.0,
            work: 50_000.0,
            mix: SeverityMix::default(),
        }
    }

    #[test]
    fn no_failures_efficiency_is_ckpt_overhead_only() {
        let mut s = scenario();
        s.mtbf = 1e12; // effectively failure-free
        let r = simulate(&s, 100.0, &mut Rng::new(1));
        // overhead = 5s per 100s of work
        assert!((r.efficiency - 100.0 / 105.0).abs() < 0.01, "{}", r.efficiency);
        assert_eq!(r.failures, 0);
    }

    #[test]
    fn failures_cost_rework() {
        let s = scenario();
        let r = simulate(&s, 100.0, &mut Rng::new(2));
        assert!(r.failures > 0);
        assert!(r.efficiency < 0.99);
        assert!(r.efficiency > 0.5, "{}", r.efficiency);
        assert!(r.makespan > s.work);
    }

    #[test]
    fn optimum_is_interior_and_near_young_scale() {
        let s = scenario();
        let (w, e) = optimal_interval(&s, 12, 6, 42);
        let y = young(s.l1_cost, s.mtbf);
        // The DES optimum should be the same order of magnitude as Young.
        assert!(w > y / 10.0 && w < y * 10.0, "w={w} young={y}");
        assert!(e > 0.5 && e <= 1.0);
        // Extremes must be worse.
        let e_tiny = mean_efficiency(&s, s.l1_cost * 2.0, 6, 42);
        let e_huge = mean_efficiency(&s, s.mtbf * 2.0, 6, 42);
        assert!(e >= e_tiny, "{e} vs tiny {e_tiny}");
        assert!(e >= e_huge, "{e} vs huge {e_huge}");
    }

    #[test]
    fn features_are_finite_and_scaled() {
        let f = scenario().features();
        assert!(f.iter().all(|x| x.is_finite()));
        assert!(f.iter().all(|&x| (0.0..=100.0).contains(&x)));
    }

    #[test]
    fn deterministic_under_seed() {
        let s = scenario();
        let a = mean_efficiency(&s, 150.0, 4, 7);
        let b = mean_efficiency(&s, 150.0, 4, 7);
        assert_eq!(a, b);
    }
}
