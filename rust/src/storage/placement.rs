//! Adaptive heterogeneous-tier placement (paper §1: "transparently
//! optimize performance and scalability by leveraging heterogeneous
//! storage options").
//!
//! The [`PlacementEngine`] sits between the flush paths (the direct PFS
//! transfer of `modules::transfer` and the aggregated container drains of
//! `crate::aggregation`) and the [`StorageFabric`](super::StorageFabric):
//! instead of hard-wiring one destination tier per resilience level, every
//! shared-tier flush asks the engine for the best *eligible* tier and
//! automatically fails over to the next-best one when the choice is down,
//! full or read-only. The actual destination is reported back to the
//! caller, which records it (version registry / aggregation segment
//! index) so restores find the bytes wherever they landed.
//!
//! ## Health model
//!
//! Per tier the engine keeps:
//!
//! - an EWMA **service multiplier**: every observed [`TransferStat`] is
//!   compared against the tier spec's predicted duration and the ratio is
//!   exponentially averaged. A healthy tier sits at 1.0; a degraded or
//!   congested tier drifts upward and adaptive policies route away from
//!   it. Tracking the multiplier (rather than raw bandwidth) folds both
//!   bandwidth *and* latency degradation into one number, so small-object
//!   workloads — where per-op latency dominates — adapt just as well as
//!   streaming ones.
//! - **capacity headroom**, consulted before every route (a flush larger
//!   than the remaining space is never attempted), and
//! - a **consecutive-error circuit breaker**: after
//!   [`PlacementConfig::breaker_threshold`] consecutive put failures the
//!   tier is skipped outright; every
//!   [`PlacementConfig::breaker_probe_after`] skipped routes one probe
//!   put is allowed through, and a success closes the breaker.
//!
//! ## Durability semantics
//!
//! Level 4 means "a copy on a shared tier", and its survival domain is
//! the *serving tier's* ([`FailureDomain`](super::FailureDomain)): a
//! flush routed to the burst buffer survives node failures but not a
//! full-system outage, exactly like the pre-existing
//! `aggregation.target = "burst-buffer"` configuration. Deployments that
//! need system-outage durability for every level-4 copy should keep only
//! `Persistent` tiers in the pool (no burst buffer / no extra
//! `burst-buffer`-kind tiers) — the recorded destination makes the actual
//! placement auditable per version (`VersionInfo::dest`, segment-index
//! `tier`).
//!
//! ## Policies
//!
//! - [`PlacementPolicy::Static`] — rank tiers in their configured order
//!   (the primary flush target first): today's behavior, plus failover.
//! - [`PlacementPolicy::FastestEligible`] — rank by predicted service
//!   time for this flush's size (spec shape × health multiplier).
//! - [`PlacementPolicy::CapacityAware`] — like fastest-eligible, but the
//!   score is penalized by fill fraction and tiers past
//!   [`PlacementConfig::full_watermark`] are skipped while an emptier
//!   tier can serve.
//!
//! ```
//! use std::sync::Arc;
//! use veloc::storage::{presets, PlacementConfig, PlacementEngine, PlacementPolicy};
//! use veloc::storage::{StorageTier, TimeMode};
//!
//! let pfs = StorageTier::memory(presets::pfs(u64::MAX / 2, 5.0e9), TimeMode::Model);
//! let bb = StorageTier::memory(
//!     presets::burst_buffer(u64::MAX / 2, 20.0e9),
//!     TimeMode::Model,
//! );
//! let cfg = PlacementConfig {
//!     enabled: true,
//!     policy: PlacementPolicy::FastestEligible,
//!     ..Default::default()
//! };
//! let engine = PlacementEngine::new(vec![Arc::clone(&pfs), bb], cfg, None).unwrap();
//! // The burst buffer wins on both bandwidth and latency...
//! let (dest, _) = engine.put("ckpt.v1", &Arc::new(vec![0u8; 1 << 20])).unwrap();
//! assert_eq!(dest, "burst-buffer");
//! // ...and an outage fails the next flush over instead of failing it.
//! engine.tier("burst-buffer").unwrap().set_down(true);
//! let (dest, _) = engine.put("ckpt.v2", &Arc::new(vec![0u8; 1 << 20])).unwrap();
//! assert_eq!(dest, "pfs");
//! ```

use crate::metrics::Metrics;
use crate::obs::signals::{SignalsBus, SIG_TIER_HEALTH_PREFIX};
use crate::storage::{StorageTier, TransferStat};
use crate::util::bufpool::Bytes;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// How the engine ranks eligible tiers for a flush.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Configured order (primary flush target first) — today's static
    /// routing, with failover on top.
    Static,
    /// Predicted service time for this flush's size, health-adjusted.
    FastestEligible,
    /// Service time penalized by fill fraction; nearly-full tiers are
    /// skipped while an emptier tier can serve.
    CapacityAware,
}

impl PlacementPolicy {
    /// Stable config/CLI spelling.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::Static => "static",
            PlacementPolicy::FastestEligible => "fastest-eligible",
            PlacementPolicy::CapacityAware => "capacity-aware",
        }
    }

    /// Parse the config/CLI spelling (single source of truth for both).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "static" => Ok(PlacementPolicy::Static),
            "fastest-eligible" | "fastest" => Ok(PlacementPolicy::FastestEligible),
            "capacity-aware" => Ok(PlacementPolicy::CapacityAware),
            other => bail!(
                "placement policy must be static|fastest-eligible|capacity-aware, got {other}"
            ),
        }
    }
}

/// Placement knobs (see `VelocConfig::placement` and the JSON
/// `"placement"` section).
#[derive(Clone, Debug)]
pub struct PlacementConfig {
    /// Route shared-tier flushes through the placement engine. Off by
    /// default: the legacy paths write straight to their configured tier.
    pub enabled: bool,
    /// Ranking policy.
    pub policy: PlacementPolicy,
    /// EWMA smoothing factor for the per-tier health multiplier, in
    /// `(0, 1]`; higher reacts faster.
    pub ewma_alpha: f64,
    /// Consecutive put failures that open a tier's circuit breaker.
    pub breaker_threshold: u32,
    /// Routes skipped while a breaker is open before one probe put is
    /// allowed through (half-open retry).
    pub breaker_probe_after: u32,
    /// Capacity-aware only: a tier filled past this fraction is skipped
    /// while any emptier tier is eligible, in `(0, 1]`.
    pub full_watermark: f64,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        PlacementConfig {
            enabled: false,
            policy: PlacementPolicy::Static,
            ewma_alpha: 0.3,
            breaker_threshold: 3,
            breaker_probe_after: 8,
            full_watermark: 0.95,
        }
    }
}

impl PlacementConfig {
    /// Reject knob values outside their documented ranges. Called by
    /// `VelocConfig::validate`.
    pub fn validate(&self) -> Result<()> {
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            bail!(
                "placement.ewma_alpha must be in (0, 1], got {}",
                self.ewma_alpha
            );
        }
        if self.breaker_threshold == 0 {
            bail!("placement.breaker_threshold must be >= 1");
        }
        if self.breaker_probe_after == 0 {
            bail!("placement.breaker_probe_after must be >= 1");
        }
        if !(self.full_watermark > 0.0 && self.full_watermark <= 1.0) {
            bail!(
                "placement.full_watermark must be in (0, 1], got {}",
                self.full_watermark
            );
        }
        Ok(())
    }
}

/// Mutable per-tier health state.
struct TierState {
    /// EWMA of observed/predicted duration ratios (1.0 = healthy).
    mult: Mutex<f64>,
    consec_errors: AtomicU32,
    breaker_open: AtomicBool,
    /// Routes skipped since the breaker opened (probe pacing).
    skips: AtomicU32,
    routed_puts: AtomicU64,
    routed_bytes: AtomicU64,
}

impl TierState {
    fn new() -> Self {
        TierState {
            mult: Mutex::new(1.0),
            consec_errors: AtomicU32::new(0),
            breaker_open: AtomicBool::new(false),
            skips: AtomicU32::new(0),
            routed_puts: AtomicU64::new(0),
            routed_bytes: AtomicU64::new(0),
        }
    }
}

/// Point-in-time health view of one placement tier (diagnostics: the
/// `veloc info` command prints these).
#[derive(Clone, Debug)]
pub struct TierHealth {
    /// Tier id ([`crate::storage::TierSpec::id`]).
    pub id: String,
    /// EWMA service multiplier (1.0 = spec-speed; higher = degraded).
    pub multiplier: f64,
    /// Consecutive put errors.
    pub consec_errors: u32,
    /// Is the circuit breaker currently open?
    pub breaker_open: bool,
    /// Puts this engine routed to the tier.
    pub routed_puts: u64,
    /// Bytes this engine routed to the tier.
    pub routed_bytes: u64,
    /// Fill fraction in `[0, 1]`.
    pub fill: f64,
}

/// The adaptive placement engine (see the [module docs](self)).
pub struct PlacementEngine {
    tiers: Vec<Arc<StorageTier>>,
    states: Vec<TierState>,
    cfg: PlacementConfig,
    metrics: Option<Arc<Metrics>>,
    failovers: AtomicU64,
    breaker_trips: AtomicU64,
    signals: OnceLock<Arc<SignalsBus>>,
}

impl PlacementEngine {
    /// Build an engine over an ordered tier pool. `tiers[0]` is the
    /// *primary* — the static policy's first choice and the home of
    /// shared metadata objects (aggregation index, lineage).
    pub fn new(
        tiers: Vec<Arc<StorageTier>>,
        cfg: PlacementConfig,
        metrics: Option<Arc<Metrics>>,
    ) -> Result<Arc<Self>> {
        if tiers.is_empty() {
            bail!("placement engine needs at least one shared tier");
        }
        let states = tiers.iter().map(|_| TierState::new()).collect();
        Ok(Arc::new(PlacementEngine {
            states,
            tiers,
            cfg,
            metrics,
            failovers: AtomicU64::new(0),
            breaker_trips: AtomicU64::new(0),
            signals: OnceLock::new(),
        }))
    }

    /// Attach a signals bus: every EWMA health update then also samples
    /// `tier.health.<id>`. One-shot — later calls are ignored (the engine
    /// is shared via `Arc`, so constructor threading would churn every
    /// call site).
    pub fn set_signals(&self, bus: Arc<SignalsBus>) {
        let _ = self.signals.set(bus);
    }

    /// The configured knobs.
    pub fn config(&self) -> &PlacementConfig {
        &self.cfg
    }

    /// The tier pool, in configured (static-priority) order.
    pub fn tiers(&self) -> &[Arc<StorageTier>] {
        &self.tiers
    }

    /// The primary tier (`tiers[0]`): static first choice and metadata
    /// home.
    pub fn primary(&self) -> &Arc<StorageTier> {
        &self.tiers[0]
    }

    /// Find a pool tier by id.
    pub fn tier(&self, id: &str) -> Option<&Arc<StorageTier>> {
        self.tiers.iter().find(|t| t.id() == id)
    }

    /// Flushes served by a tier other than the policy's first choice
    /// (health-driven skips and error retries; policy re-ranking under
    /// fresh observations is adaptation, not failover).
    pub fn failover_count(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    /// Circuit-breaker trips across all tiers.
    pub fn breaker_trip_count(&self) -> u64 {
        self.breaker_trips.load(Ordering::Relaxed)
    }

    /// Health snapshot of one pool tier.
    pub fn health(&self, id: &str) -> Option<TierHealth> {
        let i = self.tiers.iter().position(|t| t.id() == id)?;
        let st = &self.states[i];
        Some(TierHealth {
            id: id.to_string(),
            multiplier: *st.mult.lock().unwrap(),
            consec_errors: st.consec_errors.load(Ordering::Relaxed),
            breaker_open: st.breaker_open.load(Ordering::Relaxed),
            routed_puts: st.routed_puts.load(Ordering::Relaxed),
            routed_bytes: st.routed_bytes.load(Ordering::Relaxed),
            fill: self.tiers[i].fill_fraction(),
        })
    }

    /// Health snapshots for the whole pool, in configured order.
    pub fn health_all(&self) -> Vec<TierHealth> {
        self.tiers
            .iter()
            .filter_map(|t| self.health(t.id()))
            .collect()
    }

    /// Predicted seconds to write `bytes` to tier `i`: the spec's shape
    /// (latency + bytes/bandwidth) scaled by the observed health
    /// multiplier.
    fn service_secs(&self, i: usize, bytes: u64) -> f64 {
        let spec = self.tiers[i].spec();
        let base = spec.latency.as_secs_f64() + bytes as f64 / spec.write_bw.max(1.0);
        base * *self.states[i].mult.lock().unwrap()
    }

    /// Tier indices ranked by the configured policy (best first),
    /// ignoring health/eligibility — the walk in [`Self::put`] applies
    /// those, so a skip can be counted as a failover.
    fn ranked(&self, bytes: u64) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.tiers.len()).collect();
        match self.cfg.policy {
            PlacementPolicy::Static => {}
            PlacementPolicy::FastestEligible => {
                order.sort_by(|&a, &b| {
                    self.service_secs(a, bytes)
                        .partial_cmp(&self.service_secs(b, bytes))
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
            }
            PlacementPolicy::CapacityAware => {
                let score = |i: usize| {
                    // Penalize fill: a tier at 80% costs 5x its service
                    // time, so an emptier-but-slower tier wins before the
                    // fast one runs out entirely.
                    let headroom = (1.0 - self.tiers[i].fill_fraction()).max(1e-3);
                    self.service_secs(i, bytes) / headroom
                };
                order.sort_by(|&a, &b| {
                    score(a)
                        .partial_cmp(&score(b))
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
            }
        }
        order
    }

    /// Is tier `i` eligible for a `bytes`-sized flush right now?
    /// `strict` additionally enforces the capacity-aware watermark and
    /// the open-breaker skip; the relaxed second pass drops both so a
    /// checkpoint is never failed by placement bookkeeping alone.
    fn eligible(&self, i: usize, bytes: u64, strict: bool) -> bool {
        let tier = &self.tiers[i];
        if tier.is_down() || tier.is_read_only() {
            return false;
        }
        if tier.headroom() < bytes {
            return false;
        }
        if !strict {
            return true;
        }
        // Watermark first: a capacity rejection must not consume the
        // breaker's half-open probe allowance below (the probe would be
        // spent without any put being attempted).
        if self.cfg.policy == PlacementPolicy::CapacityAware {
            let fill_after =
                (self.tiers[i].used_bytes().saturating_add(bytes)) as f64
                    / self.tiers[i].spec().capacity.max(1) as f64;
            if fill_after > self.cfg.full_watermark {
                // Skip only while some emptier tier could still take it;
                // the relaxed pass picks it up otherwise.
                return false;
            }
        }
        if self.states[i].breaker_open.load(Ordering::SeqCst) {
            // Half-open: after `breaker_probe_after` skipped routes, the
            // next route is allowed through as the probe.
            let skips = self.states[i].skips.fetch_add(1, Ordering::SeqCst) + 1;
            if skips <= self.cfg.breaker_probe_after {
                return false;
            }
            self.states[i].skips.store(0, Ordering::SeqCst);
        }
        true
    }

    fn observe_success(&self, i: usize, stat: &TransferStat) {
        let spec = self.tiers[i].spec();
        let predicted =
            spec.latency.as_secs_f64() + stat.bytes as f64 / spec.write_bw.max(1.0);
        if predicted > 0.0 {
            let obs = (stat.modeled.as_secs_f64() / predicted).max(1e-3);
            let mut m = self.states[i].mult.lock().unwrap();
            *m = self.cfg.ewma_alpha * obs + (1.0 - self.cfg.ewma_alpha) * *m;
            let mult = *m;
            drop(m);
            if let Some(bus) = self.signals.get() {
                let id = self.tiers[i].id();
                bus.sample(&format!("{SIG_TIER_HEALTH_PREFIX}{id}"), mult);
            }
        }
        self.states[i].consec_errors.store(0, Ordering::SeqCst);
        if self.states[i].breaker_open.swap(false, Ordering::SeqCst) {
            if let Some(m) = &self.metrics {
                m.incr("placement.breaker.closes", 1);
            }
        }
        self.states[i]
            .routed_puts
            .fetch_add(1, Ordering::Relaxed);
        self.states[i]
            .routed_bytes
            .fetch_add(stat.bytes, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            let id = self.tiers[i].id();
            m.incr(&format!("placement.routed.puts.{id}"), 1);
            m.incr(&format!("placement.routed.bytes.{id}"), stat.bytes);
        }
    }

    fn observe_error(&self, i: usize) {
        let errs = self.states[i].consec_errors.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(m) = &self.metrics {
            m.incr("placement.put.errors", 1);
        }
        if errs >= self.cfg.breaker_threshold
            && !self.states[i].breaker_open.swap(true, Ordering::SeqCst)
        {
            self.states[i].skips.store(0, Ordering::SeqCst);
            self.breaker_trips.fetch_add(1, Ordering::Relaxed);
            if let Some(m) = &self.metrics {
                m.incr("placement.breaker.trips", 1);
            }
        }
    }

    /// The routing walk shared by every put flavor: try tiers in policy
    /// order, failing over past down/read-only/full/broken ones, and
    /// record each observed [`TransferStat`] into the health state.
    /// Returns the id of the tier that actually stored the object.
    ///
    /// A strict pass respects the circuit breaker and the capacity
    /// watermark; if nothing serves, a relaxed pass retries every
    /// reachable, writable tier with room — placement bookkeeping alone
    /// never fails a checkpoint. The error returned when *that* fails
    /// carries every attempted tier's failure.
    fn route<F>(&self, bytes: u64, store: F) -> Result<(String, TransferStat)>
    where
        F: Fn(&StorageTier) -> Result<TransferStat>,
    {
        let order = self.ranked(bytes);
        let first_choice = order[0];
        let mut attempted = vec![false; self.tiers.len()];
        let mut errors: Vec<String> = Vec::new();
        for strict in [true, false] {
            for &i in &order {
                // The relaxed pass retries only tiers the strict pass
                // skipped (open breaker, capacity watermark) — a tier
                // that just errored is not hammered twice in one route.
                if attempted[i] || !self.eligible(i, bytes, strict) {
                    continue;
                }
                attempted[i] = true;
                match store(&self.tiers[i]) {
                    Ok(stat) => {
                        self.observe_success(i, &stat);
                        if i != first_choice {
                            self.failovers.fetch_add(1, Ordering::Relaxed);
                            if let Some(m) = &self.metrics {
                                m.incr("placement.failovers", 1);
                            }
                        }
                        return Ok((self.tiers[i].id().to_string(), stat));
                    }
                    Err(e) => {
                        self.observe_error(i);
                        errors.push(format!("{}: {e}", self.tiers[i].id()));
                    }
                }
            }
        }
        if errors.is_empty() {
            bail!(
                "placement: no eligible tier for a {bytes}-byte flush \
                 (all {} tiers down, read-only or full)",
                self.tiers.len()
            );
        }
        bail!("placement: every eligible tier failed: {}", errors.join("; "));
    }

    /// Route one shared-vector flush (see [`Self::route`] semantics).
    pub fn put(&self, key: &str, data: &Arc<Vec<u8>>) -> Result<(String, TransferStat)> {
        self.put_bytes(key, &Bytes::from_arc(Arc::clone(data)))
    }

    /// Route one zero-copy flush: the serving tier shares the refcounted
    /// slice instead of copying it (memory backings) or streams it out
    /// (directory backings).
    pub fn put_bytes(&self, key: &str, data: &Bytes) -> Result<(String, TransferStat)> {
        self.route(data.len() as u64, |t| t.put_bytes(key, data))
    }

    /// Route one scatter-gather flush: `parts` land as a single object on
    /// the chosen tier without being concatenated first (the aggregation
    /// drain path — header, segments and trailing CRC are written as the
    /// pieces they already are).
    pub fn put_gather(&self, key: &str, parts: &[&[u8]]) -> Result<(String, TransferStat)> {
        let bytes: u64 = parts.iter().map(|p| p.len() as u64).sum();
        self.route(bytes, |t| t.put_gather(key, parts))
    }

    /// Tier-agnostic lookup: probe the pool in configured order (down
    /// tiers miss) and return the first hit plus the serving tier's id.
    pub fn get(&self, key: &str) -> Option<(Vec<u8>, TransferStat, String)> {
        for t in &self.tiers {
            if let Some((data, stat)) = t.get(key) {
                return Some((data, stat, t.id().to_string()));
            }
        }
        None
    }

    /// Fast-path lookup on a recorded destination tier; falls back to
    /// the full probe when the tier is unknown, down or misses (the
    /// object may have been re-flushed elsewhere after a failover).
    pub fn get_recorded(
        &self,
        dest: Option<&str>,
        key: &str,
    ) -> Option<(Vec<u8>, TransferStat, String)> {
        if let Some(id) = dest {
            if let Some(t) = self.tier(id) {
                if let Some((data, stat)) = t.get(key) {
                    return Some((data, stat, id.to_string()));
                }
            }
        }
        self.get(key)
    }

    /// Delete an object from every pool tier (GC is tier-agnostic once
    /// flushes can land anywhere). Returns how many tiers held it.
    pub fn delete(&self, key: &str) -> usize {
        self.tiers.iter().filter(|t| t.delete(key)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{presets, TimeMode};

    fn pool(pfs_bw: f64, bb_bw: f64) -> Vec<Arc<StorageTier>> {
        vec![
            StorageTier::memory(presets::pfs(u64::MAX / 2, pfs_bw), TimeMode::Model),
            StorageTier::memory(presets::burst_buffer(u64::MAX / 2, bb_bw), TimeMode::Model),
        ]
    }

    fn engine(policy: PlacementPolicy, tiers: Vec<Arc<StorageTier>>) -> Arc<PlacementEngine> {
        let cfg = PlacementConfig {
            enabled: true,
            policy,
            ..Default::default()
        };
        PlacementEngine::new(tiers, cfg, None).unwrap()
    }

    fn payload(n: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![7u8; n])
    }

    #[test]
    fn static_routes_to_primary() {
        let e = engine(PlacementPolicy::Static, pool(5e9, 20e9));
        let (dest, _) = e.put("k1", &payload(1 << 20)).unwrap();
        assert_eq!(dest, "pfs", "static ignores the faster burst buffer");
        assert_eq!(e.failover_count(), 0);
    }

    #[test]
    fn fastest_eligible_picks_best_service_time() {
        let e = engine(PlacementPolicy::FastestEligible, pool(5e9, 20e9));
        let (dest, _) = e.put("k1", &payload(1 << 20)).unwrap();
        assert_eq!(dest, "burst-buffer");
        assert_eq!(e.failover_count(), 0, "policy choice is not a failover");
    }

    #[test]
    fn down_primary_fails_over() {
        let tiers = pool(5e9, 20e9);
        tiers[0].set_down(true);
        let e = engine(PlacementPolicy::Static, tiers);
        let (dest, _) = e.put("k1", &payload(4096)).unwrap();
        assert_eq!(dest, "burst-buffer");
        assert_eq!(e.failover_count(), 1);
    }

    #[test]
    fn read_only_primary_fails_over_but_still_serves_reads() {
        let tiers = pool(5e9, 20e9);
        let e = engine(PlacementPolicy::Static, tiers);
        e.put("old", &payload(64)).unwrap();
        e.primary().set_read_only(true);
        let (dest, _) = e.put("new", &payload(64)).unwrap();
        assert_eq!(dest, "burst-buffer");
        // The old object still reads back from the read-only primary.
        let (_, _, served) = e.get("old").unwrap();
        assert_eq!(served, "pfs");
        let (_, _, served) = e.get_recorded(Some("burst-buffer"), "new").unwrap();
        assert_eq!(served, "burst-buffer");
    }

    #[test]
    fn full_tier_fails_over() {
        let tiers = vec![
            StorageTier::memory(presets::pfs(1 << 10, 5e9), TimeMode::Model),
            StorageTier::memory(presets::burst_buffer(u64::MAX / 2, 20e9), TimeMode::Model),
        ];
        let e = engine(PlacementPolicy::Static, tiers);
        let (dest, _) = e.put("big", &payload(1 << 20)).unwrap();
        assert_eq!(dest, "burst-buffer", "flush larger than primary headroom");
        assert_eq!(e.failover_count(), 1);
    }

    #[test]
    fn degradation_moves_adaptive_routing() {
        let e = engine(PlacementPolicy::FastestEligible, pool(5e9, 20e9));
        let (dest, _) = e.put("k1", &payload(1 << 20)).unwrap();
        assert_eq!(dest, "burst-buffer");
        // Degrade the burst buffer hard; a couple of observations push
        // its multiplier past the point where the PFS wins.
        e.tier("burst-buffer").unwrap().set_degraded(64.0);
        let mut dests = Vec::new();
        for i in 0..6 {
            let (d, _) = e.put(&format!("k{i}"), &payload(1 << 20)).unwrap();
            dests.push(d);
        }
        assert_eq!(
            dests.last().map(String::as_str),
            Some("pfs"),
            "routing must adapt away from the degraded tier: {dests:?}"
        );
        assert!(e.health("burst-buffer").unwrap().multiplier > 4.0);
    }

    #[test]
    fn signals_bus_samples_tier_health_on_observations() {
        let e = engine(PlacementPolicy::Static, pool(5e9, 20e9));
        let bus = SignalsBus::new(16);
        e.set_signals(Arc::clone(&bus));
        for i in 0..3 {
            e.put(&format!("k{i}"), &payload(1 << 16)).unwrap();
        }
        let view = bus.view();
        let series = view
            .series(&format!("{SIG_TIER_HEALTH_PREFIX}pfs"))
            .expect("routed tier sampled");
        assert_eq!(series.points.len(), 3);
        assert!(series.points.iter().all(|p| p.value > 0.0));
        // A second set_signals is a no-op — the first bus keeps receiving.
        e.set_signals(SignalsBus::new(16));
        e.put("k-extra", &payload(1 << 16)).unwrap();
        let view = bus.view();
        let series = view.series(&format!("{SIG_TIER_HEALTH_PREFIX}pfs")).unwrap();
        assert_eq!(series.points.len(), 4);
    }

    #[test]
    fn breaker_opens_after_consecutive_errors_and_probe_recovers() {
        // Down/read-only/full are eligibility *skips*, not errors, so the
        // breaker state machine is driven through its observe hooks here
        // (the error path itself is covered by the failover tests).
        let e = engine(PlacementPolicy::Static, pool(5e9, 20e9));
        for _ in 0..3 {
            e.observe_error(0);
        }
        assert!(e.health("pfs").unwrap().breaker_open);
        assert_eq!(e.breaker_trip_count(), 1);
        // While open, strict eligibility skips `breaker_probe_after`
        // routes, then lets the next one through as the probe.
        let mut skipped = 0;
        for _ in 0..e.config().breaker_probe_after {
            if !e.eligible(0, 64, true) {
                skipped += 1;
            }
        }
        assert_eq!(skipped, e.config().breaker_probe_after);
        assert!(e.eligible(0, 64, true), "probe allowed after the pacing window");
        // A successful probe closes the breaker.
        let stat = e.tiers()[0].put_shared("probe", &payload(64)).unwrap();
        e.observe_success(0, &stat);
        assert!(!e.health("pfs").unwrap().breaker_open);
        assert_eq!(e.health("pfs").unwrap().consec_errors, 0);
    }

    #[test]
    fn capacity_aware_prefers_headroom() {
        // Fast-but-tiny NVMe-class tier vs slower-but-huge PFS: once the
        // fast tier is nearly full, capacity-aware routes to the PFS
        // while fastest-eligible would keep hammering the full one.
        let small = StorageTier::memory(
            presets::burst_buffer(1 << 20, 20e9),
            TimeMode::Model,
        );
        let big = StorageTier::memory(presets::pfs(u64::MAX / 2, 5e9), TimeMode::Model);
        let e = engine(PlacementPolicy::CapacityAware, vec![small, big]);
        // Fill the small tier past the watermark.
        e.tiers()[0].put("fill", &vec![0u8; 1015 << 10]).unwrap();
        let (dest, _) = e.put("k", &payload(8 << 10)).unwrap();
        assert_eq!(dest, "pfs", "watermarked tier must be skipped");
    }

    #[test]
    fn all_tiers_down_is_an_error() {
        let tiers = pool(5e9, 20e9);
        tiers[0].set_down(true);
        tiers[1].set_down(true);
        let e = engine(PlacementPolicy::Static, tiers);
        let err = e.put("k", &payload(64)).unwrap_err().to_string();
        assert!(err.contains("no eligible tier"), "{err}");
    }

    #[test]
    fn get_probes_all_tiers() {
        let e = engine(PlacementPolicy::Static, pool(5e9, 20e9));
        e.tiers()[1].put("only-bb", b"x").unwrap();
        let (_, _, served) = e.get("only-bb").unwrap();
        assert_eq!(served, "burst-buffer");
        assert!(e.get("missing").is_none());
        // Recorded-destination miss falls back to the probe.
        let (_, _, served) = e.get_recorded(Some("pfs"), "only-bb").unwrap();
        assert_eq!(served, "burst-buffer");
    }

    #[test]
    fn delete_reaches_every_tier() {
        let e = engine(PlacementPolicy::Static, pool(5e9, 20e9));
        e.tiers()[0].put("k", b"1").unwrap();
        e.tiers()[1].put("k", b"2").unwrap();
        assert_eq!(e.delete("k"), 2);
        assert!(e.get("k").is_none());
    }
}
