//! Heterogeneous storage substrate: tier models, contention, presets,
//! adaptive [`placement`] and the per-cluster [`StorageFabric`].

pub mod contention;
pub mod placement;
pub mod presets;
pub mod tier;

pub use placement::{PlacementConfig, PlacementEngine, PlacementPolicy, TierHealth};
pub use tier::{FailureDomain, StorageTier, TierKind, TierSpec, TimeMode, TransferStat};

use anyhow::{bail, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// One configured extra shared tier (the JSON `fabric.tiers` array): a
/// second burst buffer, a scratch PFS, a KV pool... The spec's latency
/// shape derives from the kind's preset; id, bandwidth, capacity and the
/// optional directory backing come from the definition.
#[derive(Clone, Debug)]
pub struct TierDef {
    /// Unique tier id (`VelocConfig::validate` rejects duplicates and
    /// ids colliding with the built-in tiers).
    pub id: String,
    /// Shared tier kind: `burst-buffer`, `pfs` or `kv-store` (node-local
    /// kinds are per-node and cannot be declared here).
    pub kind: TierKind,
    /// Aggregate write bandwidth in bytes/s.
    pub write_bw: f64,
    /// Usable capacity in bytes.
    pub capacity: u64,
    /// Directory backing (real files, e.g. a tmpfs or scratch mount);
    /// in-memory when absent. Overlapping mounts are rejected by
    /// `VelocConfig::validate`.
    pub mount: Option<PathBuf>,
}

impl TierDef {
    /// The full [`TierSpec`] this definition materializes: the kind's
    /// preset (latency, read/write ratio, failure domain) resized to the
    /// declared bandwidth/capacity, carrying the declared id.
    pub fn spec(&self) -> Result<TierSpec> {
        let spec = match self.kind {
            TierKind::BurstBuffer => presets::burst_buffer(self.capacity, self.write_bw),
            TierKind::Pfs => presets::pfs(self.capacity, self.write_bw),
            TierKind::KvStore => presets::kv_store(self.capacity, self.write_bw),
            other => bail!(
                "fabric.tiers entry {:?}: kind {} is node-local; only shared \
                 kinds (burst-buffer, pfs, kv-store) can be declared",
                self.id,
                other.name()
            ),
        };
        Ok(spec.with_id(&self.id))
    }
}

/// Configuration for building a fabric; all bandwidths in bytes/s.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    /// Simulated node count.
    pub nodes: usize,
    /// Per-node DRAM staging capacity.
    pub dram_capacity: u64,
    /// Per-node NVMe capacity.
    pub nvme_capacity: u64,
    /// Per-node SATA-SSD capacity.
    pub ssd_capacity: u64,
    /// Whether nodes have the NVMe level at all (heterogeneity knob).
    pub with_nvme: bool,
    /// Whether nodes have the SSD level at all.
    pub with_ssd: bool,
    /// Provision the shared burst buffer.
    pub with_burst_buffer: bool,
    /// Provision the shared KV object store.
    pub with_kv: bool,
    /// Aggregate PFS write bandwidth.
    pub pfs_bw: f64,
    /// Aggregate burst-buffer write bandwidth.
    pub bb_bw: f64,
    /// Aggregate KV-store write bandwidth.
    pub kv_bw: f64,
    /// How modeled durations translate to wall-clock time.
    pub time_mode: TimeMode,
    /// When set, the PFS tier is backed by a real directory (tmpfs) so that
    /// checkpoints genuinely survive the process; otherwise in-memory.
    pub pfs_dir: Option<PathBuf>,
    /// Extra shared tiers beyond the built-in PFS/burst-buffer/KV trio
    /// (the placement engine routes across all of them).
    pub tiers: Vec<TierDef>,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            nodes: 4,
            dram_capacity: 1 << 30,
            nvme_capacity: 8 << 30,
            ssd_capacity: 32 << 30,
            with_nvme: true,
            with_ssd: true,
            with_burst_buffer: false,
            with_kv: false,
            pfs_bw: 5.0e9,
            bb_bw: 20.0e9,
            kv_bw: 10.0e9,
            time_mode: TimeMode::Model,
            pfs_dir: None,
            tiers: Vec::new(),
        }
    }
}

/// All storage of one simulated cluster: node-local tier lists (fastest
/// first) plus the shared system tiers.
pub struct StorageFabric {
    /// `local[node]` = ordered local tiers of that node (fast -> slow).
    local: Vec<Vec<Arc<StorageTier>>>,
    burst_buffer: Option<Arc<StorageTier>>,
    pfs: Arc<StorageTier>,
    kv: Option<Arc<StorageTier>>,
    /// Configured extra shared tiers, in declaration order.
    extras: Vec<Arc<StorageTier>>,
}

impl StorageFabric {
    /// Materialize the fabric a configuration describes.
    pub fn build(cfg: &FabricConfig) -> Result<Self> {
        let mut local = Vec::with_capacity(cfg.nodes);
        for _ in 0..cfg.nodes {
            let mut tiers: Vec<Arc<StorageTier>> = vec![StorageTier::memory(
                presets::dram(cfg.dram_capacity),
                cfg.time_mode,
            )];
            if cfg.with_nvme {
                tiers.push(StorageTier::memory(
                    presets::nvme(cfg.nvme_capacity),
                    cfg.time_mode,
                ));
            }
            if cfg.with_ssd {
                tiers.push(StorageTier::memory(
                    presets::ssd(cfg.ssd_capacity),
                    cfg.time_mode,
                ));
            }
            local.push(tiers);
        }
        let burst_buffer = if cfg.with_burst_buffer {
            Some(StorageTier::memory(
                presets::burst_buffer(u64::MAX / 2, cfg.bb_bw),
                cfg.time_mode,
            ))
        } else {
            None
        };
        let pfs_spec = presets::pfs(u64::MAX / 2, cfg.pfs_bw);
        let pfs = match &cfg.pfs_dir {
            Some(dir) => StorageTier::dir(pfs_spec, dir.clone(), cfg.time_mode)?,
            None => StorageTier::memory(pfs_spec, cfg.time_mode),
        };
        let kv = if cfg.with_kv {
            Some(StorageTier::memory(
                presets::kv_store(u64::MAX / 2, cfg.kv_bw),
                cfg.time_mode,
            ))
        } else {
            None
        };
        let mut extras = Vec::with_capacity(cfg.tiers.len());
        for def in &cfg.tiers {
            let spec = def.spec()?;
            let tier = match &def.mount {
                Some(dir) => StorageTier::dir(spec, dir.clone(), cfg.time_mode)?,
                None => StorageTier::memory(spec, cfg.time_mode),
            };
            extras.push(tier);
        }
        Ok(StorageFabric {
            local,
            burst_buffer,
            pfs,
            kv,
            extras,
        })
    }

    /// Simulated node count.
    pub fn nodes(&self) -> usize {
        self.local.len()
    }

    /// Ordered local tiers (fastest first) of a node.
    pub fn local_tiers(&self, node: usize) -> &[Arc<StorageTier>] {
        &self.local[node]
    }

    /// The parallel file system (always present).
    pub fn pfs(&self) -> &Arc<StorageTier> {
        &self.pfs
    }

    /// The shared burst buffer, when provisioned.
    pub fn burst_buffer(&self) -> Option<&Arc<StorageTier>> {
        self.burst_buffer.as_ref()
    }

    /// The shared KV object store, when provisioned.
    pub fn kv(&self) -> Option<&Arc<StorageTier>> {
        self.kv.as_ref()
    }

    /// Configured extra shared tiers, in declaration order.
    pub fn extras(&self) -> &[Arc<StorageTier>] {
        &self.extras
    }

    /// Every cluster-visible shared tier: the PFS, then the burst buffer,
    /// the KV store and the configured extras, in that order. This is the
    /// candidate pool the placement engine routes over and the probe set
    /// for tier-agnostic restores.
    pub fn shared_tiers(&self) -> Vec<Arc<StorageTier>> {
        let mut v = vec![Arc::clone(&self.pfs)];
        if let Some(bb) = &self.burst_buffer {
            v.push(Arc::clone(bb));
        }
        if let Some(kv) = &self.kv {
            v.push(Arc::clone(kv));
        }
        v.extend(self.extras.iter().cloned());
        v
    }

    /// Find a shared tier by its spec id.
    pub fn shared_tier(&self, id: &str) -> Option<Arc<StorageTier>> {
        self.shared_tiers().into_iter().find(|t| t.id() == id)
    }

    /// Apply a node failure: wipe every tier whose failure domain is the
    /// node (paper §2: lighter levels do not survive their domain).
    pub fn fail_node(&self, node: usize) {
        for t in &self.local[node] {
            if t.spec().failure_domain == FailureDomain::Node {
                t.wipe();
            }
        }
    }

    /// Apply a full-system failure: everything non-persistent is lost.
    pub fn fail_system(&self) {
        for node in &self.local {
            for t in node {
                if t.spec().failure_domain != FailureDomain::Persistent {
                    t.wipe();
                }
            }
        }
        if let Some(bb) = &self.burst_buffer {
            if bb.spec().failure_domain != FailureDomain::Persistent {
                bb.wipe();
            }
        }
        for t in &self.extras {
            if t.spec().failure_domain != FailureDomain::Persistent {
                t.wipe();
            }
        }
    }

    /// Total bytes held across all tiers (diagnostics).
    pub fn total_used(&self) -> u64 {
        let mut sum: u64 = self
            .local
            .iter()
            .flatten()
            .map(|t| t.used_bytes())
            .sum();
        sum += self.pfs.used_bytes();
        if let Some(bb) = &self.burst_buffer {
            sum += bb.used_bytes();
        }
        if let Some(kv) = &self.kv {
            sum += kv.used_bytes();
        }
        sum += self.extras.iter().map(|t| t.used_bytes()).sum::<u64>();
        sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric() -> StorageFabric {
        StorageFabric::build(&FabricConfig {
            nodes: 2,
            with_kv: true,
            with_burst_buffer: true,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn builds_expected_topology() {
        let f = fabric();
        assert_eq!(f.nodes(), 2);
        assert_eq!(f.local_tiers(0).len(), 3); // dram, nvme, ssd
        assert_eq!(f.local_tiers(0)[0].kind(), TierKind::Dram);
        assert!(f.kv().is_some());
        assert!(f.burst_buffer().is_some());
    }

    #[test]
    fn node_failure_wipes_local_only() {
        let f = fabric();
        f.local_tiers(0)[0].put("x", b"1").unwrap();
        f.local_tiers(1)[0].put("y", b"2").unwrap();
        f.pfs().put("z", b"3").unwrap();
        f.fail_node(0);
        assert!(!f.local_tiers(0)[0].exists("x"));
        assert!(f.local_tiers(1)[0].exists("y"));
        assert!(f.pfs().exists("z"));
    }

    #[test]
    fn system_failure_spares_persistent() {
        let f = fabric();
        f.local_tiers(0)[0].put("x", b"1").unwrap();
        f.burst_buffer().unwrap().put("b", b"2").unwrap();
        f.pfs().put("z", b"3").unwrap();
        f.kv().unwrap().put("k", b"4").unwrap();
        f.fail_system();
        assert!(!f.local_tiers(0)[0].exists("x"));
        assert!(!f.burst_buffer().unwrap().exists("b"));
        assert!(f.pfs().exists("z"));
        assert!(f.kv().unwrap().exists("k"));
    }

    #[test]
    fn total_used_accounts_everything() {
        let f = fabric();
        f.local_tiers(0)[0].put("x", &vec![0u8; 10]).unwrap();
        f.pfs().put("z", &vec![0u8; 5]).unwrap();
        assert_eq!(f.total_used(), 15);
    }

    #[test]
    fn shared_tiers_ordered_and_findable_by_id() {
        let f = fabric();
        let ids: Vec<String> = f
            .shared_tiers()
            .iter()
            .map(|t| t.id().to_string())
            .collect();
        assert_eq!(ids, vec!["pfs", "burst-buffer", "kv-store"]);
        assert_eq!(f.shared_tier("burst-buffer").unwrap().kind(), TierKind::BurstBuffer);
        assert!(f.shared_tier("nope").is_none());
    }

    #[test]
    fn extra_tiers_built_from_defs() {
        let f = StorageFabric::build(&FabricConfig {
            nodes: 2,
            tiers: vec![TierDef {
                id: "bb-scratch".to_string(),
                kind: TierKind::BurstBuffer,
                write_bw: 9.0e9,
                capacity: 1 << 30,
                mount: None,
            }],
            ..Default::default()
        })
        .unwrap();
        let t = f.shared_tier("bb-scratch").unwrap();
        assert_eq!(t.kind(), TierKind::BurstBuffer);
        assert_eq!(t.spec().write_bw, 9.0e9);
        t.put("x", &vec![1u8; 8]).unwrap();
        assert_eq!(f.total_used(), 8);
        // A burst-buffer-class extra dies with the system, like the
        // built-in one.
        f.fail_system();
        assert!(!t.exists("x"));
    }

    #[test]
    fn node_local_tier_defs_rejected() {
        let def = TierDef {
            id: "bad".to_string(),
            kind: TierKind::Nvme,
            write_bw: 1e9,
            capacity: 1 << 30,
            mount: None,
        };
        assert!(def.spec().is_err());
    }
}
