//! Heterogeneous storage substrate: tier models, contention, presets and
//! the per-cluster [`StorageFabric`].

pub mod contention;
pub mod presets;
pub mod tier;

pub use tier::{FailureDomain, StorageTier, TierKind, TierSpec, TimeMode, TransferStat};

use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// Configuration for building a fabric; all bandwidths in bytes/s.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    pub nodes: usize,
    /// Per-node DRAM staging capacity.
    pub dram_capacity: u64,
    pub nvme_capacity: u64,
    pub ssd_capacity: u64,
    /// Whether nodes have the NVMe / SSD levels at all (heterogeneity knob).
    pub with_nvme: bool,
    pub with_ssd: bool,
    pub with_burst_buffer: bool,
    pub with_kv: bool,
    pub pfs_bw: f64,
    pub bb_bw: f64,
    pub kv_bw: f64,
    pub time_mode: TimeMode,
    /// When set, the PFS tier is backed by a real directory (tmpfs) so that
    /// checkpoints genuinely survive the process; otherwise in-memory.
    pub pfs_dir: Option<PathBuf>,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            nodes: 4,
            dram_capacity: 1 << 30,
            nvme_capacity: 8 << 30,
            ssd_capacity: 32 << 30,
            with_nvme: true,
            with_ssd: true,
            with_burst_buffer: false,
            with_kv: false,
            pfs_bw: 5.0e9,
            bb_bw: 20.0e9,
            kv_bw: 10.0e9,
            time_mode: TimeMode::Model,
            pfs_dir: None,
        }
    }
}

/// All storage of one simulated cluster: node-local tier lists (fastest
/// first) plus the shared system tiers.
pub struct StorageFabric {
    /// `local[node]` = ordered local tiers of that node (fast -> slow).
    local: Vec<Vec<Arc<StorageTier>>>,
    burst_buffer: Option<Arc<StorageTier>>,
    pfs: Arc<StorageTier>,
    kv: Option<Arc<StorageTier>>,
}

impl StorageFabric {
    pub fn build(cfg: &FabricConfig) -> Result<Self> {
        let mut local = Vec::with_capacity(cfg.nodes);
        for _ in 0..cfg.nodes {
            let mut tiers: Vec<Arc<StorageTier>> = vec![StorageTier::memory(
                presets::dram(cfg.dram_capacity),
                cfg.time_mode,
            )];
            if cfg.with_nvme {
                tiers.push(StorageTier::memory(
                    presets::nvme(cfg.nvme_capacity),
                    cfg.time_mode,
                ));
            }
            if cfg.with_ssd {
                tiers.push(StorageTier::memory(
                    presets::ssd(cfg.ssd_capacity),
                    cfg.time_mode,
                ));
            }
            local.push(tiers);
        }
        let burst_buffer = if cfg.with_burst_buffer {
            Some(StorageTier::memory(
                presets::burst_buffer(u64::MAX / 2, cfg.bb_bw),
                cfg.time_mode,
            ))
        } else {
            None
        };
        let pfs_spec = presets::pfs(u64::MAX / 2, cfg.pfs_bw);
        let pfs = match &cfg.pfs_dir {
            Some(dir) => StorageTier::dir(pfs_spec, dir.clone(), cfg.time_mode)?,
            None => StorageTier::memory(pfs_spec, cfg.time_mode),
        };
        let kv = if cfg.with_kv {
            Some(StorageTier::memory(
                presets::kv_store(u64::MAX / 2, cfg.kv_bw),
                cfg.time_mode,
            ))
        } else {
            None
        };
        Ok(StorageFabric {
            local,
            burst_buffer,
            pfs,
            kv,
        })
    }

    pub fn nodes(&self) -> usize {
        self.local.len()
    }

    /// Ordered local tiers (fastest first) of a node.
    pub fn local_tiers(&self, node: usize) -> &[Arc<StorageTier>] {
        &self.local[node]
    }

    pub fn pfs(&self) -> &Arc<StorageTier> {
        &self.pfs
    }

    pub fn burst_buffer(&self) -> Option<&Arc<StorageTier>> {
        self.burst_buffer.as_ref()
    }

    pub fn kv(&self) -> Option<&Arc<StorageTier>> {
        self.kv.as_ref()
    }

    /// Apply a node failure: wipe every tier whose failure domain is the
    /// node (paper §2: lighter levels do not survive their domain).
    pub fn fail_node(&self, node: usize) {
        for t in &self.local[node] {
            if t.spec().failure_domain == FailureDomain::Node {
                t.wipe();
            }
        }
    }

    /// Apply a full-system failure: everything non-persistent is lost.
    pub fn fail_system(&self) {
        for node in &self.local {
            for t in node {
                if t.spec().failure_domain != FailureDomain::Persistent {
                    t.wipe();
                }
            }
        }
        if let Some(bb) = &self.burst_buffer {
            if bb.spec().failure_domain != FailureDomain::Persistent {
                bb.wipe();
            }
        }
    }

    /// Total bytes held across all tiers (diagnostics).
    pub fn total_used(&self) -> u64 {
        let mut sum: u64 = self
            .local
            .iter()
            .flatten()
            .map(|t| t.used_bytes())
            .sum();
        sum += self.pfs.used_bytes();
        if let Some(bb) = &self.burst_buffer {
            sum += bb.used_bytes();
        }
        if let Some(kv) = &self.kv {
            sum += kv.used_bytes();
        }
        sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric() -> StorageFabric {
        StorageFabric::build(&FabricConfig {
            nodes: 2,
            with_kv: true,
            with_burst_buffer: true,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn builds_expected_topology() {
        let f = fabric();
        assert_eq!(f.nodes(), 2);
        assert_eq!(f.local_tiers(0).len(), 3); // dram, nvme, ssd
        assert_eq!(f.local_tiers(0)[0].kind(), TierKind::Dram);
        assert!(f.kv().is_some());
        assert!(f.burst_buffer().is_some());
    }

    #[test]
    fn node_failure_wipes_local_only() {
        let f = fabric();
        f.local_tiers(0)[0].put("x", b"1").unwrap();
        f.local_tiers(1)[0].put("y", b"2").unwrap();
        f.pfs().put("z", b"3").unwrap();
        f.fail_node(0);
        assert!(!f.local_tiers(0)[0].exists("x"));
        assert!(f.local_tiers(1)[0].exists("y"));
        assert!(f.pfs().exists("z"));
    }

    #[test]
    fn system_failure_spares_persistent() {
        let f = fabric();
        f.local_tiers(0)[0].put("x", b"1").unwrap();
        f.burst_buffer().unwrap().put("b", b"2").unwrap();
        f.pfs().put("z", b"3").unwrap();
        f.kv().unwrap().put("k", b"4").unwrap();
        f.fail_system();
        assert!(!f.local_tiers(0)[0].exists("x"));
        assert!(!f.burst_buffer().unwrap().exists("b"));
        assert!(f.pfs().exists("z"));
        assert!(f.kv().unwrap().exists("k"));
    }

    #[test]
    fn total_used_accounts_everything() {
        let f = fabric();
        f.local_tiers(0)[0].put("x", &vec![0u8; 10]).unwrap();
        f.pfs().put("z", &vec![0u8; 5]).unwrap();
        assert_eq!(f.total_used(), 15);
    }
}
