//! Tier presets loosely calibrated to published Summit-class numbers
//! (scaled; see DESIGN.md §Reproduction bands / substitutions). Absolute
//! values are not the point — the *ratios* between levels are what drive
//! VeloC's behaviour, and those follow the machines the paper names:
//! DRAM >> NVMe >> SSD >> burst buffer > PFS-per-node under contention.

use super::tier::{FailureDomain, TierKind, TierSpec};
use std::time::Duration;

/// Node-local DRAM staging area (the level-1 "in-memory checkpoint" of the
/// 224 TB/s Summit headline: ~10 GB/s memcpy-class bandwidth per rank).
pub fn dram(capacity: u64) -> TierSpec {
    TierSpec {
        id: "dram".to_string(),
        kind: TierKind::Dram,
        write_bw: 10.0e9,
        read_bw: 12.0e9,
        latency: Duration::from_micros(1),
        capacity,
        shared: false,
        failure_domain: FailureDomain::Node,
    }
}

/// Node-local NVMe (Summit's 1.6 TB Samsung drives: ~2.1 GB/s write).
/// Shared among the ranks of one node.
pub fn nvme(capacity: u64) -> TierSpec {
    TierSpec {
        id: "nvme".to_string(),
        kind: TierKind::Nvme,
        write_bw: 2.1e9,
        read_bw: 5.5e9,
        latency: Duration::from_micros(80),
        capacity,
        shared: true,
        failure_domain: FailureDomain::Node,
    }
}

/// Node-local SATA SSD class device (the "slower but bigger" local level
/// that makes tier selection non-obvious under concurrency, paper [4]).
pub fn ssd(capacity: u64) -> TierSpec {
    TierSpec {
        id: "ssd".to_string(),
        kind: TierKind::Ssd,
        write_bw: 0.5e9,
        read_bw: 1.0e9,
        latency: Duration::from_micros(120),
        capacity,
        shared: true,
        failure_domain: FailureDomain::Node,
    }
}

/// Shared burst buffer (aggregate bandwidth across the whole allocation).
pub fn burst_buffer(capacity: u64, aggregate_bw: f64) -> TierSpec {
    TierSpec {
        id: "burst-buffer".to_string(),
        kind: TierKind::BurstBuffer,
        write_bw: aggregate_bw,
        read_bw: aggregate_bw * 1.2,
        latency: Duration::from_micros(250),
        capacity,
        shared: true,
        failure_domain: FailureDomain::System,
    }
}

/// Lustre-like parallel file system: persistent, aggregate-bandwidth
/// shared by every rank, high per-op latency.
pub fn pfs(capacity: u64, aggregate_bw: f64) -> TierSpec {
    TierSpec {
        id: "pfs".to_string(),
        kind: TierKind::Pfs,
        write_bw: aggregate_bw,
        read_bw: aggregate_bw * 1.5,
        latency: Duration::from_millis(2),
        capacity,
        shared: true,
        failure_domain: FailureDomain::Persistent,
    }
}

/// DAOS-like key-value object store (paper §4): persistent like the PFS
/// but with much lower per-op latency and better small-object behaviour.
pub fn kv_store(capacity: u64, aggregate_bw: f64) -> TierSpec {
    TierSpec {
        id: "kv-store".to_string(),
        kind: TierKind::KvStore,
        write_bw: aggregate_bw,
        read_bw: aggregate_bw * 1.3,
        latency: Duration::from_micros(30),
        capacity,
        shared: true,
        failure_domain: FailureDomain::Persistent,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_ordering_holds() {
        // The defining property: each level is slower than the previous.
        let d = dram(1);
        let n = nvme(1);
        let s = ssd(1);
        assert!(d.write_bw > n.write_bw);
        assert!(n.write_bw > s.write_bw);
        assert!(d.latency < n.latency);
        assert!(n.latency < s.latency);
    }

    #[test]
    fn persistency_domains() {
        assert_eq!(dram(1).failure_domain, FailureDomain::Node);
        assert_eq!(pfs(1, 1e9).failure_domain, FailureDomain::Persistent);
        assert_eq!(kv_store(1, 1e9).failure_domain, FailureDomain::Persistent);
        assert_eq!(
            burst_buffer(1, 1e9).failure_domain,
            FailureDomain::System
        );
    }

    #[test]
    fn kv_latency_beats_pfs() {
        assert!(kv_store(1, 1e9).latency < pfs(1, 1e9).latency);
    }
}
