//! Shared-bandwidth contention model.
//!
//! Shared tiers (PFS, burst buffer, KV store) fair-share their aggregate
//! bandwidth across concurrent transfers — the effect that makes direct
//! PFS checkpointing collapse under write concurrency (paper §1: "high
//! write concurrency that overwhelms the I/O bandwidth").
//!
//! Model: a transfer of `B` bytes that observes `n` concurrent transfers
//! (including itself) is charged `latency + B / (bw / n)`. This is the
//! fair-share-at-start approximation of progressive filling: exact for
//! synchronized bursts (the checkpoint pattern we care about) and within a
//! small factor for staggered arrivals. The DES in `interval::simulator`
//! uses the same formula, so real-runtime and extrapolated numbers agree.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Fair-shared bandwidth pool of one tier (write and read lanes).
#[derive(Debug)]
pub struct BandwidthPool {
    write_bw: f64,
    read_bw: f64,
    active: AtomicUsize,
}

impl BandwidthPool {
    /// Pool with the given aggregate bandwidths (bytes/s).
    pub fn new(write_bw: f64, read_bw: f64) -> Self {
        assert!(write_bw > 0.0 && read_bw > 0.0);
        BandwidthPool {
            write_bw,
            read_bw,
            active: AtomicUsize::new(0),
        }
    }

    /// Concurrent transfers currently charged to the pool.
    pub fn active(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    fn charge(&self, bytes: u64, latency: Duration, bw: f64, shared: bool) -> Duration {
        let n = self.active.fetch_add(1, Ordering::SeqCst) + 1;
        let effective = if shared { bw / n as f64 } else { bw };
        let secs = latency.as_secs_f64() + bytes as f64 / effective;
        self.active.fetch_sub(1, Ordering::SeqCst);
        Duration::from_secs_f64(secs)
    }

    /// Model a write; returns the charged duration.
    pub fn write(&self, bytes: u64, latency: Duration, shared: bool) -> Duration {
        self.charge(bytes, latency, self.write_bw, shared)
    }

    /// Model a read; returns the charged duration.
    pub fn read(&self, bytes: u64, latency: Duration, shared: bool) -> Duration {
        self.charge(bytes, latency, self.read_bw, shared)
    }

    /// RAII guard marking a long-lived transfer as active so that *other*
    /// transfers see the contention (used by the async flush path, whose
    /// transfers span many model steps).
    pub fn hold(&self) -> ActiveGuard<'_> {
        self.active.fetch_add(1, Ordering::SeqCst);
        ActiveGuard { pool: self }
    }
}

/// RAII guard returned by [`BandwidthPool::hold`].
pub struct ActiveGuard<'a> {
    pool: &'a BandwidthPool,
}

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.pool.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Closed-form fair-share duration for `writers` synchronized writers each
/// moving `bytes` over a `bw` pool — used by benches and the DES to compute
/// expected values without touching a live pool.
pub fn fair_share_secs(bytes: u64, bw: f64, writers: usize, latency: Duration) -> f64 {
    latency.as_secs_f64() + bytes as f64 * writers as f64 / bw
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unshared_ignores_concurrency() {
        let p = BandwidthPool::new(1e9, 1e9);
        let _g1 = p.hold();
        let _g2 = p.hold();
        let d = p.write(1_000_000, Duration::ZERO, false);
        assert!((d.as_secs_f64() - 1e-3).abs() < 1e-6);
    }

    #[test]
    fn shared_divides_bandwidth() {
        let p = BandwidthPool::new(1e9, 1e9);
        let base = p.write(1_000_000, Duration::ZERO, true).as_secs_f64();
        let _g1 = p.hold();
        let _g2 = p.hold();
        let contended = p.write(1_000_000, Duration::ZERO, true).as_secs_f64();
        assert!((contended / base - 3.0).abs() < 0.01, "{contended} vs {base}");
    }

    #[test]
    fn guard_releases_on_drop() {
        let p = BandwidthPool::new(1e9, 1e9);
        {
            let _g = p.hold();
            assert_eq!(p.active(), 1);
        }
        assert_eq!(p.active(), 0);
    }

    #[test]
    fn latency_added() {
        let p = BandwidthPool::new(1e9, 1e9);
        let d = p.write(0, Duration::from_millis(5), true);
        assert!((d.as_secs_f64() - 5e-3).abs() < 1e-6);
    }

    #[test]
    fn fair_share_closed_form() {
        let s = fair_share_secs(1_000_000, 1e9, 4, Duration::ZERO);
        assert!((s - 4e-3).abs() < 1e-9);
    }

    #[test]
    fn read_uses_read_bw() {
        let p = BandwidthPool::new(1e9, 2e9);
        let d = p.read(2_000_000, Duration::ZERO, false);
        assert!((d.as_secs_f64() - 1e-3).abs() < 1e-6);
    }
}
