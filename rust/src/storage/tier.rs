//! Heterogeneous storage tier model.
//!
//! A [`StorageTier`] couples a *performance model* (bandwidth, latency,
//! capacity, sharing) with a *backing store* (in-memory map or a real
//! directory, e.g. on tmpfs). The paper's observation that the storage stack
//! is heterogeneous — deep node-local memory hierarchies plus burst buffers,
//! key-value stores and parallel file systems — maps to one `TierSpec` per
//! level; VeloC's modules consult the specs instead of hard-coding vendor
//! APIs (the portability argument of §1).
//!
//! Time accounting: every transfer returns a *modeled* duration computed
//! from the spec (fair-shared for `shared` tiers, see
//! [`super::contention::BandwidthPool`]). Depending on the stack's
//! [`TimeMode`] the call may also sleep a scaled amount of that duration to
//! emulate the tier in wall-clock time (examples use a small scale; unit
//! tests use pure modeling).

use crate::storage::contention::BandwidthPool;
use crate::util::bufpool::Bytes;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Where a tier sits in the hierarchy and what failure takes it out.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TierKind {
    /// Node-local DRAM (fastest, lost on node failure).
    Dram,
    /// Node-local NVMe.
    Nvme,
    /// Node-local SATA SSD.
    Ssd,
    /// Shared burst buffer.
    BurstBuffer,
    /// Parallel file system (Lustre-like, shared, persistent).
    Pfs,
    /// Key-value object store (DAOS-like, shared, persistent).
    KvStore,
}

impl TierKind {
    /// Stable lowercase name (used as the default tier id, in config
    /// parsing and in reports).
    pub fn name(&self) -> &'static str {
        match self {
            TierKind::Dram => "dram",
            TierKind::Nvme => "nvme",
            TierKind::Ssd => "ssd",
            TierKind::BurstBuffer => "burst-buffer",
            TierKind::Pfs => "pfs",
            TierKind::KvStore => "kv-store",
        }
    }

    /// Parse the config spelling produced by [`TierKind::name`].
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dram" => Ok(TierKind::Dram),
            "nvme" => Ok(TierKind::Nvme),
            "ssd" => Ok(TierKind::Ssd),
            "burst-buffer" | "bb" => Ok(TierKind::BurstBuffer),
            "pfs" => Ok(TierKind::Pfs),
            "kv-store" | "kv" => Ok(TierKind::KvStore),
            other => bail!(
                "tier kind must be dram|nvme|ssd|burst-buffer|pfs|kv-store, got {other}"
            ),
        }
    }
}

/// What survives which failure (paper §2: "lighter resilience levels").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureDomain {
    /// Contents lost when the owning node fails.
    Node,
    /// Contents survive node failures, lost only on full-system failure.
    System,
    /// Persistent: survives everything.
    Persistent,
}

/// Performance/persistency description of one tier.
#[derive(Clone, Debug)]
pub struct TierSpec {
    /// Stable tier identity. Built-in tiers use their kind name
    /// (`"pfs"`, `"burst-buffer"`, ...); configured extra tiers carry the
    /// id from their `fabric.tiers` entry. The placement engine records
    /// this id as the flush destination, so it must be unique among the
    /// shared tiers of one fabric (`VelocConfig::validate` enforces it).
    pub id: String,
    /// Where this tier sits in the hierarchy.
    pub kind: TierKind,
    /// Sustained write bandwidth in bytes/s (per writer for local tiers,
    /// aggregate for shared tiers).
    pub write_bw: f64,
    /// Sustained read bandwidth in bytes/s.
    pub read_bw: f64,
    /// Per-operation latency.
    pub latency: Duration,
    /// Usable capacity in bytes.
    pub capacity: u64,
    /// Shared across ranks (bandwidth fair-shared) or per-rank dedicated.
    pub shared: bool,
    /// What failure wipes the tier's contents.
    pub failure_domain: FailureDomain,
}

impl TierSpec {
    /// Replace the tier id (builder-style; used for configured extra
    /// tiers that derive their spec from a preset).
    pub fn with_id(mut self, id: &str) -> Self {
        self.id = id.to_string();
        self
    }
}

/// How modeled durations translate to wall-clock time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TimeMode {
    /// Account modeled durations only; never sleep (unit tests, DES).
    Model,
    /// Sleep `modeled * scale` (examples/benches; scale << 1 compresses
    /// minutes of I/O into milliseconds while preserving ratios).
    Emulate { scale: f64 },
}

impl TimeMode {
    fn apply(&self, modeled: Duration) {
        if let TimeMode::Emulate { scale } = self {
            let d = modeled.mul_f64(*scale);
            if d > Duration::ZERO {
                std::thread::sleep(d);
            }
        }
    }
}

/// Result of one put/get.
#[derive(Clone, Copy, Debug)]
pub struct TransferStat {
    /// Payload bytes moved.
    pub bytes: u64,
    /// Duration predicted by the tier model (fair-share aware).
    pub modeled: Duration,
}

impl TransferStat {
    /// Modeled throughput in bytes/s.
    pub fn throughput_bps(&self) -> f64 {
        self.bytes as f64 / self.modeled.as_secs_f64().max(1e-12)
    }
}

enum Backing {
    Memory(Mutex<HashMap<String, Bytes>>),
    Dir(PathBuf),
}

/// Write `parts` to `path` as one file using vectored writes — the
/// scatter-gather drain path: aggregation containers and multi-part
/// objects land on disk without being concatenated in memory first.
fn write_gather(path: &Path, parts: &[&[u8]]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    let mut part_idx = 0usize;
    let mut offset = 0usize;
    while part_idx < parts.len() {
        if offset >= parts[part_idx].len() {
            part_idx += 1;
            offset = 0;
            continue;
        }
        let mut slices = Vec::with_capacity(parts.len() - part_idx);
        slices.push(std::io::IoSlice::new(&parts[part_idx][offset..]));
        for p in &parts[part_idx + 1..] {
            slices.push(std::io::IoSlice::new(p));
        }
        let n = f.write_vectored(&slices)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                "vectored write made no progress",
            ));
        }
        // Advance (part_idx, offset) past the n bytes just written.
        let mut adv = n;
        while adv > 0 {
            let rem = parts[part_idx].len() - offset;
            if adv >= rem {
                adv -= rem;
                part_idx += 1;
                offset = 0;
            } else {
                offset += adv;
                adv = 0;
            }
        }
    }
    f.flush()
}

/// One storage level: performance model + backing store.
///
/// Besides the static [`TierSpec`], a tier carries mutable *health* state
/// the placement engine (and the sim's `tier-outage` / `tier-degraded`
/// injection points) drive at runtime: an offline flag, a read-only flag
/// and a service-time degradation factor. Production code never sets
/// these; operators (or fault injection) do.
pub struct StorageTier {
    spec: TierSpec,
    backing: Backing,
    pool: BandwidthPool,
    time_mode: TimeMode,
    used: AtomicU64,
    puts: AtomicU64,
    gets: AtomicU64,
    /// Tier unreachable: puts fail, gets miss (models a dead mount or a
    /// partitioned burst-buffer appliance).
    down: AtomicBool,
    /// Tier rejects writes but still serves reads (models a file system
    /// remounted read-only after an error, or a draining burst buffer).
    read_only: AtomicBool,
    /// Modeled-duration multiplier (f64 bits, >= 1.0). A degraded tier
    /// still works, just slower — the signal adaptive placement reacts to.
    degrade: AtomicU64,
}

fn sanitize_key(key: &str) -> String {
    key.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
        .collect()
}

impl StorageTier {
    /// In-memory backed tier (DRAM levels, simulated remote stores).
    pub fn memory(spec: TierSpec, time_mode: TimeMode) -> Arc<Self> {
        let pool = BandwidthPool::new(spec.write_bw, spec.read_bw);
        Arc::new(StorageTier {
            spec,
            backing: Backing::Memory(Mutex::new(HashMap::new())),
            pool,
            time_mode,
            used: AtomicU64::new(0),
            puts: AtomicU64::new(0),
            gets: AtomicU64::new(0),
            down: AtomicBool::new(false),
            read_only: AtomicBool::new(false),
            degrade: AtomicU64::new(1.0f64.to_bits()),
        })
    }

    /// Directory-backed tier (real files, e.g. tmpfs or scratch).
    pub fn dir(spec: TierSpec, root: PathBuf, time_mode: TimeMode) -> Result<Arc<Self>> {
        std::fs::create_dir_all(&root)?;
        let pool = BandwidthPool::new(spec.write_bw, spec.read_bw);
        Ok(Arc::new(StorageTier {
            spec,
            backing: Backing::Dir(root),
            pool,
            time_mode,
            used: AtomicU64::new(0),
            puts: AtomicU64::new(0),
            gets: AtomicU64::new(0),
            down: AtomicBool::new(false),
            read_only: AtomicBool::new(false),
            degrade: AtomicU64::new(1.0f64.to_bits()),
        }))
    }

    /// The tier's static performance/persistency description.
    pub fn spec(&self) -> &TierSpec {
        &self.spec
    }

    /// Stable tier identity (see [`TierSpec::id`]).
    pub fn id(&self) -> &str {
        &self.spec.id
    }

    /// Where this tier sits in the hierarchy.
    pub fn kind(&self) -> TierKind {
        self.spec.kind
    }

    /// Bytes currently stored.
    pub fn used_bytes(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    /// Remaining capacity in bytes.
    pub fn headroom(&self) -> u64 {
        self.spec.capacity.saturating_sub(self.used_bytes())
    }

    /// Fraction of capacity in use, in `[0, 1]`.
    pub fn fill_fraction(&self) -> f64 {
        if self.spec.capacity == 0 {
            return 1.0;
        }
        (self.used_bytes() as f64 / self.spec.capacity as f64).min(1.0)
    }

    /// Completed puts since construction.
    pub fn put_count(&self) -> u64 {
        self.puts.load(Ordering::Relaxed)
    }

    /// Completed gets since construction.
    pub fn get_count(&self) -> u64 {
        self.gets.load(Ordering::Relaxed)
    }

    /// Mark the tier unreachable (or reachable again): puts fail with
    /// `TierDown`, gets miss. Contents are *not* lost — an outage is a
    /// connectivity event, not a failure-domain wipe ([`Self::wipe`]).
    pub fn set_down(&self, down: bool) {
        self.down.store(down, Ordering::SeqCst);
    }

    /// Is the tier currently unreachable?
    pub fn is_down(&self) -> bool {
        self.down.load(Ordering::SeqCst)
    }

    /// Mark the tier read-only (or writable again): puts fail with
    /// `TierReadOnly`, reads still work.
    pub fn set_read_only(&self, ro: bool) {
        self.read_only.store(ro, Ordering::SeqCst);
    }

    /// Does the tier currently reject writes?
    pub fn is_read_only(&self) -> bool {
        self.read_only.load(Ordering::SeqCst)
    }

    /// Degrade (or restore) the tier's service time: every modeled
    /// transfer duration is multiplied by `factor` (clamped to >= 1.0).
    /// Adaptive placement observes the slowdown through the returned
    /// [`TransferStat`]s and routes away.
    pub fn set_degraded(&self, factor: f64) {
        self.degrade.store(factor.max(1.0).to_bits(), Ordering::SeqCst);
    }

    /// Current service-time degradation factor (1.0 = healthy).
    pub fn degrade_factor(&self) -> f64 {
        f64::from_bits(self.degrade.load(Ordering::SeqCst))
    }

    fn degraded(&self, modeled: Duration) -> Duration {
        let f = self.degrade_factor();
        if f > 1.0 {
            modeled.mul_f64(f)
        } else {
            modeled
        }
    }

    fn check_writable(&self) -> Result<()> {
        if self.is_down() {
            bail!("TierDown: {} is offline", self.spec.id);
        }
        if self.is_read_only() {
            bail!("TierReadOnly: {} rejects writes", self.spec.id);
        }
        Ok(())
    }

    /// Currently active transfers (writers+readers) — the signal the
    /// producer-consumer-aware tier selection policy uses (paper [4]).
    pub fn active_transfers(&self) -> usize {
        self.pool.active()
    }

    /// Mark a long-lived transfer (e.g. an in-flight flush readback) as
    /// active on this tier so other transfers observe the contention.
    pub fn hold_transfer(&self) -> crate::storage::contention::ActiveGuard<'_> {
        self.pool.hold()
    }

    /// Reserve `len` bytes of capacity (subtract on failure).
    fn reserve(&self, len: u64) -> Result<()> {
        let prev = self.used.fetch_add(len, Ordering::SeqCst);
        if prev + len > self.spec.capacity {
            self.used.fetch_sub(len, Ordering::SeqCst);
            bail!(
                "TierFull: {} over capacity ({} + {} > {})",
                self.spec.kind.name(),
                prev,
                len,
                self.spec.capacity
            );
        }
        Ok(())
    }

    /// Release a previously reserved/charged `len` bytes.
    fn release(&self, len: u64) {
        self.used.fetch_sub(len, Ordering::SeqCst);
    }

    /// Store a refcounted slice without copying it: the in-memory backing
    /// keeps a reference to the shared buffer (§Perf: saves one full
    /// memcpy per resilience level on the capture path; the container is
    /// immutable once encoded, so sharing is safe). Directory backings
    /// write the bytes out — a device transfer, not a payload copy.
    pub fn put_bytes(&self, key: &str, data: &Bytes) -> Result<TransferStat> {
        self.check_writable()?;
        let len = data.len() as u64;
        self.reserve(len)?;
        let modeled = self.degraded(self.pool.write(len, self.spec.latency, self.spec.shared));
        match &self.backing {
            Backing::Memory(m) => {
                let old = m.lock().unwrap().insert(key.to_string(), data.clone());
                if let Some(old) = old {
                    self.release(old.len() as u64);
                }
            }
            Backing::Dir(root) => {
                let path = root.join(sanitize_key(key));
                if let Ok(meta) = std::fs::metadata(&path) {
                    self.release(meta.len());
                }
                let tmp = root.join(format!(".{}.tmp", sanitize_key(key)));
                std::fs::write(&tmp, data.as_ref())?;
                std::fs::rename(&tmp, &path)?; // atomic publish
            }
        }
        self.puts.fetch_add(1, Ordering::Relaxed);
        self.time_mode.apply(modeled);
        Ok(TransferStat {
            bytes: len,
            modeled,
        })
    }

    /// Store an already-shared vector without copying (wrapped into a
    /// [`Bytes`] view of the same allocation).
    pub fn put_shared(&self, key: &str, data: &Arc<Vec<u8>>) -> Result<TransferStat> {
        self.put_bytes(key, &Bytes::from_arc(Arc::clone(data)))
    }

    /// Store a borrowed slice. In-memory backings must copy it into the
    /// map (a counted payload copy — callers holding a [`Bytes`] should
    /// use [`Self::put_bytes`]); directory backings write it directly.
    pub fn put(&self, key: &str, data: &[u8]) -> Result<TransferStat> {
        match &self.backing {
            Backing::Memory(_) => self.put_bytes(key, &Bytes::copy_from_slice(data)),
            Backing::Dir(_) => self.put_gather(key, &[data]),
        }
    }

    /// Scatter-gather store: persist `parts` as one object without
    /// concatenating them first. Directory backings issue vectored writes
    /// into the tmp file; in-memory backings gather once into the stored
    /// block — that gather *is* the tier write (the analogue of a device
    /// DMA gather), so it is not a payload copy.
    pub fn put_gather(&self, key: &str, parts: &[&[u8]]) -> Result<TransferStat> {
        self.check_writable()?;
        let len: u64 = parts.iter().map(|p| p.len() as u64).sum();
        self.reserve(len)?;
        let modeled = self.degraded(self.pool.write(len, self.spec.latency, self.spec.shared));
        match &self.backing {
            Backing::Memory(m) => {
                let mut buf = Vec::with_capacity(len as usize);
                for p in parts {
                    buf.extend_from_slice(p);
                }
                let old = m.lock().unwrap().insert(key.to_string(), Bytes::from(buf));
                if let Some(old) = old {
                    self.release(old.len() as u64);
                }
            }
            Backing::Dir(root) => {
                let path = root.join(sanitize_key(key));
                if let Ok(meta) = std::fs::metadata(&path) {
                    self.release(meta.len());
                }
                let tmp = root.join(format!(".{}.tmp", sanitize_key(key)));
                write_gather(&tmp, parts)?;
                std::fs::rename(&tmp, &path)?; // atomic publish
            }
        }
        self.puts.fetch_add(1, Ordering::Relaxed);
        self.time_mode.apply(modeled);
        Ok(TransferStat {
            bytes: len,
            modeled,
        })
    }

    /// Fetch an object as a shared slice (None if missing or the tier is
    /// down). In-memory backings hand back a reference to the stored
    /// buffer — no copy; directory backings read the file once.
    pub fn get_shared(&self, key: &str) -> Option<(Bytes, TransferStat)> {
        if self.is_down() {
            return None;
        }
        let data: Bytes = match &self.backing {
            Backing::Memory(m) => m.lock().unwrap().get(key).cloned()?,
            Backing::Dir(root) => Bytes::from(std::fs::read(root.join(sanitize_key(key))).ok()?),
        };
        let modeled =
            self.degraded(self.pool.read(data.len() as u64, self.spec.latency, self.spec.shared));
        self.gets.fetch_add(1, Ordering::Relaxed);
        self.time_mode.apply(modeled);
        let stat = TransferStat {
            bytes: data.len() as u64,
            modeled,
        };
        Some((data, stat))
    }

    /// Fetch an object as an owned vector (None if missing or the tier is
    /// down). Cloning out of the in-memory map is a counted payload copy —
    /// restore paths that can work from the shared view should use
    /// [`Self::get_shared`].
    pub fn get(&self, key: &str) -> Option<(Vec<u8>, TransferStat)> {
        if self.is_down() {
            return None;
        }
        let data: Vec<u8> = match &self.backing {
            Backing::Memory(m) => {
                let b = { m.lock().unwrap().get(key).cloned() }?;
                b.to_vec() // counted: clone-out of the shared map
            }
            Backing::Dir(root) => std::fs::read(root.join(sanitize_key(key))).ok()?,
        };
        let modeled =
            self.degraded(self.pool.read(data.len() as u64, self.spec.latency, self.spec.shared));
        self.gets.fetch_add(1, Ordering::Relaxed);
        self.time_mode.apply(modeled);
        let stat = TransferStat {
            bytes: data.len() as u64,
            modeled,
        };
        Some((data, stat))
    }

    /// Is an object stored under `key` (false while the tier is down)?
    pub fn exists(&self, key: &str) -> bool {
        if self.is_down() {
            return false;
        }
        match &self.backing {
            Backing::Memory(m) => m.lock().unwrap().contains_key(key),
            Backing::Dir(root) => root.join(sanitize_key(key)).exists(),
        }
    }

    /// Remove an object; returns whether one was stored. Deletes keep
    /// working on down/read-only tiers — they are our own bookkeeping
    /// (GC), not remote I/O.
    pub fn delete(&self, key: &str) -> bool {
        match &self.backing {
            Backing::Memory(m) => {
                if let Some(old) = m.lock().unwrap().remove(key) {
                    self.used.fetch_sub(old.len() as u64, Ordering::SeqCst);
                    true
                } else {
                    false
                }
            }
            Backing::Dir(root) => {
                let path = root.join(sanitize_key(key));
                if let Ok(meta) = std::fs::metadata(&path) {
                    if std::fs::remove_file(&path).is_ok() {
                        self.used.fetch_sub(meta.len(), Ordering::SeqCst);
                        return true;
                    }
                }
                false
            }
        }
    }

    /// List stored keys with the given prefix (memory backing returns exact
    /// keys; dir backing returns sanitized names, which match for the
    /// key alphabet VeloC uses).
    pub fn list(&self, prefix: &str) -> Vec<String> {
        if self.is_down() {
            return Vec::new();
        }
        match &self.backing {
            Backing::Memory(m) => {
                let mut v: Vec<String> = m
                    .lock()
                    .unwrap()
                    .keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned()
                    .collect();
                v.sort();
                v
            }
            Backing::Dir(root) => {
                let sp = sanitize_key(prefix);
                let mut v: Vec<String> = std::fs::read_dir(root)
                    .map(|rd| {
                        rd.filter_map(|e| e.ok())
                            .filter_map(|e| e.file_name().into_string().ok())
                            .filter(|n| n.starts_with(&sp) && !n.starts_with('.'))
                            .collect()
                    })
                    .unwrap_or_default();
                v.sort();
                v
            }
        }
    }

    /// Drop all contents — models loss of the tier's failure domain
    /// (node crash wipes DRAM/NVMe tiers of that node).
    pub fn wipe(&self) {
        match &self.backing {
            Backing::Memory(m) => m.lock().unwrap().clear(),
            Backing::Dir(root) => {
                if let Ok(rd) = std::fs::read_dir(root) {
                    for e in rd.filter_map(|e| e.ok()) {
                        let _ = std::fs::remove_file(e.path());
                    }
                }
            }
        }
        self.used.store(0, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(capacity: u64, shared: bool) -> TierSpec {
        TierSpec {
            id: "dram".to_string(),
            kind: TierKind::Dram,
            write_bw: 1e9,
            read_bw: 2e9,
            latency: Duration::from_micros(10),
            capacity,
            shared,
            failure_domain: FailureDomain::Node,
        }
    }

    #[test]
    fn put_get_roundtrip_memory() {
        let t = StorageTier::memory(spec(1 << 20, false), TimeMode::Model);
        let stat = t.put("a", b"hello").unwrap();
        assert_eq!(stat.bytes, 5);
        let (data, _) = t.get("a").unwrap();
        assert_eq!(data, b"hello");
        assert!(t.exists("a"));
        assert!(!t.exists("b"));
    }

    #[test]
    fn put_get_roundtrip_dir() {
        let dir = std::env::temp_dir().join(format!("veloc-tier-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let t = StorageTier::dir(spec(1 << 20, false), dir.clone(), TimeMode::Model).unwrap();
        t.put("ckpt/r0/v1", b"payload").unwrap();
        let (data, _) = t.get("ckpt/r0/v1").unwrap();
        assert_eq!(data, b"payload");
        assert_eq!(t.list("ckpt").len(), 1);
        assert!(t.delete("ckpt/r0/v1"));
        assert!(t.get("ckpt/r0/v1").is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn modeled_duration_matches_bandwidth() {
        let t = StorageTier::memory(spec(1 << 30, false), TimeMode::Model);
        let stat = t.put("x", &vec![0u8; 1_000_000]).unwrap();
        // 1 MB at 1 GB/s = 1 ms + 10 µs latency
        let ms = stat.modeled.as_secs_f64() * 1e3;
        assert!((ms - 1.01).abs() < 0.05, "modeled {ms} ms");
    }

    #[test]
    fn capacity_enforced() {
        let t = StorageTier::memory(spec(100, false), TimeMode::Model);
        t.put("a", &vec![0u8; 60]).unwrap();
        let err = t.put("b", &vec![0u8; 60]).unwrap_err().to_string();
        assert!(err.contains("TierFull"), "{err}");
        // Overwrite of same key reclaims space.
        t.put("a", &vec![0u8; 40]).unwrap();
        assert_eq!(t.used_bytes(), 40);
    }

    #[test]
    fn delete_reclaims_capacity() {
        let t = StorageTier::memory(spec(100, false), TimeMode::Model);
        t.put("a", &vec![0u8; 80]).unwrap();
        assert!(t.delete("a"));
        assert_eq!(t.used_bytes(), 0);
        t.put("b", &vec![0u8; 80]).unwrap();
    }

    #[test]
    fn wipe_clears_everything() {
        let t = StorageTier::memory(spec(1 << 20, false), TimeMode::Model);
        t.put("a", b"1").unwrap();
        t.put("b", b"2").unwrap();
        t.wipe();
        assert!(!t.exists("a"));
        assert_eq!(t.used_bytes(), 0);
        assert!(t.list("").is_empty());
    }

    #[test]
    fn down_tier_fails_puts_and_misses_gets() {
        let t = StorageTier::memory(spec(1 << 20, false), TimeMode::Model);
        t.put("a", b"1").unwrap();
        t.set_down(true);
        assert!(t.is_down());
        let err = t.put("b", b"2").unwrap_err().to_string();
        assert!(err.contains("TierDown"), "{err}");
        assert!(t.get("a").is_none());
        assert!(!t.exists("a"));
        assert!(t.list("").is_empty());
        t.set_down(false);
        assert_eq!(t.get("a").unwrap().0, b"1", "contents survive an outage");
    }

    #[test]
    fn read_only_tier_serves_reads_rejects_writes() {
        let t = StorageTier::memory(spec(1 << 20, false), TimeMode::Model);
        t.put("a", b"1").unwrap();
        t.set_read_only(true);
        let err = t.put("b", b"2").unwrap_err().to_string();
        assert!(err.contains("TierReadOnly"), "{err}");
        assert_eq!(t.get("a").unwrap().0, b"1");
        t.set_read_only(false);
        t.put("b", b"2").unwrap();
    }

    #[test]
    fn degradation_scales_modeled_durations() {
        let t = StorageTier::memory(spec(1 << 30, false), TimeMode::Model);
        let base = t.put("x", &vec![0u8; 1_000_000]).unwrap().modeled;
        t.set_degraded(4.0);
        let slow = t.put("y", &vec![0u8; 1_000_000]).unwrap().modeled;
        let ratio = slow.as_secs_f64() / base.as_secs_f64();
        assert!((ratio - 4.0).abs() < 0.01, "ratio {ratio}");
        t.set_degraded(1.0);
        assert_eq!(t.degrade_factor(), 1.0);
    }

    #[test]
    fn put_gather_matches_concatenation_dir() {
        let dir = std::env::temp_dir().join(format!("veloc-gather-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let t = StorageTier::dir(spec(1 << 20, false), dir.clone(), TimeMode::Model).unwrap();
        let a = vec![1u8; 7];
        let b: Vec<u8> = Vec::new();
        let c = vec![3u8; 4097];
        let d = vec![4u8; 1];
        let stat = t
            .put_gather("obj", &[&a, &b, &c, &d])
            .unwrap();
        assert_eq!(stat.bytes, 7 + 4097 + 1);
        let (read, _) = t.get("obj").unwrap();
        let mut expect = a.clone();
        expect.extend_from_slice(&c);
        expect.extend_from_slice(&d);
        assert_eq!(read, expect);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn put_gather_matches_concatenation_memory() {
        let t = StorageTier::memory(spec(1 << 20, false), TimeMode::Model);
        t.put_gather("obj", &[b"ab", b"", b"cde"]).unwrap();
        let (read, _) = t.get("obj").unwrap();
        assert_eq!(read, b"abcde");
        assert_eq!(t.used_bytes(), 5);
    }

    #[test]
    fn put_bytes_shares_and_get_shared_reads_back() {
        use crate::util::bufpool;
        let t = StorageTier::memory(spec(1 << 20, false), TimeMode::Model);
        let payload = Bytes::from(vec![9u8; 1024]);
        let before = bufpool::thread_payload_copies();
        t.put_bytes("k", &payload).unwrap();
        let (back, _) = t.get_shared("k").unwrap();
        assert_eq!(back, payload);
        assert_eq!(
            bufpool::thread_payload_copies(),
            before,
            "put_bytes + get_shared must not copy the payload"
        );
        // The owned paths do copy — and are counted.
        let _ = t.get("k").unwrap();
        t.put("k2", &payload).unwrap();
        assert_eq!(bufpool::thread_payload_copies(), before + 2);
    }

    #[test]
    fn list_prefix_sorted() {
        let t = StorageTier::memory(spec(1 << 20, false), TimeMode::Model);
        t.put("ck.2", b"x").unwrap();
        t.put("ck.1", b"x").unwrap();
        t.put("other", b"x").unwrap();
        assert_eq!(t.list("ck."), vec!["ck.1".to_string(), "ck.2".to_string()]);
    }
}
