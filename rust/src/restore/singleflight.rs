//! Single-flight deduplication of concurrent container fetches.
//!
//! During a restart storm N clients cold-restore the same checkpoint at
//! once; without coalescing, every one of them issues the same tier read
//! and the shared tier serves N identical transfers. Single-flight keys
//! each in-flight fetch by its canonical container identity: the first
//! caller becomes the *leader* and performs the real fetch, every caller
//! that arrives while the flight is open *joins* it, blocks on the
//! leader's condvar and shares the leader's `Arc`'d bytes — exactly one
//! tier read per container, no matter how wide the storm.
//!
//! A leader that fails (error or panic) publishes a miss to its waiters:
//! they see `None` and treat it like any other unavailable copy (fall to
//! the next resilience level) rather than re-issuing the fetch — an
//! erroring source would otherwise be hammered N times over.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// One in-flight fetch: the slot is `None` while the leader runs and
/// `Some(result)` once published; waiters block on the condvar.
struct Flight {
    slot: Mutex<Option<Option<Arc<Vec<u8>>>>>,
    cv: Condvar,
}

/// The per-engine single-flight table.
#[derive(Default)]
pub(crate) struct SingleFlight {
    inflight: Mutex<HashMap<String, Arc<Flight>>>,
}

/// How one `run` call resolved: this caller led the fetch (and owns its
/// full `Result`, errors included) or joined another caller's flight
/// (and shares the published bytes, `None` on leader miss/failure).
pub(crate) enum FlightOutcome {
    Led(anyhow::Result<Option<Arc<Vec<u8>>>>),
    Joined(Option<Arc<Vec<u8>>>),
}

/// Publishes the leader's result on drop — even on unwind — so waiters
/// can never deadlock behind a leader that panicked mid-fetch.
struct Lead<'a> {
    sf: &'a SingleFlight,
    key: &'a str,
    flight: Arc<Flight>,
    value: Option<Arc<Vec<u8>>>,
}

impl Drop for Lead<'_> {
    fn drop(&mut self) {
        *self.flight.slot.lock().unwrap() = Some(self.value.take());
        self.flight.cv.notify_all();
        self.sf.inflight.lock().unwrap().remove(self.key);
    }
}

impl SingleFlight {
    /// Run `fetch` under single-flight semantics for `key`.
    pub fn run(
        &self,
        key: &str,
        fetch: impl FnOnce() -> anyhow::Result<Option<Arc<Vec<u8>>>>,
    ) -> FlightOutcome {
        let (flight, leader) = {
            let mut map = self.inflight.lock().unwrap();
            match map.get(key) {
                Some(f) => (Arc::clone(f), false),
                None => {
                    let f = Arc::new(Flight {
                        slot: Mutex::new(None),
                        cv: Condvar::new(),
                    });
                    map.insert(key.to_string(), Arc::clone(&f));
                    (f, true)
                }
            }
        };
        if leader {
            let mut lead = Lead {
                sf: self,
                key,
                flight,
                value: None,
            };
            let res = fetch();
            if let Ok(v) = &res {
                lead.value.clone_from(v);
            }
            drop(lead); // publish + deregister
            FlightOutcome::Led(res)
        } else {
            let mut slot = flight.slot.lock().unwrap();
            while slot.is_none() {
                slot = flight.cv.wait(slot).unwrap();
            }
            FlightOutcome::Joined(slot.as_ref().unwrap().clone())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Barrier;

    #[test]
    fn waiters_share_one_fetch() {
        let sf = Arc::new(SingleFlight::default());
        let fetches = Arc::new(AtomicU64::new(0));
        let gate = Arc::new(Barrier::new(9));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let (sf, fetches, gate) = (Arc::clone(&sf), Arc::clone(&fetches), Arc::clone(&gate));
            handles.push(std::thread::spawn(move || {
                match sf.run("k", || {
                    // Hold the flight open until all 8 callers arrived, so
                    // everyone but the leader demonstrably joins.
                    gate.wait();
                    fetches.fetch_add(1, Ordering::SeqCst);
                    Ok(Some(Arc::new(vec![7u8; 64])))
                }) {
                    FlightOutcome::Led(r) => r.unwrap().unwrap(),
                    FlightOutcome::Joined(v) => v.unwrap(),
                }
            }));
        }
        // Release the leader only after every thread is running (the main
        // thread is the 9th barrier participant).
        gate.wait();
        for h in handles {
            assert_eq!(*h.join().unwrap(), vec![7u8; 64]);
        }
        assert_eq!(fetches.load(Ordering::SeqCst), 1, "exactly one real fetch");
    }

    #[test]
    fn leader_error_publishes_miss_to_waiters() {
        let sf = SingleFlight::default();
        match sf.run("k", || anyhow::bail!("tier exploded")) {
            FlightOutcome::Led(r) => assert!(r.is_err()),
            FlightOutcome::Joined(_) => panic!("sole caller must lead"),
        }
        // The flight was deregistered: a later caller leads afresh.
        match sf.run("k", || Ok(Some(Arc::new(vec![1u8])))) {
            FlightOutcome::Led(r) => assert!(r.unwrap().is_some()),
            FlightOutcome::Joined(_) => panic!("flight must be gone after the error"),
        }
    }
}
