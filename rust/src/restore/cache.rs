//! Two-level read-through container cache.
//!
//! - **L1** is an in-memory segment cache: canonical container key →
//!   `Arc`'d bytes, size-bounded, with admission capped per entry so one
//!   huge container cannot wipe the working set.
//! - **L2** is a node-local-tier spill: L1 victims are written to the
//!   node's largest local tier as `rcache.<key>` objects (charging that
//!   tier's write, exactly like any other local copy) and promoted back
//!   to L1 on hit.
//!
//! Eviction is cost-aware LRU: victims are picked cheapest-to-refetch
//! first (local re-reads before partner hops before PFS/aggregated reads
//! before erasure rebuilds), least-recently-used within a cost class.
//!
//! Every entry carries a CRC32 fingerprint computed at admission and
//! re-verified on *every* hit (L1 in memory, L2 as a 4-byte object
//! trailer). A corrupted — "poisoned" — entry is never served: it is
//! counted (`restore.cache.poisoned`), dropped, and the read falls
//! through to a real refetch.

use crate::metrics::Metrics;
use crate::storage::StorageFabric;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

struct L1Entry {
    data: Arc<Vec<u8>>,
    crc: u32,
    node: usize,
    cost: u8,
    last_use: u64,
}

struct L2Entry {
    node: usize,
    len: u64,
    cost: u8,
    last_use: u64,
}

#[derive(Default)]
struct CacheState {
    tick: u64,
    l1: HashMap<String, L1Entry>,
    l1_bytes: u64,
    l2: HashMap<String, L2Entry>,
    l2_bytes: u64,
}

pub(crate) struct ReadCache {
    l1_cap: u64,
    l2_cap: u64,
    max_entry: u64,
    fabric: Arc<StorageFabric>,
    metrics: Arc<Metrics>,
    state: Mutex<CacheState>,
}

/// Storage key of a spilled L1 victim on the node-local tier.
fn l2_key(key: &str) -> String {
    format!("rcache.{key}")
}

impl ReadCache {
    pub fn new(
        l1_cap: u64,
        l2_cap: u64,
        max_entry: u64,
        fabric: Arc<StorageFabric>,
        metrics: Arc<Metrics>,
    ) -> Self {
        ReadCache {
            l1_cap,
            l2_cap,
            max_entry,
            fabric,
            metrics,
            state: Mutex::new(CacheState::default()),
        }
    }

    /// Look `key` up in L1, then L2. Hits re-verify the stored CRC; a
    /// mismatch is counted as poisoned, dropped, and reported as a miss
    /// so the caller refetches from the real source.
    pub fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        let l2_probe = {
            let mut st = self.state.lock().unwrap();
            st.tick += 1;
            let tick = st.tick;
            if let Some(e) = st.l1.get_mut(key) {
                if crc32fast::hash(&e.data) == e.crc {
                    e.last_use = tick;
                    self.metrics.incr("restore.cache.hits", 1);
                    return Some(Arc::clone(&e.data));
                }
                // Poisoned in memory: drop it, fall through to L2 (whose
                // copy carries its own trailer CRC) and then the source.
                self.metrics.incr("restore.cache.poisoned", 1);
                let e = st.l1.remove(key).unwrap();
                st.l1_bytes -= e.data.len() as u64;
            }
            st.l2.get_mut(key).map(|e| {
                e.last_use = tick;
                e.node
            })
        };
        let node = l2_probe?;
        // Read the spilled object outside the cache lock (tier reads may
        // sleep under emulated time).
        for tier in self.fabric.local_tiers(node) {
            let Some((raw, _)) = tier.get(&l2_key(key)) else {
                continue;
            };
            if raw.len() >= 4 {
                let (data, trailer) = raw.split_at(raw.len() - 4);
                let crc = u32::from_le_bytes(trailer.try_into().unwrap());
                if crc32fast::hash(data) == crc {
                    self.metrics.incr("restore.cache.hits", 1);
                    self.metrics.incr("restore.cache.l2.hits", 1);
                    // Promote: hot again, so it belongs back in memory.
                    return Some(self.insert_raw(key, node, data.to_vec(), crc));
                }
            }
            // Poisoned on the spill tier: delete, forget, miss.
            self.metrics.incr("restore.cache.poisoned", 1);
            tier.delete(&l2_key(key));
            let mut st = self.state.lock().unwrap();
            if let Some(e) = st.l2.remove(key) {
                st.l2_bytes -= e.len;
            }
            return None;
        }
        // Index said L2 but no tier holds the object (tier wiped by a
        // failure): forget the stale index entry.
        let mut st = self.state.lock().unwrap();
        if let Some(e) = st.l2.remove(key) {
            st.l2_bytes -= e.len;
        }
        None
    }

    /// Admit freshly fetched bytes under `key`. Oversized entries bypass
    /// admission (returned to the caller untouched); undersized caches
    /// evict cost-aware-LRU victims into L2 to make room.
    pub fn insert(&self, key: &str, node: usize, cost: u8, data: Vec<u8>) -> Arc<Vec<u8>> {
        if self.l1_cap == 0 || data.len() as u64 > self.max_entry {
            self.metrics.incr("restore.cache.rejected", 1);
            return Arc::new(data);
        }
        let crc = crc32fast::hash(&data);
        self.insert_with_cost(key, node, cost, data, crc)
    }

    fn insert_raw(&self, key: &str, node: usize, data: Vec<u8>, crc: u32) -> Arc<Vec<u8>> {
        let cost = self
            .state
            .lock()
            .unwrap()
            .l2
            .get(key)
            .map(|e| e.cost)
            .unwrap_or(0);
        self.insert_with_cost(key, node, cost, data, crc)
    }

    fn insert_with_cost(
        &self,
        key: &str,
        node: usize,
        cost: u8,
        data: Vec<u8>,
        crc: u32,
    ) -> Arc<Vec<u8>> {
        let arc = Arc::new(data);
        let victims = {
            let mut st = self.state.lock().unwrap();
            st.tick += 1;
            let tick = st.tick;
            if let Some(old) = st.l1.insert(
                key.to_string(),
                L1Entry {
                    data: Arc::clone(&arc),
                    crc,
                    node,
                    cost,
                    last_use: tick,
                },
            ) {
                st.l1_bytes -= old.data.len() as u64;
            }
            st.l1_bytes += arc.len() as u64;
            let mut victims = Vec::new();
            while st.l1_bytes > self.l1_cap {
                // Cheapest-to-refetch first, LRU within a cost class.
                let victim = st
                    .l1
                    .iter()
                    .min_by_key(|(_, e)| (e.cost, e.last_use))
                    .map(|(k, _)| k.clone())
                    .expect("l1_bytes > 0 implies at least one entry");
                let e = st.l1.remove(&victim).unwrap();
                st.l1_bytes -= e.data.len() as u64;
                self.metrics.incr("restore.cache.evictions", 1);
                victims.push((victim, e));
            }
            victims
        };
        for (k, e) in victims {
            self.spill(&k, &e);
        }
        arc
    }

    /// Write an L1 victim to its node's largest local tier with a CRC
    /// trailer. Spilling is best-effort: no capacity, no L2.
    fn spill(&self, key: &str, e: &L1Entry) {
        if self.l2_cap == 0 || e.data.len() as u64 > self.max_entry {
            return;
        }
        let mut payload = Vec::with_capacity(e.data.len() + 4);
        payload.extend_from_slice(&e.data);
        payload.extend_from_slice(&e.crc.to_le_bytes());
        let bytes = payload.len() as u64;
        let Some(tier) = self
            .fabric
            .local_tiers(e.node)
            .iter()
            .rev() // slowest/biggest first: never crowd out level-1 copies
            .find(|t| t.used_bytes() + bytes <= t.spec().capacity)
        else {
            return;
        };
        if tier.put(&l2_key(key), &payload).is_err() {
            return;
        }
        self.metrics.incr("restore.cache.l2.spills", 1);
        let doomed = {
            let mut st = self.state.lock().unwrap();
            st.tick += 1;
            let tick = st.tick;
            if let Some(old) = st.l2.insert(
                key.to_string(),
                L2Entry {
                    node: e.node,
                    len: bytes,
                    cost: e.cost,
                    last_use: tick,
                },
            ) {
                st.l2_bytes -= old.len;
            }
            st.l2_bytes += bytes;
            let mut doomed = Vec::new();
            while st.l2_bytes > self.l2_cap {
                let victim = st
                    .l2
                    .iter()
                    .min_by_key(|(_, e)| (e.cost, e.last_use))
                    .map(|(k, _)| k.clone())
                    .expect("l2_bytes > 0 implies at least one entry");
                let e = st.l2.remove(&victim).unwrap();
                st.l2_bytes -= e.len;
                self.metrics.incr("restore.cache.l2.evictions", 1);
                doomed.push((victim, e.node));
            }
            doomed
        };
        for (k, node) in doomed {
            for tier in self.fabric.local_tiers(node) {
                if tier.delete(&l2_key(&k)) {
                    break;
                }
            }
        }
    }

    /// Fault injection: corrupt the cached L1 bytes of `key` *without*
    /// updating the stored CRC, so the next hit trips the fingerprint
    /// check. Returns false when the key is not resident in L1.
    pub fn poison(&self, key: &str) -> bool {
        let mut st = self.state.lock().unwrap();
        let Some(e) = st.l1.get_mut(key) else {
            return false;
        };
        let mut corrupt = (*e.data).clone();
        let Some(b) = corrupt.first_mut() else {
            return false;
        };
        *b ^= 0xFF;
        e.data = Arc::new(corrupt);
        true
    }

    /// Drop everything — in-memory entries and spilled objects. Called
    /// when a failure is injected: the cache is node memory serving tier
    /// bytes, and must not outlive the state it mirrors.
    pub fn invalidate_all(&self) {
        let l2 = {
            let mut st = self.state.lock().unwrap();
            st.l1.clear();
            st.l1_bytes = 0;
            st.l2_bytes = 0;
            std::mem::take(&mut st.l2)
        };
        for (k, e) in l2 {
            for tier in self.fabric.local_tiers(e.node) {
                if tier.delete(&l2_key(&k)) {
                    break;
                }
            }
        }
    }

    /// Resident L1 bytes (tests / introspection).
    pub fn l1_bytes(&self) -> u64 {
        self.state.lock().unwrap().l1_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::FabricConfig;

    fn cache(l1: u64, l2: u64) -> ReadCache {
        let fabric = Arc::new(
            StorageFabric::build(&FabricConfig {
                nodes: 1,
                ..Default::default()
            })
            .unwrap(),
        );
        ReadCache::new(l1, l2, 1 << 20, fabric, Metrics::new())
    }

    #[test]
    fn hit_after_insert_and_poison_detection() {
        let c = cache(1 << 20, 0);
        c.insert("pfs:app:r0:v1", 0, 2, vec![9u8; 4096]);
        assert_eq!(*c.get("pfs:app:r0:v1").unwrap(), vec![9u8; 4096]);
        assert_eq!(c.metrics.counter("restore.cache.hits"), 1);
        // Poison: the corrupted bytes are never served.
        assert!(c.poison("pfs:app:r0:v1"));
        assert!(c.get("pfs:app:r0:v1").is_none());
        assert_eq!(c.metrics.counter("restore.cache.poisoned"), 1);
        // And the entry is gone, so a refetch re-admits clean bytes.
        c.insert("pfs:app:r0:v1", 0, 2, vec![9u8; 4096]);
        assert_eq!(*c.get("pfs:app:r0:v1").unwrap(), vec![9u8; 4096]);
    }

    #[test]
    fn cost_aware_eviction_spills_to_l2_and_promotes_back() {
        // L1 fits two 4 KiB entries; the third insert evicts the cheap one.
        let c = cache(8 << 10, 1 << 20);
        c.insert("local:app:r0:v1", 0, 0, vec![1u8; 4096]);
        c.insert("erasure:app:r0:v1", 0, 3, vec![3u8; 4096]);
        c.insert("pfs:app:r0:v1", 0, 2, vec![2u8; 4096]);
        assert_eq!(c.metrics.counter("restore.cache.evictions"), 1);
        assert_eq!(c.metrics.counter("restore.cache.l2.spills"), 1);
        // The expensive erasure rebuild survived in L1.
        assert!(c.state.lock().unwrap().l1.contains_key("erasure:app:r0:v1"));
        // The evicted local entry still hits — from the L2 spill — and
        // promotes back into L1.
        assert_eq!(*c.get("local:app:r0:v1").unwrap(), vec![1u8; 4096]);
        assert_eq!(c.metrics.counter("restore.cache.l2.hits"), 1);
        assert!(c.state.lock().unwrap().l1.contains_key("local:app:r0:v1"));
    }

    #[test]
    fn oversized_entries_bypass_admission() {
        let c = cache(8 << 20, 0);
        c.insert("pfs:app:r0:v1", 0, 2, vec![0u8; 2 << 20]); // > max_entry
        assert!(c.get("pfs:app:r0:v1").is_none());
        assert_eq!(c.metrics.counter("restore.cache.rejected"), 1);
        assert_eq!(c.l1_bytes(), 0);
    }

    #[test]
    fn invalidate_all_clears_both_levels() {
        let c = cache(4 << 10, 1 << 20);
        c.insert("a", 0, 0, vec![1u8; 4096]);
        c.insert("b", 0, 0, vec![2u8; 4096]); // evicts "a" into L2
        c.invalidate_all();
        assert!(c.get("a").is_none());
        assert!(c.get("b").is_none());
        assert_eq!(c.l1_bytes(), 0);
    }
}
