//! Restore-side serving subsystem: the read path's data plane.
//!
//! Every write-path subsystem (aggregation, delta, placement, the active
//! backend) optimizes checkpoint *production*; the expensive production
//! event is the *restart storm*, where thousands of clients cold-restore
//! the same checkpoints at once and a parallel file system collapses
//! under redundant reads. This module sits between the restore entry
//! points ([`crate::recovery`], the per-level module `restore()` paths,
//! the daemon's restart query) and the storage fabric, and serves
//! container bytes through three cooperating mechanisms:
//!
//! - **Read-through cache** ([`cache`]) — L1 in-memory segment cache with
//!   an L2 node-local-tier spill; size-bounded admission, cost-aware LRU
//!   eviction, CRC-fingerprint verification on every hit (a poisoned
//!   entry is dropped and refetched, never served).
//! - **Single-flight dedup** ([`singleflight`]) — N concurrent restores
//!   of one container issue exactly one tier read; later arrivals block
//!   on the in-flight fetch and share the leader's bytes.
//! - **Parallel chain prefetch** — for delta containers the manifest
//!   chain's hop list is predicted up front
//!   ([`crate::delta::predicted_hops`]) and fetched in waves of
//!   `prefetch_depth` concurrent reads, so chain-restore latency scales
//!   with the configured depth instead of the chain length; the
//!   authoritative serial walk ([`crate::delta::materialize_planned`])
//!   then resolves against the warmed cache and returns the canonical
//!   [`ChainPlan`](crate::delta::ChainPlan) it actually took.
//!
//! Containers are keyed by one canonical identity,
//! `<source>:<name>:r<rank>:v<version>` (see [`RestoreEngine::key`]),
//! shared by the cache, the single-flight table and the prefetcher. The
//! `source` prefix keeps resilience levels from cross-contaminating:
//! `local`, `partner`, `erasure` (rebuilt bytes — the most expensive to
//! refetch), `pfs` (direct or placed level-4 objects) and `agg`
//! (aggregated-container extraction).
//!
//! The subsystem is observable through the `restore.*` metrics:
//! `restore.cache.{hits,misses,evictions,poisoned}`,
//! `restore.cache.l2.{hits,spills,evictions}`,
//! `restore.singleflight.coalesced`, `restore.prefetch.{depth,issued}`
//! and `restore.plan.hops`.

mod cache;
mod singleflight;

use crate::delta::store::ChunkStore;
use crate::delta::{manifest, materialize_planned, predicted_hops};
use crate::metrics::Metrics;
use crate::modules::transfer::maybe_decompress;
use crate::obs::{SpanId, TraceRecorder};
use crate::storage::StorageFabric;
use crate::util::bytes::Checkpoint;
use anyhow::{bail, Result};
use cache::ReadCache;
use singleflight::{FlightOutcome, SingleFlight};
use std::sync::Arc;

/// Knobs for the restore-side serving plane (JSON `"restore"` section,
/// `--restore-*` CLI flags).
#[derive(Clone, Debug)]
pub struct RestoreConfig {
    /// Route restore reads through the cache + single-flight + prefetch
    /// plane (disabled = the historical direct serial path).
    pub enabled: bool,
    /// L1 in-memory cache capacity in bytes.
    pub l1_bytes: u64,
    /// L2 node-local-tier spill capacity in bytes (0 disables the spill).
    pub l2_bytes: u64,
    /// Largest single container admitted to the cache; bigger ones are
    /// served but never cached (one huge container must not wipe the
    /// working set).
    pub max_entry_bytes: u64,
    /// Concurrent fetches per prefetch wave when walking a delta chain.
    pub prefetch_depth: usize,
}

impl Default for RestoreConfig {
    fn default() -> Self {
        RestoreConfig {
            enabled: true,
            l1_bytes: 64 << 20,
            l2_bytes: 128 << 20,
            max_entry_bytes: 16 << 20,
            prefetch_depth: 4,
        }
    }
}

impl RestoreConfig {
    /// Reject combinations the engine would otherwise have to patch up
    /// silently. Called by `VelocConfig::validate`.
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if self.l1_bytes < 1 << 20 {
            bail!(
                "restore.l1_bytes = {} is below the 1 MiB minimum (set \
                 restore.enabled = false to disable the cache entirely)",
                self.l1_bytes
            );
        }
        if self.max_entry_bytes < 4096 || self.max_entry_bytes > self.l1_bytes {
            bail!(
                "restore.max_entry_bytes = {} must lie in [4096, l1_bytes = {}]",
                self.max_entry_bytes,
                self.l1_bytes
            );
        }
        if self.prefetch_depth == 0 || self.prefetch_depth > 64 {
            bail!(
                "restore.prefetch_depth = {} must lie in [1, 64]",
                self.prefetch_depth
            );
        }
        Ok(())
    }
}

/// Relative refetch cost of a byte source — the cache's eviction
/// preference (evict cheap-to-refetch entries first).
fn source_cost(source: &str) -> u8 {
    match source {
        "local" => 0,
        "partner" => 1,
        "erasure" => 3,
        // "pfs", "agg" and anything unknown: shared-tier read.
        _ => 2,
    }
}

/// The runtime-wide restore serving engine. One instance serves every
/// rank's restore paths (that sharing is the whole point: a storm of
/// clients restoring one container must meet in one cache and one
/// single-flight table).
pub struct RestoreEngine {
    cfg: RestoreConfig,
    cache: ReadCache,
    flight: SingleFlight,
    metrics: Arc<Metrics>,
    /// Optional span recorder: cache hits/misses, single-flight joins and
    /// prefetch waves become visible in `veloc trace` exports.
    tracer: std::sync::Mutex<Option<Arc<TraceRecorder>>>,
}

impl RestoreEngine {
    /// Build an engine over the runtime's fabric. `metrics` defaults to a
    /// private registry when the caller has none.
    pub fn new(
        cfg: RestoreConfig,
        fabric: Arc<StorageFabric>,
        metrics: Option<Arc<Metrics>>,
    ) -> Arc<Self> {
        let metrics = metrics.unwrap_or_else(Metrics::new);
        let cache = ReadCache::new(
            cfg.l1_bytes,
            cfg.l2_bytes,
            cfg.max_entry_bytes,
            fabric,
            Arc::clone(&metrics),
        );
        Arc::new(RestoreEngine {
            cfg,
            cache,
            flight: SingleFlight::default(),
            metrics,
            tracer: std::sync::Mutex::new(None),
        })
    }

    /// Attach the runtime's span recorder after construction.
    pub fn set_tracer(&self, tracer: Arc<TraceRecorder>) {
        *self.tracer.lock().unwrap() = Some(tracer);
    }

    /// The recorder, only when it is both attached and enabled (so the
    /// disabled path never pays more than one mutex peek).
    fn live_tracer(&self) -> Option<Arc<TraceRecorder>> {
        let g = self.tracer.lock().unwrap();
        match &*g {
            Some(t) if t.is_enabled() => Some(Arc::clone(t)),
            _ => None,
        }
    }

    /// The configuration the engine was built from.
    pub fn config(&self) -> &RestoreConfig {
        &self.cfg
    }

    /// The canonical container identity — the one key the cache, the
    /// single-flight table and the prefetcher all share.
    pub fn key(source: &str, name: &str, rank: usize, version: u64) -> String {
        format!("{source}:{name}:r{rank}:v{version}")
    }

    /// Fetch one container through the cache and single-flight planes.
    /// `fetch` is the source-of-truth read (tier/aggregator/rebuild);
    /// it runs at most once per key across all concurrent callers.
    pub fn fetch_container(
        &self,
        source: &str,
        name: &str,
        rank: usize,
        node: usize,
        version: u64,
        fetch: &(dyn Fn(u64) -> Result<Option<Vec<u8>>> + Sync),
    ) -> Result<Option<Arc<Vec<u8>>>> {
        if !self.cfg.enabled {
            return fetch(version).map(|o| o.map(Arc::new));
        }
        let key = Self::key(source, name, rank, version);
        if let Some(data) = self.cache.get(&key) {
            if let Some(t) = self.live_tracer() {
                t.event(
                    "restore.cache.hit",
                    SpanId::NONE,
                    &[("key", key.as_str())],
                    rank as u64,
                );
            }
            return Ok(Some(data));
        }
        match self.flight.run(&key, || {
            self.metrics.incr("restore.cache.misses", 1);
            if let Some(t) = self.live_tracer() {
                t.event(
                    "restore.cache.miss",
                    SpanId::NONE,
                    &[("key", key.as_str())],
                    rank as u64,
                );
            }
            Ok(fetch(version)?
                .map(|data| self.cache.insert(&key, node, source_cost(source), data)))
        }) {
            FlightOutcome::Led(res) => res,
            FlightOutcome::Joined(shared) => {
                self.metrics.incr("restore.singleflight.coalesced", 1);
                if let Some(t) = self.live_tracer() {
                    t.event(
                        "restore.singleflight.join",
                        SpanId::NONE,
                        &[("key", key.as_str())],
                        rank as u64,
                    );
                }
                // A leader miss/failure joins as a miss; re-issuing the
                // fetch here would defeat the coalescing under storms.
                Ok(shared)
            }
        }
    }

    /// Serve a full restore: fetch the primary container through the
    /// cache, prefetch its predicted chain hops in bounded-depth waves,
    /// then reassemble through [`materialize_planned`] against the
    /// warmed cache. `fetch` is the level's raw container read, keyed by
    /// version; `store` is the optional node chunk-store fast path.
    pub fn materialize(
        &self,
        source: &str,
        name: &str,
        rank: usize,
        node: usize,
        version: u64,
        store: Option<&ChunkStore>,
        fetch: &(dyn Fn(u64) -> Result<Option<Vec<u8>>> + Sync),
    ) -> Result<Option<Checkpoint>> {
        let Some(primary) = self.fetch_container(source, name, rank, node, version, fetch)?
        else {
            return Ok(None);
        };
        if self.cfg.enabled {
            self.prefetch_chain(source, name, rank, node, &primary, fetch);
        }
        // The authoritative walk consults the warmed cache first and
        // falls back to the raw fetch, so a chain misprediction costs a
        // wasted prefetch, never a wrong (or failed) restore.
        let cached_fetch = |v: u64| -> Option<Vec<u8>> {
            self.fetch_container(source, name, rank, node, v, fetch)
                .ok()
                .flatten()
                .map(|a| (*a).clone())
        };
        let (ckpt, plan) = materialize_planned((*primary).clone(), store, &cached_fetch)?;
        self.metrics.incr("restore.plan.hops", plan.hops.len() as u64);
        Ok(Some(ckpt))
    }

    /// Speculatively fetch the predicted chain ancestors of a delta
    /// container in waves of `prefetch_depth` concurrent reads. Purely a
    /// cache warmer: failures and mispredictions are ignored.
    fn prefetch_chain(
        &self,
        source: &str,
        name: &str,
        rank: usize,
        node: usize,
        primary: &Arc<Vec<u8>>,
        fetch: &(dyn Fn(u64) -> Result<Option<Vec<u8>>> + Sync),
    ) {
        let Ok(raw) = maybe_decompress((**primary).clone()) else {
            return;
        };
        if !manifest::is_delta(&raw) {
            return;
        }
        let Ok((m, _)) = manifest::decode(&raw) else {
            return;
        };
        let hops = predicted_hops(&m);
        if hops.is_empty() {
            return;
        }
        let depth = self.cfg.prefetch_depth.max(1);
        self.metrics.set("restore.prefetch.depth", depth as u64);
        self.metrics.incr("restore.prefetch.issued", hops.len() as u64);
        let tracer = self.live_tracer();
        for (i, wave) in hops.chunks(depth).enumerate() {
            let span = match &tracer {
                Some(t) => {
                    let ws = i.to_string();
                    let fs = wave.len().to_string();
                    t.open(
                        "restore.prefetch.wave",
                        SpanId::NONE,
                        &[("wave", ws.as_str()), ("fetches", fs.as_str())],
                        rank as u64,
                    )
                }
                None => SpanId::NONE,
            };
            std::thread::scope(|s| {
                for &v in wave {
                    s.spawn(move || {
                        let _ = self.fetch_container(source, name, rank, node, v, fetch);
                    });
                }
            });
            if let Some(t) = &tracer {
                t.close(span);
            }
        }
    }

    /// Fault injection (sim / tests): corrupt the cached bytes of one
    /// container without touching its stored CRC, so the next hit trips
    /// the fingerprint check. Returns false if the key is not resident.
    pub fn poison(&self, source: &str, name: &str, rank: usize, version: u64) -> bool {
        self.cache.poison(&Self::key(source, name, rank, version))
    }

    /// Drop every cached entry (both levels). Called on injected
    /// failures: the cache is serving-layer node memory and must not
    /// outlive the tier state it mirrors.
    pub fn invalidate_all(&self) {
        self.cache.invalidate_all();
    }

    /// Resident L1 bytes (introspection / tests).
    pub fn cached_bytes(&self) -> u64 {
        self.cache.l1_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::FabricConfig;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn engine(cfg: RestoreConfig) -> Arc<RestoreEngine> {
        let fabric = Arc::new(
            StorageFabric::build(&FabricConfig {
                nodes: 1,
                ..Default::default()
            })
            .unwrap(),
        );
        RestoreEngine::new(cfg, fabric, None)
    }

    #[test]
    fn config_validation() {
        assert!(RestoreConfig::default().validate().is_ok());
        let mut c = RestoreConfig::default();
        c.l1_bytes = 1024;
        assert!(c.validate().is_err());
        let mut c = RestoreConfig::default();
        c.max_entry_bytes = c.l1_bytes * 2;
        assert!(c.validate().is_err());
        let mut c = RestoreConfig::default();
        c.prefetch_depth = 0;
        assert!(c.validate().is_err());
        // Disabled configs skip validation entirely.
        let mut c = RestoreConfig::default();
        c.enabled = false;
        c.l1_bytes = 0;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn read_through_fetches_once_then_serves_from_cache() {
        let eng = engine(RestoreConfig::default());
        let fetches = AtomicU64::new(0);
        let fetch = |_v: u64| -> Result<Option<Vec<u8>>> {
            fetches.fetch_add(1, Ordering::SeqCst);
            Ok(Some(vec![7u8; 2048]))
        };
        for _ in 0..5 {
            let got = eng
                .fetch_container("pfs", "app", 0, 0, 3, &fetch)
                .unwrap()
                .unwrap();
            assert_eq!(*got, vec![7u8; 2048]);
        }
        assert_eq!(fetches.load(Ordering::SeqCst), 1);
        assert_eq!(eng.metrics.counter("restore.cache.hits"), 4);
        assert_eq!(eng.metrics.counter("restore.cache.misses"), 1);
    }

    #[test]
    fn disabled_engine_is_a_transparent_passthrough() {
        let mut cfg = RestoreConfig::default();
        cfg.enabled = false;
        let eng = engine(cfg);
        let fetches = AtomicU64::new(0);
        let fetch = |_v: u64| -> Result<Option<Vec<u8>>> {
            fetches.fetch_add(1, Ordering::SeqCst);
            Ok(Some(vec![1u8; 64]))
        };
        eng.fetch_container("pfs", "app", 0, 0, 1, &fetch).unwrap();
        eng.fetch_container("pfs", "app", 0, 0, 1, &fetch).unwrap();
        assert_eq!(fetches.load(Ordering::SeqCst), 2, "no caching when disabled");
        assert_eq!(eng.cached_bytes(), 0);
    }

    #[test]
    fn materialize_passthrough_and_poison_refetch() {
        let eng = engine(RestoreConfig::default());
        let mut ckpt = Checkpoint::new("app", 0, 1);
        ckpt.push_region(0, vec![5u8; 4096]);
        let encoded = ckpt.encode();
        let fetches = AtomicU64::new(0);
        let fetch = |_v: u64| -> Result<Option<Vec<u8>>> {
            fetches.fetch_add(1, Ordering::SeqCst);
            Ok(Some(encoded.clone()))
        };
        let out = eng
            .materialize("pfs", "app", 0, 0, 1, None, &fetch)
            .unwrap()
            .unwrap();
        assert_eq!(out, ckpt);
        // Poison the cached container: the corrupt bytes are never
        // served — the engine refetches and restores correctly.
        assert!(eng.poison("pfs", "app", 0, 1));
        let out = eng
            .materialize("pfs", "app", 0, 0, 1, None, &fetch)
            .unwrap()
            .unwrap();
        assert_eq!(out, ckpt);
        assert_eq!(fetches.load(Ordering::SeqCst), 2, "poison forces a refetch");
        assert!(eng.metrics.counter("restore.cache.poisoned") >= 1);
    }

    #[test]
    fn missing_container_is_a_clean_none() {
        let eng = engine(RestoreConfig::default());
        let fetch = |_v: u64| -> Result<Option<Vec<u8>>> { Ok(None) };
        assert!(eng
            .materialize("pfs", "app", 0, 0, 9, None, &fetch)
            .unwrap()
            .is_none());
        // Misses are not negatively cached: a later fetch succeeds.
        let mut ckpt = Checkpoint::new("app", 0, 9);
        ckpt.push_region(0, vec![1u8; 128]);
        let encoded = ckpt.encode();
        let fetch = move |_v: u64| -> Result<Option<Vec<u8>>> { Ok(Some(encoded.clone())) };
        assert!(eng
            .materialize("pfs", "app", 0, 0, 9, None, &fetch)
            .unwrap()
            .is_some());
    }
}
