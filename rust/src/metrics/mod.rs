//! Metrics registry: named counters, gauges, bounded sample reservoirs
//! and fixed-bucket histograms, with optional label sets per series.
//!
//! Counters and gauges live in *separate* stores (an `incr` can never
//! silently accumulate onto a value someone `set`), every family supports
//! `{label="value"}` dimensions (job, level, tier, stage), and hot-path
//! latency distributions go into fixed log-spaced histograms instead of
//! unbounded vectors. The whole registry is exportable three ways: the
//! JSON dump ([`Metrics::to_json`]), the Prometheus text exposition
//! (`obs::prom`), and direct programmatic reads for tests and benches.

use crate::util::json::Json;
use crate::util::stats::Samples;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A label set: `(key, value)` pairs, canonically sorted by key.
pub type Labels = Vec<(String, String)>;

/// One series identity: metric name plus its (possibly empty) label set.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct SeriesKey {
    /// Metric family name (dotted namespace, e.g. `backend.queue_depth`).
    pub name: String,
    /// Sorted label pairs; empty for unlabeled series.
    pub labels: Labels,
}

impl SeriesKey {
    fn new(name: &str, labels: &[(&str, &str)]) -> Self {
        let mut labels: Labels = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        SeriesKey {
            name: name.to_string(),
            labels,
        }
    }

    /// `name` or `name{k=v,k2=v2}` — the JSON-dump key for this series.
    pub fn display(&self) -> String {
        if self.labels.is_empty() {
            return self.name.clone();
        }
        let pairs: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        format!("{}{{{}}}", self.name, pairs.join(","))
    }
}

/// Upper bounds (seconds) of the fixed duration-histogram ladder:
/// log-spaced 1-2.5-5 steps from 1µs to 100s. The implicit final bucket
/// is `+Inf`.
pub const DURATION_BUCKETS: [f64; 25] = [
    1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2,
    2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
];

/// Fixed-bucket histogram: O(bounds) memory regardless of observation
/// count, exact `sum`/`count`, interpolated percentile estimates.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Per-bucket observation counts; `counts[i]` covers
    /// `(bounds[i-1], bounds[i]]`, the last slot is the `+Inf` overflow.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: vec![0; DURATION_BUCKETS.len() + 1],
            sum: 0.0,
            count: 0,
            max: 0.0,
        }
    }
}

impl Histogram {
    /// Record one observation (seconds for duration histograms).
    pub fn observe(&mut self, v: f64) {
        let idx = DURATION_BUCKETS
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(DURATION_BUCKETS.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
        self.max = self.max.max(v);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum over every observation.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Per-bucket (non-cumulative) counts; last slot is `+Inf`.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Percentile estimate (q in [0, 100]) by linear interpolation inside
    /// the bucket holding the target rank; the `+Inf` bucket reports the
    /// tracked maximum.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let prev = cum;
            cum += c;
            if cum >= target {
                if i >= DURATION_BUCKETS.len() {
                    return self.max;
                }
                let lo = if i == 0 { 0.0 } else { DURATION_BUCKETS[i - 1] };
                let hi = DURATION_BUCKETS[i];
                let frac = (target - prev) as f64 / c as f64;
                return lo + (hi - lo) * frac;
            }
        }
        self.max
    }

    /// Median estimate.
    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    /// 95th-percentile estimate.
    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    /// 99th-percentile estimate.
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Exact maximum observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// A point-in-time copy of every series, for exposition and reports.
pub struct MetricsSnapshot {
    /// Monotonic counters.
    pub counters: Vec<(SeriesKey, u64)>,
    /// Last-write-wins gauges.
    pub gauges: Vec<(SeriesKey, u64)>,
    /// Fixed-bucket histograms.
    pub histograms: Vec<(SeriesKey, Histogram)>,
    /// Bounded sample reservoirs (exposed as summaries).
    pub samples: Vec<(String, Samples)>,
}

/// The process-wide registry. All methods are cheap and lock-granular;
/// counter/gauge handles are atomics behind a name-lookup mutex.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<SeriesKey, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<SeriesKey, Arc<AtomicU64>>>,
    samples: Mutex<BTreeMap<String, Samples>>,
    histograms: Mutex<BTreeMap<SeriesKey, Histogram>>,
}

impl Metrics {
    /// Fresh shared registry.
    pub fn new() -> Arc<Self> {
        Arc::new(Metrics::default())
    }

    fn handle(
        store: &Mutex<BTreeMap<SeriesKey, Arc<AtomicU64>>>,
        key: SeriesKey,
    ) -> Arc<AtomicU64> {
        let mut g = store.lock().unwrap();
        Arc::clone(g.entry(key).or_insert_with(|| Arc::new(AtomicU64::new(0))))
    }

    /// Add `by` to the unlabeled counter `name`.
    pub fn incr(&self, name: &str, by: u64) {
        self.incr_with(name, &[], by);
    }

    /// Add `by` to the counter `name{labels}`.
    pub fn incr_with(&self, name: &str, labels: &[(&str, &str)], by: u64) {
        Self::handle(&self.counters, SeriesKey::new(name, labels))
            .fetch_add(by, Ordering::Relaxed);
    }

    /// Read the unlabeled counter `name` (0 if never written).
    pub fn counter(&self, name: &str) -> u64 {
        self.counter_with(name, &[])
    }

    /// Read the counter `name{labels}` (0 if never written).
    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        Self::handle(&self.counters, SeriesKey::new(name, labels)).load(Ordering::Relaxed)
    }

    /// Overwrite the unlabeled gauge `name` (queue depths, cursors).
    /// Gauges live in their own store: a counter `incr` under the same
    /// name can never accumulate onto a gauge value.
    pub fn set(&self, name: &str, value: u64) {
        self.set_with(name, &[], value);
    }

    /// Overwrite the gauge `name{labels}`.
    pub fn set_with(&self, name: &str, labels: &[(&str, &str)], value: u64) {
        Self::handle(&self.gauges, SeriesKey::new(name, labels)).store(value, Ordering::Relaxed);
    }

    /// Read the unlabeled gauge `name` (0 if never set).
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauge_with(name, &[])
    }

    /// Read the gauge `name{labels}` (0 if never set).
    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        Self::handle(&self.gauges, SeriesKey::new(name, labels)).load(Ordering::Relaxed)
    }

    /// Record one value into the bounded reservoir `name`.
    pub fn observe(&self, name: &str, value: f64) {
        self.samples
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(value);
    }

    /// Record one duration into the bounded reservoir `name`.
    pub fn observe_duration(&self, name: &str, d: Duration) {
        self.observe(name, d.as_secs_f64());
    }

    /// Record one value into the fixed-bucket histogram `name{labels}`.
    pub fn observe_hist(&self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.histograms
            .lock()
            .unwrap()
            .entry(SeriesKey::new(name, labels))
            .or_default()
            .observe(value);
    }

    /// Record one duration into the histogram `name{labels}`.
    pub fn observe_hist_duration(&self, name: &str, labels: &[(&str, &str)], d: Duration) {
        self.observe_hist(name, labels, d.as_secs_f64());
    }

    /// Copy of the reservoir `name`, if any values were observed.
    pub fn samples(&self, name: &str) -> Option<Samples> {
        self.samples.lock().unwrap().get(name).cloned()
    }

    /// Copy of the histogram `name{labels}`, if anything was observed.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .get(&SeriesKey::new(name, labels))
            .cloned()
    }

    /// Point-in-time copy of every series (exposition, reports).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let histograms = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), h.clone()))
            .collect();
        let samples = self
            .samples
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        MetricsSnapshot {
            counters,
            gauges,
            histograms,
            samples,
        }
    }

    /// JSON dump: `counters`, `gauges`, `samples` and `histograms` under
    /// distinct keys; labeled series appear as `name{k=v}` entries.
    pub fn to_json(&self) -> Json {
        let snap = self.snapshot();
        let mut counters = Json::obj();
        for (k, v) in &snap.counters {
            counters = counters.set(&k.display(), *v);
        }
        let mut gauges = Json::obj();
        for (k, v) in &snap.gauges {
            gauges = gauges.set(&k.display(), *v);
        }
        let mut samples = Json::obj();
        for (k, s) in &snap.samples {
            samples = samples.set(
                k,
                Json::obj()
                    .set("count", s.observed())
                    .set("mean", s.mean())
                    .set("p50", s.p50())
                    .set("p95", s.p95())
                    .set("p99", s.p99())
                    .set("max", s.max()),
            );
        }
        let mut hists = Json::obj();
        for (k, h) in &snap.histograms {
            hists = hists.set(
                &k.display(),
                Json::obj()
                    .set("count", h.count())
                    .set("sum", h.sum())
                    .set("p50", h.p50())
                    .set("p95", h.p95())
                    .set("p99", h.p99())
                    .set("max", h.max()),
            );
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("samples", samples)
            .set("histograms", hists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("ckpt.count", 1);
        m.incr("ckpt.count", 2);
        assert_eq!(m.counter("ckpt.count"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn samples_summarize() {
        let m = Metrics::new();
        for i in 1..=10 {
            m.observe("lat", i as f64);
        }
        let s = m.samples("lat").unwrap();
        assert_eq!(s.len(), 10);
        assert!((s.mean() - 5.5).abs() < 1e-9);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set("backend.queue_depth", 7);
        m.set("backend.queue_depth", 3);
        assert_eq!(m.gauge("backend.queue_depth"), 3);
    }

    #[test]
    fn counters_and_gauges_are_separate_stores() {
        // Regression for the old aliasing bug: incr after set used to
        // accumulate onto the gauge value through the shared store.
        let m = Metrics::new();
        m.set("depth", 7);
        m.incr("depth", 1);
        assert_eq!(m.gauge("depth"), 7, "incr must not touch the gauge");
        assert_eq!(m.counter("depth"), 1, "set must not seed the counter");
        let j = m.to_json();
        assert_eq!(j.at(&["gauges", "depth"]).unwrap().as_u64(), Some(7));
        assert_eq!(j.at(&["counters", "depth"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn labeled_series_are_distinct() {
        let m = Metrics::new();
        m.incr_with("backend.settled", &[("job", "a")], 2);
        m.incr_with("backend.settled", &[("job", "b")], 5);
        assert_eq!(m.counter_with("backend.settled", &[("job", "a")]), 2);
        assert_eq!(m.counter_with("backend.settled", &[("job", "b")]), 5);
        assert_eq!(m.counter("backend.settled"), 0);
        // Label order never matters: keys canonicalize sorted.
        m.incr_with("x", &[("b", "2"), ("a", "1")], 1);
        assert_eq!(m.counter_with("x", &[("a", "1"), ("b", "2")]), 1);
    }

    #[test]
    fn histogram_buckets_and_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            // 1ms..100ms spread
            m.observe_hist("lat", &[("level", "1")], i as f64 * 1e-3);
        }
        let h = m.histogram("lat", &[("level", "1")]).unwrap();
        assert_eq!(h.count(), 100);
        assert!((h.sum() - 5.050).abs() < 1e-9);
        // p50 must land near 50ms (inside the (25ms, 50ms] bucket).
        assert!(h.p50() > 0.025 && h.p50() <= 0.050, "p50 {}", h.p50());
        assert!(h.p99() > 0.05 && h.p99() <= 0.1, "p99 {}", h.p99());
        assert_eq!(h.max(), 0.1);
        // Bucket counts cover all observations.
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), 100);
    }

    #[test]
    fn histogram_overflow_bucket() {
        let mut h = Histogram::default();
        h.observe(1e9); // way past the last finite bound
        assert_eq!(h.count(), 1);
        assert_eq!(*h.bucket_counts().last().unwrap(), 1);
        assert_eq!(h.percentile(50.0), 1e9, "+Inf bucket reports the max");
    }

    #[test]
    fn json_report_shape() {
        let m = Metrics::new();
        m.incr("a", 7);
        m.observe("b", 1.0);
        m.set("g", 4);
        m.observe_hist("h", &[("tier", "pfs")], 0.5);
        let j = m.to_json();
        assert_eq!(j.at(&["counters", "a"]).unwrap().as_u64(), Some(7));
        assert_eq!(
            j.at(&["samples", "b", "count"]).unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(j.at(&["gauges", "g"]).unwrap().as_u64(), Some(4));
        assert_eq!(
            j.at(&["histograms", "h{tier=pfs}", "count"])
                .unwrap()
                .as_u64(),
            Some(1)
        );
    }
}
