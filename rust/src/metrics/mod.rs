//! Lightweight metrics registry: named counters and duration samples,
//! dumped as JSON for the bench harness and the `veloc report` command.

use crate::util::json::Json;
use crate::util::stats::Samples;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    samples: Mutex<BTreeMap<String, Samples>>,
}

impl Metrics {
    pub fn new() -> Arc<Self> {
        Arc::new(Metrics::default())
    }

    fn counter_handle(&self, name: &str) -> Arc<AtomicU64> {
        let mut g = self.counters.lock().unwrap();
        Arc::clone(
            g.entry(name.to_string())
                .or_insert_with(|| Arc::new(AtomicU64::new(0))),
        )
    }

    pub fn incr(&self, name: &str, by: u64) {
        self.counter_handle(name).fetch_add(by, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counter_handle(name).load(Ordering::Relaxed)
    }

    /// Gauge semantics over the counter store: overwrite the value instead
    /// of accumulating (queue depths, replay cursors). Read back with
    /// [`Metrics::counter`]; reported next to the counters in `to_json`.
    pub fn set(&self, name: &str, value: u64) {
        self.counter_handle(name).store(value, Ordering::Relaxed);
    }

    pub fn observe(&self, name: &str, value: f64) {
        self.samples
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(value);
    }

    pub fn observe_duration(&self, name: &str, d: Duration) {
        self.observe(name, d.as_secs_f64());
    }

    pub fn samples(&self, name: &str) -> Option<Samples> {
        self.samples.lock().unwrap().get(name).cloned()
    }

    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters = counters.set(k, v.load(Ordering::Relaxed));
        }
        let mut samples = Json::obj();
        for (k, s) in self.samples.lock().unwrap().iter() {
            samples = samples.set(
                k,
                Json::obj()
                    .set("count", s.len())
                    .set("mean", s.mean())
                    .set("p50", s.p50())
                    .set("p95", s.p95())
                    .set("p99", s.p99())
                    .set("max", s.max()),
            );
        }
        Json::obj().set("counters", counters).set("samples", samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("ckpt.count", 1);
        m.incr("ckpt.count", 2);
        assert_eq!(m.counter("ckpt.count"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn samples_summarize() {
        let m = Metrics::new();
        for i in 1..=10 {
            m.observe("lat", i as f64);
        }
        let s = m.samples("lat").unwrap();
        assert_eq!(s.len(), 10);
        assert!((s.mean() - 5.5).abs() < 1e-9);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set("backend.queue_depth.a", 7);
        m.set("backend.queue_depth.a", 3);
        assert_eq!(m.counter("backend.queue_depth.a"), 3);
        m.incr("backend.queue_depth.a", 1); // counters and gauges share the store
        assert_eq!(m.counter("backend.queue_depth.a"), 4);
    }

    #[test]
    fn json_report_shape() {
        let m = Metrics::new();
        m.incr("a", 7);
        m.observe("b", 1.0);
        let j = m.to_json();
        assert_eq!(j.at(&["counters", "a"]).unwrap().as_u64(), Some(7));
        assert_eq!(
            j.at(&["samples", "b", "count"]).unwrap().as_usize(),
            Some(1)
        );
    }
}
