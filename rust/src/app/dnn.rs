//! DNN training workload — the *productive checkpointing* scenario of
//! paper §3 (DeepFreeze [3] / DeepClone [5] / the model-discovery
//! workflows of [7]).
//!
//! The model is the AOT-compiled application MLP (L2 `dnn_train_step`
//! through PJRT); its parameter tensors are VeloC critical memory regions.
//! Checkpointing supports two modes:
//!
//! - `Monolithic` — all tensors snapshotted in one region set at the
//!   checkpoint call (the classic blocking approach).
//! - `FineGrained` — DeepFreeze's idea adapted: each layer's tensors are
//!   captured as their own region immediately after the optimizer updates
//!   them, overlapping capture of layer `i` with the (PJRT) update of the
//!   rest of the step; the checkpoint call then only assembles
//!   already-captured regions.

use crate::api::{RegionHandle, VelocClient};
use crate::runtime::{PjrtEngine, Tensor};
use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Synthetic classification task: inputs drawn from class-dependent
/// Gaussian clusters (so the model can actually learn and the loss curve
/// in EXPERIMENTS.md means something).
pub struct SyntheticData {
    rng: Rng,
    dim: usize,
    classes: usize,
    /// class centroids
    centroids: Vec<Vec<f32>>,
}

impl SyntheticData {
    pub fn new(dim: usize, classes: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let centroids = (0..classes)
            .map(|_| (0..dim).map(|_| rng.normal() as f32 * 2.0).collect())
            .collect();
        SyntheticData {
            rng,
            dim,
            classes,
            centroids,
        }
    }

    /// Draw a batch: (x flat [b*dim], labels [b]).
    pub fn batch(&mut self, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(b * self.dim);
        let mut y = Vec::with_capacity(b);
        for _ in 0..b {
            let c = self.rng.range_usize(0, self.classes);
            y.push(c as i32);
            for d in 0..self.dim {
                x.push(self.centroids[c][d] + self.rng.normal() as f32);
            }
        }
        (x, y)
    }
}

/// Checkpoint capture strategy (E7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaptureMode {
    Monolithic,
    FineGrained,
}

pub struct DnnTrainer {
    engine: Arc<PjrtEngine>,
    client_name: String,
    /// Current parameters (6 tensors: w1,b1,w2,b2,w3,b3).
    params: Vec<Tensor>,
    /// One protected region per parameter tensor.
    regions: Vec<RegionHandle>,
    pub step: u64,
    batch: usize,
    dim: usize,
    lr: f32,
    mode: CaptureMode,
    data: SyntheticData,
}

impl DnnTrainer {
    pub fn new(
        client: &VelocClient,
        engine: Arc<PjrtEngine>,
        name: &str,
        lr: f32,
        mode: CaptureMode,
        seed: u64,
    ) -> Result<Self> {
        let man = engine.manifest();
        let batch = man.constant("dnn_batch")?;
        let dim = man.constant("dnn_in")?;
        let classes = man.constant("dnn_classes")?;
        let params: Vec<Tensor> = man
            .load_params("dnn_init")?
            .iter()
            .map(Tensor::from)
            .collect();
        // Region 0 holds (step u64); regions 1..=6 hold the tensors.
        let mut regions = vec![client.mem_protect(0, vec![0u8; 8])];
        for (i, p) in params.iter().enumerate() {
            let bytes = f32s_to_bytes(p.as_f32()?);
            regions.push(client.mem_protect(1 + i as u32, bytes));
        }
        Ok(DnnTrainer {
            engine,
            client_name: name.to_string(),
            params,
            regions,
            step: 0,
            batch,
            dim,
            lr,
            mode,
            data: SyntheticData::new(dim, classes, seed),
        })
    }

    pub fn param_count(&self) -> usize {
        self.params
            .iter()
            .map(|p| p.shape().iter().product::<usize>())
            .sum()
    }

    /// One SGD step through PJRT; returns the loss. In `FineGrained` mode
    /// the updated tensors are copied into their protected regions as they
    /// come back (per-layer capture, overlap-style); in `Monolithic` mode
    /// regions are only refreshed by an explicit [`Self::capture`].
    pub fn train_step(&mut self) -> Result<f32> {
        let (x, y) = self.data.batch(self.batch);
        let mut args = self.params.clone();
        args.push(Tensor::f32(&[self.batch, self.dim], x));
        args.push(Tensor::i32(&[self.batch], y));
        args.push(Tensor::scalar_f32(self.lr));
        let out = self.engine.run("dnn_train_step", &args)?;
        let loss = out[6].as_f32()?[0];
        for (i, t) in out.into_iter().take(6).enumerate() {
            if self.mode == CaptureMode::FineGrained {
                // capture layer i immediately (cheap memcpy into region)
                *self.regions[1 + i].lock().unwrap() =
                    f32s_to_bytes(t.as_f32()?);
            }
            self.params[i] = t;
        }
        self.step += 1;
        *self.regions[0].lock().unwrap() = self.step.to_le_bytes().to_vec();
        Ok(loss)
    }

    /// Snapshot all tensors into their regions (Monolithic path; no-op
    /// cost in FineGrained because regions are already fresh).
    pub fn capture(&self) -> Result<()> {
        if self.mode == CaptureMode::Monolithic {
            for (i, p) in self.params.iter().enumerate() {
                *self.regions[1 + i].lock().unwrap() = f32s_to_bytes(p.as_f32()?);
            }
        }
        Ok(())
    }

    /// Capture + VeloC checkpoint under version = step.
    pub fn checkpoint(&self, client: &VelocClient) -> Result<u64> {
        self.capture()?;
        client.checkpoint(&self.client_name, self.step)?;
        Ok(self.step)
    }

    /// Evaluate current parameters on a fresh batch: (loss, accuracy).
    pub fn evaluate(&mut self) -> Result<(f32, f32)> {
        let (x, y) = self.data.batch(self.batch);
        let mut args = self.params.clone();
        args.push(Tensor::f32(&[self.batch, self.dim], x));
        args.push(Tensor::i32(&[self.batch], y));
        let out = self.engine.run("dnn_loss", &args)?;
        Ok((out[0].as_f32()?[0], out[1].as_f32()?[0]))
    }

    /// Restore params from the freshest VeloC checkpoint.
    pub fn restart(&mut self, client: &VelocClient) -> Result<Option<u64>> {
        let Some(info) = client.restart(&self.client_name)? else {
            return Ok(None);
        };
        // Region 0: step counter.
        {
            let r0 = self.regions[0].lock().unwrap();
            self.step = u64::from_le_bytes(r0[..8].try_into().unwrap());
        }
        let shapes: Vec<Vec<usize>> =
            self.params.iter().map(|p| p.shape().to_vec()).collect();
        for (i, shape) in shapes.iter().enumerate() {
            let bytes = self.regions[1 + i].lock().unwrap().clone();
            let data = bytes_to_f32s(&bytes)
                .map_err(|e| anyhow!("region {}: {e}", i + 1))?;
            if data.len() != shape.iter().product::<usize>() {
                return Err(anyhow!(
                    "region {} length {} does not match tensor shape {:?}",
                    i + 1,
                    data.len(),
                    shape
                ));
            }
            self.params[i] = Tensor::f32(shape, data);
        }
        Ok(Some(info.version))
    }
}
