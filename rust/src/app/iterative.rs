//! HACC-like iterative application harness.
//!
//! Models the checkpoint pattern of the ECP applications VeloC serves
//! (§4: HACC, LatticeQCD, EXAALT): each rank owns large critical state,
//! alternates compute and communication phases (repetitive behaviour the
//! predictive scheduler exploits), and periodically takes a collective
//! checkpoint. Compute is a real memory-walking kernel (so background
//! interference is physically measurable), scaled by `compute_ms`.

use crate::api::{RegionHandle, VelocClient};
use crate::util::rng::Rng;
use anyhow::Result;
use std::time::{Duration, Instant};

/// Per-rank state of the iterative app.
pub struct IterativeApp {
    name: String,
    rank: usize,
    /// Critical regions (e.g. particle arrays) registered with VeloC.
    regions: Vec<RegionHandle>,
    /// Iteration counter — also part of the protected state (region 0's
    /// first 8 bytes) so restart resumes at the right step.
    pub iteration: u64,
    compute_ms: f64,
    rng: Rng,
}

impl IterativeApp {
    /// Register `region_count` regions of `region_bytes` each.
    pub fn new(
        client: &VelocClient,
        name: &str,
        region_count: usize,
        region_bytes: usize,
        compute_ms: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed ^ client.rank() as u64);
        let mut regions = Vec::with_capacity(region_count);
        for id in 0..region_count {
            let mut data = vec![0u8; region_bytes.max(16)];
            rng.fill_bytes(&mut data[8..]);
            // first 8 bytes of region 0 hold the iteration counter
            regions.push(client.mem_protect(id as u32, data));
        }
        IterativeApp {
            name: name.to_string(),
            rank: client.rank(),
            regions,
            iteration: 0,
            compute_ms,
            rng,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn state_bytes(&self) -> u64 {
        self.regions
            .iter()
            .map(|r| r.lock().unwrap().len() as u64)
            .sum()
    }

    /// One compute step: real memory work proportional to `compute_ms`,
    /// then a state mutation (so successive checkpoints differ). Returns
    /// the measured compute duration.
    pub fn step(&mut self) -> Duration {
        let t0 = Instant::now();
        let target = Duration::from_secs_f64(self.compute_ms / 1e3);
        // Memory-walking kernel: repeat until the time budget is burnt.
        let mut scratch = [0u64; 1024];
        let mut x = self.iteration.wrapping_add(1);
        while t0.elapsed() < target {
            for s in scratch.iter_mut() {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                *s ^= x;
            }
            std::hint::black_box(&scratch);
        }
        // Mutate a random slice of a random region.
        self.iteration += 1;
        let ridx = self.rng.range_usize(0, self.regions.len());
        {
            let mut data = self.regions[ridx].lock().unwrap();
            let len = data.len();
            let start = if len > 64 { self.rng.range_usize(8, len - 32) } else { 8.min(len) };
            let end = (start + 32).min(len);
            for b in &mut data[start..end] {
                *b = b.wrapping_add(1);
            }
        }
        // Persist the iteration counter.
        {
            let mut r0 = self.regions[0].lock().unwrap();
            r0[..8].copy_from_slice(&self.iteration.to_le_bytes());
        }
        t0.elapsed()
    }

    /// Checkpoint the app state under version = iteration.
    pub fn checkpoint(&self, client: &VelocClient) -> Result<u64> {
        let version = self.iteration;
        client.checkpoint(&self.name, version)?;
        Ok(version)
    }

    /// Restore from the freshest checkpoint; repositions the iteration
    /// counter. Returns the restored version, if any.
    pub fn restart(&mut self, client: &VelocClient) -> Result<Option<u64>> {
        let Some(info) = client.restart(&self.name)? else {
            return Ok(None);
        };
        let r0 = self.regions[0].lock().unwrap();
        self.iteration = u64::from_le_bytes(r0[..8].try_into().unwrap());
        drop(r0);
        Ok(Some(info.version))
    }

    /// Deep copy of every protected region, in region-id order — the
    /// shadow state the scenario engine verifies restores against
    /// bit-for-bit.
    pub fn snapshot(&self) -> Vec<Vec<u8>> {
        self.regions
            .iter()
            .map(|r| r.lock().unwrap().clone())
            .collect()
    }

    /// Region indices whose current bytes differ from a snapshot (empty =
    /// bit-for-bit identical). A length mismatch marks every region.
    pub fn diff_snapshot(&self, snap: &[Vec<u8>]) -> Vec<usize> {
        if snap.len() != self.regions.len() {
            return (0..self.regions.len().max(snap.len())).collect();
        }
        let mut bad = Vec::new();
        for (i, r) in self.regions.iter().enumerate() {
            if *r.lock().unwrap() != snap[i] {
                bad.push(i);
            }
        }
        bad
    }

    /// A digest of the whole state (for exactness tests).
    pub fn state_digest(&self) -> u32 {
        let mut h = crc32fast::Hasher::new();
        for r in &self.regions {
            h.update(&r.lock().unwrap());
        }
        h.finalize()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{VelocConfig, VelocRuntime};

    fn runtime() -> std::sync::Arc<VelocRuntime> {
        let mut cfg = VelocConfig::default().with_nodes(4, 1);
        cfg.stack.erasure_group = 4;
        VelocRuntime::new(cfg).unwrap()
    }

    #[test]
    fn step_advances_and_mutates() {
        let rt = runtime();
        let client = rt.client(0);
        let mut app = IterativeApp::new(&client, "hacc", 2, 1024, 0.1, 7);
        let d0 = app.state_digest();
        app.step();
        assert_eq!(app.iteration, 1);
        assert_ne!(app.state_digest(), d0);
    }

    #[test]
    fn checkpoint_restart_roundtrip_exact() {
        let rt = runtime();
        let client = rt.client(0);
        let mut app = IterativeApp::new(&client, "hacc", 3, 2048, 0.05, 9);
        for _ in 0..5 {
            app.step();
        }
        let digest = app.state_digest();
        let v = app.checkpoint(&client).unwrap();
        client.checkpoint_wait_done("hacc", v).unwrap();
        // Trash the live state, then restart.
        for _ in 0..3 {
            app.step();
        }
        assert_ne!(app.state_digest(), digest);
        let restored = app.restart(&client).unwrap();
        assert_eq!(restored, Some(5));
        assert_eq!(app.iteration, 5);
        assert_eq!(app.state_digest(), digest);
    }

    #[test]
    fn compute_time_tracks_budget() {
        let rt = runtime();
        let client = rt.client(0);
        let mut app = IterativeApp::new(&client, "hacc", 1, 256, 5.0, 1);
        let d = app.step();
        assert!(d >= Duration::from_millis(4), "{d:?}");
        assert!(d < Duration::from_millis(100), "{d:?}");
    }
}
