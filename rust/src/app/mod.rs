//! Workload harnesses: the applications whose state VeloC protects.

pub mod bsp;
pub mod dnn;
pub mod iterative;

pub use bsp::BspApp;
pub use dnn::{CaptureMode, DnnTrainer, SyntheticData};
pub use iterative::IterativeApp;
