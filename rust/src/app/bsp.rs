//! BSP (bulk-synchronous parallel) workload harness: a 1-D halo-exchange
//! stencil over the rank ring, synchronized with the `cluster::comm`
//! collectives — the coordination pattern of the lattice codes the paper
//! names (LatticeQCD) and the shape MPI applications drive VeloC with.
//!
//! Each superstep: exchange halo cells with both neighbours, relax the
//! interior, barrier. Checkpoint versions are agreed collectively with an
//! allreduce (min over proposed versions), mirroring VeloC's collective
//! checkpoint primitive.

use crate::api::{RegionHandle, VelocClient};
use crate::cluster::Endpoint;
use anyhow::Result;
use std::time::Duration;

const TAG_LEFT: u32 = 0x10;
const TAG_RIGHT: u32 = 0x11;
const TAG_VERSION: u32 = 0x20;

pub struct BspApp {
    name: String,
    comm: Endpoint,
    /// Local strip of the 1-D field (f64 cells), VeloC-protected.
    region: RegionHandle,
    cells: usize,
    pub superstep: u64,
    timeout: Duration,
}

fn cells_of(bytes: &[u8]) -> Vec<f64> {
    bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn bytes_of(cells: &[f64], step: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + cells.len() * 8);
    out.extend_from_slice(&step.to_le_bytes());
    for c in cells {
        out.extend_from_slice(&c.to_le_bytes());
    }
    out
}

impl BspApp {
    pub fn new(
        client: &VelocClient,
        comm: Endpoint,
        name: &str,
        cells: usize,
        timeout: Duration,
    ) -> Self {
        assert!(cells >= 2);
        let rank = comm.rank();
        // Initial condition: a bump on rank 0, flat elsewhere.
        let field: Vec<f64> = (0..cells)
            .map(|i| if rank == 0 && i == cells / 2 { 1000.0 } else { 0.0 })
            .collect();
        let region = client.mem_protect(0, bytes_of(&field, 0));
        BspApp {
            name: name.to_string(),
            comm,
            region,
            cells,
            superstep: 0,
            timeout,
        }
    }

    fn load(&self) -> (u64, Vec<f64>) {
        let bytes = self.region.lock().unwrap();
        let step = u64::from_le_bytes(bytes[..8].try_into().unwrap());
        (step, cells_of(&bytes[8..]))
    }

    fn store(&self, step: u64, cells: &[f64]) {
        *self.region.lock().unwrap() = bytes_of(cells, step);
    }

    /// One superstep: halo exchange + Jacobi relaxation + barrier.
    pub fn superstep(&mut self) -> Result<()> {
        let (step, mut field) = self.load();
        let rank = self.comm.rank();
        let world = self.comm.world_size();
        let left = (rank + world - 1) % world;
        let right = (rank + 1) % world;
        // Send boundary cells; receive neighbours' halos.
        self.comm
            .send(left, TAG_RIGHT, field[0].to_le_bytes().to_vec());
        self.comm
            .send(right, TAG_LEFT, field[self.cells - 1].to_le_bytes().to_vec());
        let lh = self.comm.recv(Some(left), TAG_LEFT, self.timeout)?;
        let rh = self.comm.recv(Some(right), TAG_RIGHT, self.timeout)?;
        let halo_l = f64::from_le_bytes(lh.data[..8].try_into().unwrap());
        let halo_r = f64::from_le_bytes(rh.data[..8].try_into().unwrap());
        // Jacobi relaxation with ghost cells.
        let prev = field.clone();
        for i in 0..self.cells {
            let l = if i == 0 { halo_l } else { prev[i - 1] };
            let r = if i == self.cells - 1 { halo_r } else { prev[i + 1] };
            field[i] = 0.25 * l + 0.5 * prev[i] + 0.25 * r;
        }
        self.store(step + 1, &field);
        self.superstep = step + 1;
        self.comm.barrier(self.timeout)?;
        Ok(())
    }

    /// Collectively agreed checkpoint: every rank proposes its superstep;
    /// the minimum wins (stragglers define the consistent cut), then all
    /// ranks checkpoint under that version.
    pub fn collective_checkpoint(&self, client: &VelocClient) -> Result<u64> {
        let version = self.comm.allreduce_u64(
            TAG_VERSION,
            self.superstep,
            u64::min,
            self.timeout,
        )?;
        client.checkpoint(&self.name, version)?;
        Ok(version)
    }

    /// Restore from the freshest checkpoint; returns restored superstep.
    pub fn restart(&mut self, client: &VelocClient) -> Result<Option<u64>> {
        if client.restart(&self.name)?.is_none() {
            return Ok(None);
        }
        let (step, _) = self.load();
        self.superstep = step;
        Ok(Some(step))
    }

    /// Conserved quantity of the relaxation (diffusion preserves the sum
    /// up to fp error) — the correctness probe for tests.
    pub fn field_sum(&self) -> f64 {
        self.load().1.iter().sum()
    }

    pub fn field(&self) -> Vec<f64> {
        self.load().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{VelocConfig, VelocRuntime};
    use crate::cluster::CommWorld;
    use std::sync::Arc;

    const T: Duration = Duration::from_secs(10);

    fn run_world(
        world: usize,
        steps: u64,
        ckpt_every: u64,
    ) -> (Arc<VelocRuntime>, Vec<f64>, f64) {
        let mut cfg = VelocConfig::default().with_nodes(world, 1);
        cfg.stack.erasure_group = if world % 4 == 0 { 4 } else { 0 };
        let rt = VelocRuntime::new(cfg).unwrap();
        let comm = CommWorld::new(world);
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let rt = Arc::clone(&rt);
                let comm = comm.clone();
                std::thread::spawn(move || {
                    let client = rt.client(rank);
                    let mut app =
                        BspApp::new(&client, comm.endpoint(rank), "bsp", 32, T);
                    while app.superstep < steps {
                        app.superstep().unwrap();
                        if ckpt_every > 0 && app.superstep % ckpt_every == 0 {
                            let v = app.collective_checkpoint(&client).unwrap();
                            client.checkpoint_wait_done("bsp", v).unwrap();
                        }
                    }
                    (app.field_sum(), app.field())
                })
            })
            .collect();
        let mut total = 0.0;
        let mut field0 = Vec::new();
        for (rank, h) in handles.into_iter().enumerate() {
            let (s, f) = h.join().unwrap();
            total += s;
            if rank == 0 {
                field0 = f;
            }
        }
        rt.drain();
        (rt, field0, total)
    }

    #[test]
    fn diffusion_conserves_mass_across_ranks() {
        let (_rt, _f, total) = run_world(4, 12, 0);
        assert!((total - 1000.0).abs() < 1e-6, "sum {total}");
    }

    #[test]
    fn bump_spreads_to_neighbours() {
        let (_rt, field0, _) = run_world(4, 12, 0);
        // After 12 supersteps the bump on rank 0 has diffused: the centre
        // is lower than 1000 and the neighbours are non-zero.
        let max0 = field0.iter().cloned().fold(0.0, f64::max);
        assert!(max0 < 1000.0 && max0 > 0.0);
    }

    #[test]
    fn collective_checkpoint_and_restart_roundtrip() {
        let (rt, _f, _) = run_world(4, 10, 5);
        // All ranks checkpointed a consistent version (10 or 5).
        let latest = rt.env().registry.latest_complete("bsp", 4).unwrap();
        assert!(latest == 10 || latest == 5, "latest {latest}");
        // Kill everything; every rank restores the same superstep.
        rt.inject_failure(&crate::cluster::FailureScope::System);
        rt.revive_all();
        let comm = CommWorld::new(4);
        let mut restored = Vec::new();
        for rank in 0..4 {
            let client = rt.client(rank);
            let mut app = BspApp::new(&client, comm.endpoint(rank), "bsp", 32, T);
            restored.push(app.restart(&client).unwrap().unwrap());
        }
        assert!(restored.iter().all(|&s| s == restored[0]));
        assert_eq!(restored[0], latest);
    }
}
