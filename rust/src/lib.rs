//! # VeloC-rs — VEry Low Overhead Checkpointing (paper reproduction)
//!
//! A three-layer reproduction of *VELOC: VEry Low Overhead Checkpointing in
//! the Age of Exascale* (Nicolae et al., SuperCheck'21):
//!
//! - **L3 (this crate)** — the VeloC runtime: client API
//!   ([`api::VelocClient`] over an in-process or socket
//!   [`api::Transport`]), the out-of-process active backend
//!   ([`backend`]: `veloc daemon`, crash-safe job journal, multi-client
//!   fair scheduling), module pipeline ([`pipeline`]), multi-level
//!   resilience modules ([`modules`]), heterogeneous storage tiers
//!   ([`storage`]), aggregated asynchronous flush ([`aggregation`]:
//!   write-combining per-rank checkpoints into large shared-tier
//!   containers), incremental deduplicated checkpointing ([`delta`]:
//!   content-defined chunking, per-node refcounted chunk stores, delta
//!   manifests with chain recovery), cluster + failure simulation
//!   ([`cluster`]), the
//!   deterministic crash–recover–verify scenario engine ([`sim`]), recovery
//!   ([`recovery`]), the restore-side serving plane ([`restore`]:
//!   read-through cache, single-flight dedup, parallel chain prefetch
//!   for restart storms), background-flush scheduling ([`scheduler`]),
//!   checkpoint-interval optimization ([`interval`]) and workloads ([`app`]).
//! - **L2** — JAX compute graphs (interval MLP, seq2seq predictor, the
//!   checkpointed application DNN), AOT-lowered to `artifacts/*.hlo.txt`.
//! - **L1** — Pallas kernels (XOR erasure parity, block checksum, fused
//!   linear), loaded and executed from Rust through [`runtime`] via PJRT.
//!
//! Python runs only at build time (`make artifacts`); the request path is
//! pure Rust + PJRT.
//!
//! Architecture walkthroughs (layer map, checkpoint/restore data flow,
//! the fault-injection catalog, per-level storage destinations) live in
//! `docs/ARCHITECTURE.md`.

// The public surfaces of `api`, `pipeline`, `aggregation`, `delta` and
// `storage` are fully documented and doc-linted; the remaining modules
// are tracked for later passes and opt out explicitly so `cargo doc`
// stays clean under `-D warnings`.
#![warn(missing_docs)]

pub mod aggregation;
pub mod api;
#[allow(missing_docs)]
pub mod app;
pub mod backend;
#[allow(missing_docs)]
pub mod cluster;
pub mod delta;
#[allow(missing_docs)]
pub mod interval;
#[allow(missing_docs)]
pub mod metrics;
#[allow(missing_docs)]
pub mod modules;
pub mod obs;
pub mod pipeline;
#[allow(missing_docs)]
pub mod recovery;
pub mod restore;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod scheduler;
#[allow(missing_docs)]
pub mod sim;
pub mod storage;
#[allow(missing_docs)]
pub mod util;
