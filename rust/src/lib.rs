//! # VeloC-rs — VEry Low Overhead Checkpointing (paper reproduction)
//!
//! A three-layer reproduction of *VELOC: VEry Low Overhead Checkpointing in
//! the Age of Exascale* (Nicolae et al., SuperCheck'21):
//!
//! - **L3 (this crate)** — the VeloC runtime: client API
//!   ([`api::VelocClient`]), module pipeline ([`pipeline`]), multi-level
//!   resilience modules ([`modules`]), heterogeneous storage tiers
//!   ([`storage`]), aggregated asynchronous flush ([`aggregation`]:
//!   write-combining per-rank checkpoints into large shared-tier
//!   containers), incremental deduplicated checkpointing ([`delta`]:
//!   content-defined chunking, per-node refcounted chunk stores, delta
//!   manifests with chain recovery), cluster + failure simulation
//!   ([`cluster`]), the
//!   deterministic crash–recover–verify scenario engine ([`sim`]), recovery
//!   ([`recovery`]), background-flush scheduling ([`scheduler`]),
//!   checkpoint-interval optimization ([`interval`]) and workloads ([`app`]).
//! - **L2** — JAX compute graphs (interval MLP, seq2seq predictor, the
//!   checkpointed application DNN), AOT-lowered to `artifacts/*.hlo.txt`.
//! - **L1** — Pallas kernels (XOR erasure parity, block checksum, fused
//!   linear), loaded and executed from Rust through [`runtime`] via PJRT.
//!
//! Python runs only at build time (`make artifacts`); the request path is
//! pure Rust + PJRT.

pub mod aggregation;
pub mod api;
pub mod app;
pub mod cluster;
pub mod delta;
pub mod interval;
pub mod metrics;
pub mod modules;
pub mod pipeline;
pub mod recovery;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod storage;
pub mod util;
