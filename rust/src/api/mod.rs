//! Public VeloC API — the "simple API at user level" of the abstract.
//!
//! Applications (or the workload harnesses in [`crate::app`]) interact
//! with two types:
//!
//! - [`VelocRuntime`] — one per simulated cluster: owns storage fabric,
//!   topology, the active backend pool, the PJRT engine, the version
//!   registry and one pipeline [`Engine`] per rank.
//! - [`VelocClient`] — one per rank: `mem_protect` critical memory
//!   regions, then `checkpoint` / `checkpoint_wait` / `restart`.
//!
//! ```no_run
//! use veloc::api::{VelocConfig, VelocRuntime};
//! let rt = VelocRuntime::new(VelocConfig::default()).unwrap();
//! let client = rt.client(0);
//! let region = client.mem_protect(0, vec![0u8; 1 << 20]);
//! client.checkpoint("app", 1).unwrap();
//! client.checkpoint_wait("app", 1).unwrap();
//! ```

pub mod config;
pub mod transport;

pub use config::VelocConfig;
pub use transport::Transport;

use crate::aggregation::Aggregator;
use crate::cluster::{KillSwitch, Topology};
use crate::metrics::Metrics;
use crate::modules::{build_stack, ChecksumBackend, Env, FlushGate, VersionRegistry};
use crate::obs::signals::SIG_DEDUP_RATIO;
use crate::obs::{FlightRecorder, ObsHandle, SignalsBus, SpanId, TraceRecorder};
use crate::pipeline::{BoundaryHook, CkptContext, CkptStatus, Engine};
use crate::recovery::{Recovery, Restored};
use crate::runtime::PjrtEngine;
use crate::scheduler::{
    build_gate, InterferenceModel, SchedulerPolicy, UtilizationMonitor,
    UtilizationPredictor,
};
use crate::storage::StorageFabric;
use crate::util::bytes::Checkpoint;
use crate::util::pool::{Priority, ThreadPool};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Handle to a protected memory region: the application mutates the
/// contents through the lock; `checkpoint()` snapshots it atomically.
pub type RegionHandle = Arc<Mutex<Vec<u8>>>;

/// Fault-injection instrumentation installed at runtime construction —
/// used by the deterministic scenario engine ([`crate::sim`]) to land
/// failures at arbitrary points of the pipeline. Production callers use
/// [`VelocRuntime::new`], which installs none of it.
#[derive(Default)]
pub struct SimHooks {
    /// Wraps the scheduler's flush gate (e.g. with the sim's
    /// chunk-counting fault gate) before it is installed into the env.
    pub wrap_gate: Option<Box<dyn FnOnce(Arc<dyn FlushGate>) -> Arc<dyn FlushGate> + Send>>,
    /// Module-boundary hook installed into every rank engine.
    pub boundary: Option<Arc<dyn BoundaryHook>>,
    /// Pre-built storage fabric to adopt instead of building a fresh one
    /// from the config. The backend-crash scenarios use it to model
    /// storage that survives a daemon death: two runtime incarnations
    /// (before and after the "crash") share one fabric, exactly as two
    /// daemon processes share the node's tiers and the PFS.
    pub fabric: Option<Arc<StorageFabric>>,
    /// Span recorder to adopt instead of building one from `config.obs` —
    /// the scenario engine uses it to collect a span timeline from a
    /// failing run as a debugging artifact.
    pub tracer: Option<Arc<TraceRecorder>>,
}

/// Shutdown-aware driver of the aggregation age policy: a ticker thread
/// drains groups whose oldest segment exceeded `max_delay` even when no
/// further submits arrive. Dropping the guard (with the runtime) stops
/// the thread *immediately* through a flag + condvar — the previous
/// design slept on a `Weak` upgrade and could outlive the runtime by up
/// to one tick period.
struct AgeTicker {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl AgeTicker {
    fn spawn(agg: &Arc<Aggregator>, period: std::time::Duration) -> Self {
        let stop: Arc<(Mutex<bool>, Condvar)> = Arc::new((Mutex::new(false), Condvar::new()));
        let stop2 = Arc::clone(&stop);
        let weak = Arc::downgrade(agg);
        let handle = std::thread::Builder::new()
            .name("veloc-age-ticker".to_string())
            .spawn(move || {
                let (lock, cv) = &*stop2;
                let mut stopped = lock.lock().unwrap();
                loop {
                    if *stopped {
                        return;
                    }
                    let (guard, timeout) = cv.wait_timeout(stopped, period).unwrap();
                    stopped = guard;
                    if *stopped {
                        return;
                    }
                    if timeout.timed_out() {
                        // Tick outside the lock so a concurrent drop is
                        // never blocked behind a drain.
                        let Some(agg) = weak.upgrade() else { return };
                        drop(stopped);
                        let _ = agg.flush_aged();
                        drop(agg);
                        stopped = lock.lock().unwrap();
                    }
                }
            })
            .expect("spawn age ticker");
        AgeTicker {
            stop,
            handle: Some(handle),
        }
    }
}

impl Drop for AgeTicker {
    fn drop(&mut self) {
        let (lock, cv) = &*self.stop;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Cluster-wide runtime.
pub struct VelocRuntime {
    config: VelocConfig,
    topology: Topology,
    env: Arc<Env>,
    engines: Vec<Arc<Engine>>,
    backend: Arc<ThreadPool>,
    recovery: Recovery,
    kill: KillSwitch,
    monitor: Arc<UtilizationMonitor>,
    metrics: Arc<Metrics>,
    tracer: Arc<TraceRecorder>,
    signals: Arc<SignalsBus>,
    flight: Option<Arc<FlightRecorder>>,
    /// Highest wave version whose critical path already fed the
    /// histograms (drain-time dedup).
    critpath_recorded: Mutex<Option<u64>>,
    /// Keeps the aggregation age ticker alive for the runtime's lifetime;
    /// dropping the runtime stops the ticker thread immediately.
    _age_ticker: Option<AgeTicker>,
}

impl VelocRuntime {
    /// Build a production runtime (no fault-injection instrumentation).
    pub fn new(config: VelocConfig) -> Result<Arc<Self>> {
        Self::new_with_hooks(config, SimHooks::default())
    }

    /// Build a runtime with fault-injection instrumentation (the scenario
    /// engine's entry point; behaves exactly like [`VelocRuntime::new`]
    /// when `hooks` is empty).
    pub fn new_with_hooks(config: VelocConfig, hooks: SimHooks) -> Result<Arc<Self>> {
        config.validate()?;
        let topology = Topology::new(config.nodes, config.ranks_per_node);
        // Scenario instrumentation: adopt a pre-built fabric (storage that
        // survives a backend-daemon restart) instead of building fresh.
        let fabric = match hooks.fabric {
            Some(f) => f,
            None => Arc::new(StorageFabric::build(&config.fabric)?),
        };
        let registry = VersionRegistry::new();
        let pjrt = if config.use_kernels || config.scheduler == SchedulerPolicy::Predictive {
            match PjrtEngine::load(&config.artifacts_dir()) {
                Ok(e) => Some(e),
                Err(e) => {
                    if config.use_kernels {
                        return Err(anyhow!("kernels requested but artifacts unavailable: {e}"));
                    }
                    None
                }
            }
        } else {
            None
        };

        let monitor = UtilizationMonitor::new(32);
        let interference = if config.calibrate_interference {
            InterferenceModel::calibrate()
        } else {
            InterferenceModel::assumed()
        };
        let predictor = pjrt
            .as_ref()
            .and_then(|e| UtilizationPredictor::from_engine(Arc::clone(e)).ok())
            .map(Arc::new);
        let gate = build_gate(
            config.scheduler,
            &interference,
            predictor,
            Arc::clone(&monitor),
            config.fabric.pfs_bw,
        );
        // Scenario instrumentation: wrap the gate (fault-injecting gates
        // count chunks and land a failure mid-stream).
        let gate = match hooks.wrap_gate {
            Some(wrap) => wrap(gate),
            None => gate,
        };

        let metrics = Metrics::new();
        // Span recorder: sim scenarios hand in their own; otherwise the
        // `obs` config decides whether recording starts enabled.
        let tracer = match hooks.tracer {
            Some(t) => t,
            None => TraceRecorder::with_capacity(config.obs.trace, config.obs.span_capacity),
        };
        // Post-mortem plane: the signals bus always exists (sampling into
        // it is cheap and the view API is useful in-process); the flight
        // recorder only with `obs.flight_dir`. Closed spans mirror into
        // the flight stream the moment the sink is armed.
        let signals = SignalsBus::new(config.obs.signals_capacity);
        let flight = match &config.obs.flight_dir {
            Some(dir) => {
                let f = FlightRecorder::open(dir, "runtime", config.obs.flight_max_bytes)?;
                tracer.set_flight(Arc::clone(&f));
                Some(f)
            }
            None => None,
        };
        // Adaptive tier placement: the candidate pool is every shared
        // tier, ordered primary-first (the level-4 flush target leads, so
        // the static policy reproduces the legacy routing). The KV tier
        // joins the pool only when the KV *module* does not own it as its
        // own resilience level.
        let placement = if config.placement.enabled {
            let primary: Arc<crate::storage::StorageTier> =
                if config.aggregation.enabled
                    && config.aggregation.target == crate::aggregation::AggTarget::BurstBuffer
                {
                    Arc::clone(fabric.burst_buffer().ok_or_else(|| {
                        anyhow!("placement: aggregation targets the burst buffer but the fabric has none")
                    })?)
                } else {
                    Arc::clone(fabric.pfs())
                };
            let mut pool = vec![Arc::clone(&primary)];
            let kv_module_tier = if config.stack.with_kv {
                fabric.kv().map(|t| t.id().to_string())
            } else {
                None
            };
            for t in fabric.shared_tiers() {
                if t.id() == primary.id() {
                    continue;
                }
                // Only the tier the KV *module* owns as its level-5
                // repository is excluded; extra KV-kind tiers declared in
                // fabric.tiers remain level-4 placement destinations.
                if kv_module_tier.as_deref() == Some(t.id()) {
                    continue;
                }
                pool.push(t);
            }
            let eng = crate::storage::PlacementEngine::new(
                pool,
                config.placement.clone(),
                Some(Arc::clone(&metrics)),
            )?;
            eng.set_signals(Arc::clone(&signals));
            Some(eng)
        } else {
            None
        };
        // Incremental dedup state: chunker + per-node refcounted chunk
        // stores + manifest history (the delta pipeline stage and the
        // restore paths both reach it through the env).
        let delta = if config.delta.enabled {
            Some(crate::delta::DeltaState::new(
                config.delta.clone(),
                &fabric,
                Some(Arc::clone(&metrics)),
            )?)
        } else {
            None
        };
        let mut age_ticker = None;
        let aggregator = if config.aggregation.enabled {
            let agg = Aggregator::with_placement(
                topology,
                Arc::clone(&fabric),
                config.aggregation.clone(),
                Some(Arc::clone(&gate)),
                Some(Arc::clone(&metrics)),
                Some(Arc::clone(&registry)),
                placement.clone(),
            );
            // Age-policy driver; the guard stops the thread the moment the
            // runtime drops (see [`AgeTicker`]).
            let period = (config.aggregation.max_delay / 2)
                .max(std::time::Duration::from_millis(10));
            age_ticker = Some(AgeTicker::spawn(&agg, period));
            agg.set_tracer(Arc::clone(&tracer));
            Some(agg)
        } else {
            None
        };

        // Restore-side serving plane: one engine for the whole runtime,
        // so every rank's restores (and a storm of daemon clients) meet
        // in the same cache and single-flight table.
        let restore = if config.restore.enabled {
            let eng = crate::restore::RestoreEngine::new(
                config.restore.clone(),
                Arc::clone(&fabric),
                Some(Arc::clone(&metrics)),
            );
            eng.set_tracer(Arc::clone(&tracer));
            Some(eng)
        } else {
            None
        };

        let env = Arc::new(Env {
            topology,
            fabric,
            pjrt: pjrt.clone(),
            registry,
            scheduler_gate: Some(gate),
            aggregator,
            delta,
            placement,
            restore,
        });

        // Mitigated policies run the active backend at low OS priority
        // (nice 19), the paper's time-slicing strategy; greedy keeps the
        // default priority (the interference baseline).
        let backend_nice = match config.scheduler {
            SchedulerPolicy::Greedy => 0,
            _ => 19,
        };
        let backend = Arc::new(ThreadPool::with_nice(
            config.backend_threads,
            backend_nice,
        ));
        let backend_priority = match config.scheduler {
            SchedulerPolicy::Greedy => Priority::Normal,
            _ => Priority::Background,
        };
        let mut engines = Vec::with_capacity(topology.world_size());
        for _rank in 0..topology.world_size() {
            let stack = build_stack(&env, &config.stack)?;
            let mut engine = Engine::new(stack, config.engine_mode, Some(Arc::clone(&backend)))?
                .with_background_priority(backend_priority);
            if let Some(hook) = &hooks.boundary {
                engine = engine.with_boundary_hook(Arc::clone(hook));
            }
            engines.push(Arc::new(engine));
        }
        let checksum = match (&pjrt, config.use_kernels) {
            (Some(e), true) => ChecksumBackend::Kernel(Arc::clone(e)),
            _ => ChecksumBackend::Crc32,
        };
        let recovery = Recovery::new(Arc::clone(&env), checksum);
        Ok(Arc::new(VelocRuntime {
            kill: KillSwitch::new(topology.world_size()),
            config,
            topology,
            env,
            engines,
            backend,
            recovery,
            monitor,
            metrics,
            tracer,
            signals,
            flight,
            critpath_recorded: Mutex::new(None),
            _age_ticker: age_ticker,
        }))
    }

    /// The configuration the runtime was built from.
    pub fn config(&self) -> &VelocConfig {
        &self.config
    }

    /// Cluster shape (nodes x ranks-per-node).
    pub fn topology(&self) -> Topology {
        self.topology
    }

    /// The shared module environment (fabric, registry, hooks).
    pub fn env(&self) -> &Arc<Env> {
        &self.env
    }

    /// Runtime-wide metrics registry.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Runtime-wide span recorder (inert unless `obs.trace` — or an
    /// adopted sim tracer — enabled it).
    pub fn tracer(&self) -> &Arc<TraceRecorder> {
        &self.tracer
    }

    /// Runtime-wide signals bus (failure inter-arrival, tier health,
    /// queue depth, dedup ratio — see [`crate::obs::signals`]).
    pub fn signals(&self) -> &Arc<SignalsBus> {
        &self.signals
    }

    /// The crash-durable flight recorder, when `obs.flight_dir` is set.
    pub fn flight(&self) -> Option<&Arc<FlightRecorder>> {
        self.flight.as_ref()
    }

    /// Application-utilization monitor feeding the predictive scheduler.
    pub fn monitor(&self) -> &Arc<UtilizationMonitor> {
        &self.monitor
    }

    /// The active backend pool running async pipeline tails.
    pub fn backend(&self) -> &Arc<ThreadPool> {
        &self.backend
    }

    /// Restart orchestration (level probing, validation, frontiers).
    pub fn recovery(&self) -> &Recovery {
        &self.recovery
    }

    /// The write-combining aggregator, when aggregation is enabled.
    pub fn aggregator(&self) -> Option<&Arc<Aggregator>> {
        self.env.aggregator.as_ref()
    }

    /// The incremental-dedup state, when delta checkpointing is enabled.
    pub fn delta(&self) -> Option<&Arc<crate::delta::DeltaState>> {
        self.env.delta.as_ref()
    }

    /// The adaptive tier-placement engine, when placement is enabled.
    pub fn placement(&self) -> Option<&Arc<crate::storage::PlacementEngine>> {
        self.env.placement.as_ref()
    }

    /// The restore-side serving engine (read-through cache, single-flight
    /// dedup, chain prefetch), when `restore.enabled`.
    pub fn restore_engine(&self) -> Option<&Arc<crate::restore::RestoreEngine>> {
        self.env.restore.as_ref()
    }

    /// One rank's pipeline engine.
    pub fn engine(&self, rank: usize) -> &Arc<Engine> {
        &self.engines[rank]
    }

    /// Every rank's engine, indexed by rank.
    pub fn engines(&self) -> &[Arc<Engine>] {
        &self.engines
    }

    /// Per-rank liveness switch (failure injection kills, revive_all revives).
    pub fn kill_switch(&self) -> &KillSwitch {
        &self.kill
    }

    /// Per-rank client handle over the in-process transport (the
    /// out-of-process equivalent is
    /// [`BackendClient::client`](crate::backend::BackendClient::client)).
    pub fn client(self: &Arc<Self>, rank: usize) -> VelocClient {
        assert!(rank < self.topology.world_size());
        VelocClient::with_transport(
            Arc::new(LocalTransport {
                runtime: Arc::clone(self),
            }),
            rank,
        )
    }

    /// Inject a failure: kill the affected ranks and wipe the storage of
    /// the affected failure domains.
    pub fn inject_failure(&self, scope: &crate::cluster::FailureScope) {
        // The restore cache is serving-layer node memory mirroring tier
        // bytes; a failure that wipes tiers must wipe the mirror too, or
        // restores could serve data the failure destroyed.
        if let Some(r) = &self.env.restore {
            r.invalidate_all();
        }
        let inj = crate::cluster::FailureInjector::new(self.topology, 1.0);
        for r in inj.affected_ranks(scope) {
            self.kill.kill(r);
        }
        for n in inj.affected_nodes(scope) {
            self.env.fabric.fail_node(n);
            // Write-combining buffers are node memory: segments staged by
            // the failed node's ranks die with it.
            if let Some(agg) = &self.env.aggregator {
                agg.fail_node(n);
            }
            // Chunk-store counts and manifest history are node state too:
            // void them so post-restart checkpoints re-write payloads and
            // start a fresh full chain instead of referencing wiped data.
            if let Some(d) = &self.env.delta {
                let ranks: Vec<usize> = self.topology.ranks_of_node(n).collect();
                d.fail_node(n, &ranks);
            }
        }
        if matches!(scope, crate::cluster::FailureScope::System) {
            self.env.fabric.fail_system();
            if let Some(agg) = &self.env.aggregator {
                agg.fail_all_buffers();
            }
            if let Some(d) = &self.env.delta {
                d.fail_all();
            }
        }
        self.metrics.incr("failures.injected", 1);
        // Post-mortem trail: sample the failure inter-arrival series and
        // leave a durable injection marker + signals snapshot, so a dump
        // cut right here still carries the failure history.
        self.signals.note_failure();
        if let Some(f) = &self.flight {
            f.event("failure.injected", &[("scope", &format!("{scope:?}"))]);
            f.signals(&self.signals.snapshot());
            f.flush();
        }
    }

    /// Revive killed ranks (model of the job scheduler respawning them).
    pub fn revive_all(&self) {
        for r in 0..self.topology.world_size() {
            self.kill.revive(r);
        }
        // A respawned backend replays any GC intent a crashed writer left
        // behind (the chunk stores' refcount-ledger replay).
        if let Some(d) = &self.env.delta {
            d.recover_all();
        }
    }

    /// Wait until the active backend drained all queued pipeline tails,
    /// then force out any checkpoint segments still buffered in the
    /// aggregator (straggler groups below every drain threshold).
    pub fn drain(&self) {
        self.backend.wait_idle();
        if let Some(agg) = &self.env.aggregator {
            if let Err(e) = agg.flush_all() {
                // Buffered segments are still volatile; make that visible
                // instead of silently reporting a clean drain.
                self.metrics.incr("agg.drain.errors", 1);
                eprintln!("veloc: aggregated drain failed: {e:#}");
            }
        }
        // Every command of the drained waves has settled: close their
        // root spans so the timeline validates/exports cleanly.
        self.tracer.close_open_waves();
        // Surface span loss (bounded ring overflow) as a gauge, sample the
        // dedup ratio off the delta counters, and persist a signals
        // snapshot + critical-path metrics now that the waves are whole.
        self.metrics.set("obs.spans.dropped", self.tracer.dropped());
        let logical = self.metrics.counter("delta.bytes.logical");
        let physical = self.metrics.counter("delta.bytes.physical");
        if physical > 0 {
            self.signals
                .sample(SIG_DEDUP_RATIO, logical as f64 / physical as f64);
        }
        if self.tracer.is_enabled() {
            // Repeated drains re-analyze the same retained spans; only
            // waves newer than the last recorded version feed the
            // histograms, so a drain per wave does not double-observe.
            let waves = crate::obs::critpath::analyze(&self.tracer.snapshot());
            let mut last = self.critpath_recorded.lock().unwrap();
            let fresh: Vec<_> = waves
                .into_iter()
                .filter(|w| *last < Some(w.version))
                .collect();
            if let Some(max) = fresh.iter().map(|w| w.version).max() {
                *last = Some(max);
            }
            crate::obs::critpath::record_metrics(&self.metrics, &fresh);
        }
        if let Some(f) = &self.flight {
            f.signals(&self.signals.snapshot());
            f.flush();
        }
    }

    /// Cold restart: reload the persisted lineage of `name` into the
    /// (empty) in-process registry, so `restart()` can find the shared
    /// copies a previous process wrote. Every shared tier is probed and
    /// every parseable copy merged — the lineage fails over to other
    /// tiers when the PFS is unwritable, and records accumulate, so
    /// merging a stale copy next to a fresh one is harmless. Returns
    /// false if no lineage object exists anywhere. Requires a persistent
    /// backing (e.g. `fabric.pfs_dir`) to be meaningful across processes.
    pub fn reload_lineage(&self, name: &str) -> Result<bool> {
        let key = format!("lineage.{name}.json");
        let mut loaded = false;
        let mut first_err: Option<anyhow::Error> = None;
        for tier in self.env.fabric.shared_tiers() {
            let Some((data, _)) = tier.get(&key) else {
                continue;
            };
            // A torn or corrupt copy on one tier (e.g. a writer that died
            // mid-failover) must not abort the reload while another tier
            // holds an intact one — but if *no* copy loads, the error must
            // surface: "corrupt lineage" and "never checkpointed" are very
            // different operator situations.
            let parsed = std::str::from_utf8(&data)
                .map_err(anyhow::Error::from)
                .and_then(|text| {
                    crate::util::json::Json::parse(text).map_err(|e| anyhow!("{e}"))
                })
                .and_then(|j| self.env.registry.load_json(&j));
            match parsed {
                Ok(()) => loaded = true,
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("{key} on {}: {e}", tier.id()));
                    }
                }
            }
        }
        match (loaded, first_err) {
            (false, Some(e)) => Err(e),
            (l, _) => Ok(l),
        }
    }
}

/// The in-process [`Transport`]: client and runtime share one process,
/// submissions go straight into the rank's pipeline engine. This is the
/// path `VelocRuntime::client` wires up; `veloc daemon` clients use
/// [`SocketTransport`](crate::backend::SocketTransport) instead.
pub struct LocalTransport {
    runtime: Arc<VelocRuntime>,
}

impl LocalTransport {
    /// Wrap a runtime (equivalent to what [`VelocRuntime::client`] builds).
    pub fn new(runtime: Arc<VelocRuntime>) -> Self {
        LocalTransport { runtime }
    }
}

impl Transport for LocalTransport {
    fn ready(&self, rank: usize) -> Result<()> {
        if self.runtime.kill.is_killed(rank) {
            return Err(anyhow!("rank {rank} is failed"));
        }
        Ok(())
    }

    fn submit(
        &self,
        rank: usize,
        name: &str,
        version: u64,
        ckpt: Checkpoint,
        started: Instant,
    ) -> Result<()> {
        if self.runtime.kill.is_killed(rank) {
            return Err(anyhow!("rank {rank} is failed"));
        }
        let bytes = ckpt.payload_bytes();
        let node = self.runtime.topology.node_of(rank);
        let mut ctx = CkptContext::new(name, rank, node, version, ckpt);
        let m = &self.runtime.metrics;
        let tracer = self.runtime.tracer();
        if tracer.is_enabled() {
            // One shared root per wave (version); the command span starts
            // at capture time, so the wave root is back-dated to cover it.
            let wave = tracer.wave_root_at(version, started);
            let vs = version.to_string();
            let rs = rank.to_string();
            let cmd = tracer.open_at(
                "ckpt",
                wave,
                &[("rank", rs.as_str()), ("name", name), ("version", vs.as_str())],
                rank as u64,
                started,
            );
            let cap = tracer.open_at("capture", cmd, &[], rank as u64, started);
            tracer.close(cap);
            ctx.obs = ObsHandle {
                tracer: Some(Arc::clone(tracer)),
                metrics: Some(Arc::clone(m)),
                parent: cmd,
            };
        } else {
            ctx.obs.metrics = Some(Arc::clone(m));
        }
        self.runtime.engine(rank).submit(ctx)?;
        m.incr("ckpt.requests", 1);
        m.incr("ckpt.bytes", bytes);
        // Measured from capture start: the region snapshot is part of
        // what the application blocks on.
        m.observe_duration("ckpt.blocking", started.elapsed());
        Ok(())
    }

    fn wait(&self, rank: usize, name: &str, version: u64) -> Result<CkptStatus> {
        self.runtime
            .engine(rank)
            .wait(rank, name, version, self.runtime.config.wait_timeout)
    }

    fn restore(
        &self,
        rank: usize,
        name: &str,
        version: Option<u64>,
    ) -> Result<Option<Restored>> {
        let engine = self.runtime.engine(rank);
        let t0 = Instant::now();
        let tracer = self.runtime.tracer();
        let span = if tracer.is_enabled() {
            let rs = rank.to_string();
            tracer.open(
                "restart",
                SpanId::NONE,
                &[("rank", rs.as_str()), ("name", name)],
                rank as u64,
            )
        } else {
            SpanId::NONE
        };
        let restored = match version {
            Some(v) => self.runtime.recovery.restore_version(engine, name, rank, v),
            None => self.runtime.recovery.restore_latest(engine, name, rank),
        };
        tracer.close(span);
        let restored = restored?;
        if let Some(r) = &restored {
            self.runtime.metrics.incr("restart.success", 1);
            self.runtime.metrics.incr_with(
                "restart.by_level",
                &[("level", crate::pipeline::context::level_name(r.level))],
                1,
            );
            self.runtime
                .metrics
                .observe_duration("restore.latency", t0.elapsed());
        }
        Ok(restored)
    }

    fn report_utilization(&self, util: f32) {
        self.runtime.monitor.record(util);
    }
}

/// Per-rank client: the paper's user-facing API. Region bookkeeping lives
/// client-side; execution goes through the configured [`Transport`] — the
/// same type serves both the linked-in runtime and the `veloc daemon`
/// socket path.
pub struct VelocClient {
    transport: Arc<dyn Transport>,
    rank: usize,
    regions: Mutex<BTreeMap<u32, RegionHandle>>,
}

impl VelocClient {
    /// Build a client over an explicit transport (used by
    /// [`VelocRuntime::client`] and the backend daemon's client paths).
    pub fn with_transport(transport: Arc<dyn Transport>, rank: usize) -> VelocClient {
        VelocClient {
            transport,
            rank,
            regions: Mutex::new(BTreeMap::new()),
        }
    }

    /// The rank this client acts for.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Declare a critical memory region (paper §2: fine-grained
    /// declarations separate from the checkpoint request). Returns the
    /// handle through which the application mutates the region.
    pub fn mem_protect(&self, id: u32, initial: Vec<u8>) -> RegionHandle {
        let handle: RegionHandle = Arc::new(Mutex::new(initial));
        self.regions
            .lock()
            .unwrap()
            .insert(id, Arc::clone(&handle));
        handle
    }

    /// Forget a region.
    pub fn mem_unprotect(&self, id: u32) {
        self.regions.lock().unwrap().remove(&id);
    }

    /// Total bytes currently under protection.
    pub fn protected_bytes(&self) -> u64 {
        self.regions
            .lock()
            .unwrap()
            .values()
            .map(|r| r.lock().unwrap().len() as u64)
            .sum()
    }

    /// Take a checkpoint of all protected regions. Returns once the
    /// transport accepted the submission: after the blocking prefix in
    /// sync/async in-process mode, after the durable staged handoff in
    /// daemon mode. The (name, version) pair must be collectively unique.
    pub fn checkpoint(&self, name: &str, version: u64) -> Result<()> {
        // Fail fast before paying the capture memcpy (a killed rank must
        // not copy its regions just to be rejected).
        self.transport.ready(self.rank)?;
        let t0 = Instant::now();
        let mut ckpt = Checkpoint::new(name, self.rank, version);
        {
            let regions = self.regions.lock().unwrap();
            for (&id, handle) in regions.iter() {
                ckpt.push_region(id, handle.lock().unwrap().clone());
            }
        }
        self.transport.submit(self.rank, name, version, ckpt, t0)
    }

    /// Wait for an earlier checkpoint to settle across all levels;
    /// [`CkptStatus::TimedOut`] reports an expired wait budget.
    pub fn checkpoint_wait(&self, name: &str, version: u64) -> Result<CkptStatus> {
        self.transport.wait(self.rank, name, version)
    }

    /// Strict wait: anything but `Done` — a pipeline failure *or* the
    /// typed timeout — is an error. For callers that would otherwise
    /// discard the returned status (harnesses, examples), so a stalled
    /// engine fails loudly at the wait instead of passing silently.
    /// Returns the highest settled resilience level.
    pub fn checkpoint_wait_done(&self, name: &str, version: u64) -> Result<u8> {
        match self.checkpoint_wait(name, version)? {
            CkptStatus::Done(level) => Ok(level),
            other => Err(anyhow!(
                "checkpoint {name} v{version} rank {} did not settle: {other:?}",
                self.rank
            )),
        }
    }

    /// Restore the freshest recoverable version and load region contents
    /// back into the protected handles. Returns what was restored.
    pub fn restart(&self, name: &str) -> Result<Option<RestartInfo>> {
        let restored = self.transport.restore(self.rank, name, None)?;
        self.apply(restored)
    }

    /// Restore a specific version.
    pub fn restart_version(&self, name: &str, version: u64) -> Result<Option<RestartInfo>> {
        let restored = self.transport.restore(self.rank, name, Some(version))?;
        self.apply(restored)
    }

    fn apply(&self, restored: Option<Restored>) -> Result<Option<RestartInfo>> {
        let Some(r) = restored else {
            return Ok(None);
        };
        let regions = self.regions.lock().unwrap();
        for region in &r.ckpt.regions {
            if let Some(handle) = regions.get(&region.id) {
                *handle.lock().unwrap() = region.data.clone();
            }
        }
        Ok(Some(RestartInfo {
            version: r.version,
            level: r.level,
            iteration: r.ckpt.meta.iteration,
        }))
    }

    /// Report application utilization (feeds the predictive scheduler;
    /// advisory over transports without a feedback channel).
    pub fn report_utilization(&self, util: f32) {
        self.transport.report_utilization(util);
    }
}

/// Outcome of a successful restart.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RestartInfo {
    /// Restored checkpoint version.
    pub version: u64,
    /// Resilience level that served the restore.
    pub level: u8,
    /// Application iteration recorded in the checkpoint.
    pub iteration: u64,
}
