//! Client transport abstraction: the seam between the user-facing
//! [`VelocClient`](crate::api::VelocClient) and whatever executes the
//! checkpoint pipeline.
//!
//! Two implementations exist:
//!
//! - [`LocalTransport`](crate::api::LocalTransport) — the historical
//!   in-process path: the client and the
//!   [`VelocRuntime`](crate::api::VelocRuntime) live in one process,
//!   submits go straight into the rank's pipeline engine.
//! - [`SocketTransport`](crate::backend::SocketTransport) — the
//!   out-of-process path: the runtime lives inside the `veloc daemon`
//!   backend and the client speaks the length-prefixed wire protocol over
//!   a Unix domain socket (`crate::backend`).
//!
//! Both sit behind the same [`VelocClient`](crate::api::VelocClient)
//! API, so an application links once and chooses the process model at
//! configuration time — the paper's active-backend split (checkpoint
//! post-processing survives independently of the application process)
//! without an API fork.

use crate::pipeline::CkptStatus;
use crate::recovery::Restored;
use crate::util::bytes::Checkpoint;
use anyhow::Result;
use std::time::Instant;

/// What a [`VelocClient`](crate::api::VelocClient) needs from its
/// execution side. Implementations are shared (`Arc<dyn Transport>`) and
/// must be safe to call from many application threads.
pub trait Transport: Send + Sync {
    /// Cheap pre-capture check: is a submit for `rank` even possible?
    /// Called before the client pays the region snapshot, so e.g. a
    /// killed rank does not copy gigabytes just to be rejected.
    fn ready(&self, _rank: usize) -> Result<()> {
        Ok(())
    }

    /// Submit a captured checkpoint for `(rank, name, version)`. Returns
    /// once the submission is *accepted*: for the in-process path that is
    /// after the blocking pipeline prefix ran; for the daemon path after
    /// the payload handoff was journaled durably (fsync-before-ack).
    /// `started` is when the client began capturing — implementations
    /// that record client-blocking metrics measure from there, so the
    /// region snapshot cost stays included.
    fn submit(
        &self,
        rank: usize,
        name: &str,
        version: u64,
        ckpt: Checkpoint,
        started: Instant,
    ) -> Result<()>;

    /// Block until the command settles or the transport's wait budget
    /// expires; [`CkptStatus::TimedOut`] reports the expiry.
    fn wait(&self, rank: usize, name: &str, version: u64) -> Result<CkptStatus>;

    /// Restore `version` (or the freshest restorable version when `None`)
    /// for `rank`; `Ok(None)` means no level could serve it.
    fn restore(&self, rank: usize, name: &str, version: Option<u64>) -> Result<Option<Restored>>;

    /// Report application utilization (feeds the predictive scheduler).
    /// Advisory; transports without a feedback channel drop it.
    fn report_utilization(&self, _util: f32) {}
}
