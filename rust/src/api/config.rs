//! Runtime configuration, loadable from JSON (`veloc --config file.json`).

use crate::modules::{StackConfig, TierPolicy};
use crate::pipeline::EngineMode;
use crate::scheduler::SchedulerPolicy;
use crate::storage::{FabricConfig, TimeMode};
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::path::PathBuf;
use std::time::Duration;

/// Full runtime configuration.
#[derive(Clone)]
pub struct VelocConfig {
    pub nodes: usize,
    pub ranks_per_node: usize,
    pub engine_mode: EngineMode,
    pub scheduler: SchedulerPolicy,
    /// Run the interference calibration micro-benchmark at start-up.
    pub calibrate_interference: bool,
    /// Execute erasure/checksum through the Pallas kernels via PJRT.
    pub use_kernels: bool,
    pub backend_threads: usize,
    pub wait_timeout: Duration,
    pub stack: StackConfig,
    pub fabric: FabricConfig,
    /// Override for the artifacts directory.
    pub artifacts: Option<PathBuf>,
}

impl Default for VelocConfig {
    fn default() -> Self {
        let fabric = FabricConfig::default();
        VelocConfig {
            nodes: fabric.nodes,
            ranks_per_node: 2,
            engine_mode: EngineMode::Async,
            scheduler: SchedulerPolicy::LowPriority,
            calibrate_interference: false,
            use_kernels: false,
            backend_threads: 4,
            wait_timeout: Duration::from_secs(60),
            stack: StackConfig::default(),
            fabric,
            artifacts: None,
        }
    }
}

impl VelocConfig {
    pub fn artifacts_dir(&self) -> PathBuf {
        self.artifacts
            .clone()
            .unwrap_or_else(crate::runtime::default_artifacts_dir)
    }

    /// Keep `fabric.nodes` consistent with `nodes`.
    pub fn with_nodes(mut self, nodes: usize, ranks_per_node: usize) -> Self {
        self.nodes = nodes;
        self.ranks_per_node = ranks_per_node;
        self.fabric.nodes = nodes;
        self
    }

    /// Parse from a JSON document (missing keys keep defaults).
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = VelocConfig::default();
        cfg.nodes = j.usize_or("nodes", cfg.nodes);
        cfg.ranks_per_node = j.usize_or("ranks_per_node", cfg.ranks_per_node);
        cfg.fabric.nodes = cfg.nodes;
        cfg.engine_mode = match j.str_or("engine_mode", "async") {
            "sync" => EngineMode::Sync,
            "async" => EngineMode::Async,
            other => bail!("engine_mode must be sync|async, got {other}"),
        };
        cfg.scheduler = match j.str_or("scheduler", "low-priority") {
            "greedy" => SchedulerPolicy::Greedy,
            "low-priority" => SchedulerPolicy::LowPriority,
            "predictive" => SchedulerPolicy::Predictive,
            other => bail!("unknown scheduler policy {other}"),
        };
        cfg.use_kernels = j.bool_or("use_kernels", cfg.use_kernels);
        cfg.calibrate_interference =
            j.bool_or("calibrate_interference", cfg.calibrate_interference);
        cfg.backend_threads = j.usize_or("backend_threads", cfg.backend_threads);
        if let Some(t) = j.get("wait_timeout_secs").and_then(Json::as_f64) {
            cfg.wait_timeout = Duration::from_secs_f64(t);
        }
        if let Some(s) = j.get("stack") {
            cfg.stack.tier_policy = match s.str_or("tier_policy", "fastest") {
                "fastest" => TierPolicy::FastestFirst,
                "concurrency-aware" => TierPolicy::ConcurrencyAware,
                other => bail!("unknown tier_policy {other}"),
            };
            cfg.stack.erasure_group = s.usize_or("erasure_group", cfg.stack.erasure_group);
            cfg.stack.use_kernels = cfg.use_kernels;
            cfg.stack.with_checksum = s.bool_or("checksum", cfg.stack.with_checksum);
            cfg.stack.with_compression =
                s.bool_or("compression", cfg.stack.with_compression);
            cfg.stack.with_kv = s.bool_or("kvstore", cfg.stack.with_kv);
            cfg.stack.with_partner = s.bool_or("partner", cfg.stack.with_partner);
            cfg.stack.with_transfer = s.bool_or("transfer", cfg.stack.with_transfer);
            cfg.stack.keep_versions = s.usize_or("keep_versions", cfg.stack.keep_versions);
        } else {
            cfg.stack.use_kernels = cfg.use_kernels;
        }
        if let Some(f) = j.get("fabric") {
            cfg.fabric.dram_capacity =
                f.usize_or("dram_capacity", cfg.fabric.dram_capacity as usize) as u64;
            cfg.fabric.with_nvme = f.bool_or("nvme", cfg.fabric.with_nvme);
            cfg.fabric.with_ssd = f.bool_or("ssd", cfg.fabric.with_ssd);
            cfg.fabric.with_kv = f.bool_or("kv", cfg.fabric.with_kv);
            cfg.fabric.with_burst_buffer =
                f.bool_or("burst_buffer", cfg.fabric.with_burst_buffer);
            cfg.fabric.pfs_bw = f.f64_or("pfs_bw", cfg.fabric.pfs_bw);
            if let Some(scale) = f.get("emulate_scale").and_then(Json::as_f64) {
                cfg.fabric.time_mode = TimeMode::Emulate { scale };
            }
        }
        // KV module needs the KV tier.
        if cfg.stack.with_kv {
            cfg.fabric.with_kv = true;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&crate::util::json::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_consistent() {
        let c = VelocConfig::default();
        assert_eq!(c.nodes, c.fabric.nodes);
        assert_eq!(c.engine_mode, EngineMode::Async);
    }

    #[test]
    fn from_json_overrides() {
        let j = Json::parse(
            r#"{
                "nodes": 8, "ranks_per_node": 4,
                "engine_mode": "sync",
                "scheduler": "predictive",
                "stack": {"tier_policy": "concurrency-aware", "erasure_group": 8,
                          "compression": true, "kvstore": true},
                "fabric": {"pfs_bw": 1e9}
            }"#,
        )
        .unwrap();
        let c = VelocConfig::from_json(&j).unwrap();
        assert_eq!(c.nodes, 8);
        assert_eq!(c.fabric.nodes, 8);
        assert_eq!(c.engine_mode, EngineMode::Sync);
        assert_eq!(c.scheduler, SchedulerPolicy::Predictive);
        assert_eq!(c.stack.tier_policy, TierPolicy::ConcurrencyAware);
        assert_eq!(c.stack.erasure_group, 8);
        assert!(c.stack.with_compression);
        assert!(c.fabric.with_kv, "kv module implies kv tier");
        assert_eq!(c.fabric.pfs_bw, 1e9);
    }

    #[test]
    fn bad_values_rejected() {
        let j = Json::parse(r#"{"engine_mode": "turbo"}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"scheduler": "wat"}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
    }

    #[test]
    fn with_nodes_updates_fabric() {
        let c = VelocConfig::default().with_nodes(16, 1);
        assert_eq!(c.fabric.nodes, 16);
        assert_eq!(c.ranks_per_node, 1);
    }
}
