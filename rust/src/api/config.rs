//! Runtime configuration, loadable from JSON (`veloc --config file.json`).

use crate::aggregation::{AggTarget, AggregationConfig};
use crate::backend::BackendConfig;
use crate::delta::DeltaConfig;
use crate::modules::{StackConfig, TierPolicy};
use crate::obs::ObsConfig;
use crate::pipeline::EngineMode;
use crate::restore::RestoreConfig;
use crate::scheduler::SchedulerPolicy;
use crate::storage::{FabricConfig, PlacementConfig, PlacementPolicy, TierDef, TierKind, TimeMode};
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::path::{Component, Path, PathBuf};
use std::time::Duration;

/// Smallest chunk the flush pacing paths accept. `TransferModule` used to
/// clamp smaller values silently; configuration now rejects them instead.
pub const MIN_FLUSH_CHUNK: usize = 4096;

/// Full runtime configuration.
#[derive(Clone)]
pub struct VelocConfig {
    /// Simulated node count (kept consistent with `fabric.nodes`).
    pub nodes: usize,
    /// Application ranks per node.
    pub ranks_per_node: usize,
    /// Sync (linked-in) or async (active backend) pipeline engine.
    pub engine_mode: EngineMode,
    /// Background-flush scheduling policy.
    pub scheduler: SchedulerPolicy,
    /// Run the interference calibration micro-benchmark at start-up.
    pub calibrate_interference: bool,
    /// Execute erasure/checksum through the Pallas kernels via PJRT.
    pub use_kernels: bool,
    /// Active-backend thread count.
    pub backend_threads: usize,
    /// `checkpoint_wait` timeout.
    pub wait_timeout: Duration,
    /// Module-stack composition and knobs.
    pub stack: StackConfig,
    /// Storage fabric shape (tiers, bandwidths, capacities).
    pub fabric: FabricConfig,
    /// Aggregated asynchronous flush (write-combining per-rank checkpoints
    /// into shared containers).
    pub aggregation: AggregationConfig,
    /// Incremental deduplicated checkpointing (content-defined chunking +
    /// delta manifests; only novel chunks move through the levels).
    pub delta: DeltaConfig,
    /// Adaptive heterogeneous-tier placement of shared-tier flushes
    /// (policy, health EWMA, circuit breaker — `crate::storage::placement`).
    pub placement: PlacementConfig,
    /// Restore-side serving plane (read-through cache, single-flight
    /// dedup, parallel chain prefetch — `crate::restore`).
    pub restore: RestoreConfig,
    /// Active-backend daemon settings (`veloc daemon` + the socket
    /// clients — `crate::backend`): home directory, socket, admission
    /// depth, payload handoff and journal durability knobs.
    pub backend: BackendConfig,
    /// Observability plane: span tracing + the daemon's Prometheus
    /// `/metrics` + health endpoint (`crate::obs`).
    pub obs: ObsConfig,
    /// Override for the artifacts directory.
    pub artifacts: Option<PathBuf>,
}

impl Default for VelocConfig {
    fn default() -> Self {
        let fabric = FabricConfig::default();
        VelocConfig {
            nodes: fabric.nodes,
            ranks_per_node: 2,
            engine_mode: EngineMode::Async,
            scheduler: SchedulerPolicy::LowPriority,
            calibrate_interference: false,
            use_kernels: false,
            backend_threads: 4,
            wait_timeout: Duration::from_secs(60),
            stack: StackConfig::default(),
            fabric,
            aggregation: AggregationConfig::default(),
            delta: DeltaConfig::default(),
            placement: PlacementConfig::default(),
            restore: RestoreConfig::default(),
            backend: BackendConfig::default(),
            obs: ObsConfig::default(),
            artifacts: None,
        }
    }
}

impl VelocConfig {
    /// Directory holding the AOT-lowered kernel artifacts.
    pub fn artifacts_dir(&self) -> PathBuf {
        self.artifacts
            .clone()
            .unwrap_or_else(crate::runtime::default_artifacts_dir)
    }

    /// Keep `fabric.nodes` consistent with `nodes`.
    pub fn with_nodes(mut self, nodes: usize, ranks_per_node: usize) -> Self {
        self.nodes = nodes;
        self.ranks_per_node = ranks_per_node;
        self.fabric.nodes = nodes;
        self
    }

    /// Parse from a JSON document (missing keys keep defaults).
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = VelocConfig::default();
        cfg.nodes = j.usize_or("nodes", cfg.nodes);
        cfg.ranks_per_node = j.usize_or("ranks_per_node", cfg.ranks_per_node);
        cfg.fabric.nodes = cfg.nodes;
        cfg.engine_mode = match j.str_or("engine_mode", "async") {
            "sync" => EngineMode::Sync,
            "async" => EngineMode::Async,
            other => bail!("engine_mode must be sync|async, got {other}"),
        };
        cfg.scheduler = match j.str_or("scheduler", "low-priority") {
            "greedy" => SchedulerPolicy::Greedy,
            "low-priority" => SchedulerPolicy::LowPriority,
            "predictive" => SchedulerPolicy::Predictive,
            other => bail!("unknown scheduler policy {other}"),
        };
        cfg.use_kernels = j.bool_or("use_kernels", cfg.use_kernels);
        cfg.calibrate_interference =
            j.bool_or("calibrate_interference", cfg.calibrate_interference);
        cfg.backend_threads = j.usize_or("backend_threads", cfg.backend_threads);
        if let Some(t) = j.get("wait_timeout_secs").and_then(Json::as_f64) {
            cfg.wait_timeout = Duration::from_secs_f64(t);
        }
        if let Some(s) = j.get("stack") {
            cfg.stack.tier_policy = match s.str_or("tier_policy", "fastest") {
                "fastest" => TierPolicy::FastestFirst,
                "concurrency-aware" => TierPolicy::ConcurrencyAware,
                other => bail!("unknown tier_policy {other}"),
            };
            cfg.stack.erasure_group = s.usize_or("erasure_group", cfg.stack.erasure_group);
            cfg.stack.use_kernels = cfg.use_kernels;
            cfg.stack.with_checksum = s.bool_or("checksum", cfg.stack.with_checksum);
            cfg.stack.with_compression =
                s.bool_or("compression", cfg.stack.with_compression);
            cfg.stack.with_kv = s.bool_or("kvstore", cfg.stack.with_kv);
            cfg.stack.with_partner = s.bool_or("partner", cfg.stack.with_partner);
            cfg.stack.with_transfer = s.bool_or("transfer", cfg.stack.with_transfer);
            cfg.stack.keep_versions = s.usize_or("keep_versions", cfg.stack.keep_versions);
            cfg.stack.flush_chunk = s.usize_or("flush_chunk", cfg.stack.flush_chunk);
        } else {
            cfg.stack.use_kernels = cfg.use_kernels;
        }
        if let Some(f) = j.get("fabric") {
            cfg.fabric.dram_capacity =
                f.usize_or("dram_capacity", cfg.fabric.dram_capacity as usize) as u64;
            cfg.fabric.with_nvme = f.bool_or("nvme", cfg.fabric.with_nvme);
            cfg.fabric.with_ssd = f.bool_or("ssd", cfg.fabric.with_ssd);
            cfg.fabric.with_kv = f.bool_or("kv", cfg.fabric.with_kv);
            cfg.fabric.with_burst_buffer =
                f.bool_or("burst_buffer", cfg.fabric.with_burst_buffer);
            cfg.fabric.pfs_bw = f.f64_or("pfs_bw", cfg.fabric.pfs_bw);
            cfg.fabric.bb_bw = f.f64_or("bb_bw", cfg.fabric.bb_bw);
            cfg.fabric.kv_bw = f.f64_or("kv_bw", cfg.fabric.kv_bw);
            if let Some(scale) = f.get("emulate_scale").and_then(Json::as_f64) {
                cfg.fabric.time_mode = TimeMode::Emulate { scale };
            }
            if let Some(tiers) = f.get("tiers").and_then(Json::as_arr) {
                for t in tiers {
                    let id = t
                        .get("id")
                        .and_then(Json::as_str)
                        .ok_or_else(|| {
                            anyhow::anyhow!("every fabric.tiers entry needs an \"id\"")
                        })?
                        .to_string();
                    let kind = TierKind::parse(t.str_or("kind", "burst-buffer"))?;
                    let write_bw = t.f64_or("bw", 1.0e9);
                    let capacity = if let Some(gb) = t.get("capacity_gb").and_then(Json::as_f64)
                    {
                        (gb * (1u64 << 30) as f64) as u64
                    } else {
                        t.usize_or("capacity", (256u64 << 30) as usize) as u64
                    };
                    let mount = t
                        .get("mount")
                        .and_then(Json::as_str)
                        .map(PathBuf::from);
                    cfg.fabric.tiers.push(TierDef {
                        id,
                        kind,
                        write_bw,
                        capacity,
                        mount,
                    });
                }
            }
        }
        if let Some(p) = j.get("placement") {
            cfg.placement.enabled = p.bool_or("enabled", cfg.placement.enabled);
            cfg.placement.policy =
                PlacementPolicy::parse(p.str_or("policy", cfg.placement.policy.name()))?;
            cfg.placement.ewma_alpha = p.f64_or("ewma_alpha", cfg.placement.ewma_alpha);
            cfg.placement.breaker_threshold =
                p.usize_or("breaker_threshold", cfg.placement.breaker_threshold as usize)
                    as u32;
            cfg.placement.breaker_probe_after = p.usize_or(
                "breaker_probe_after",
                cfg.placement.breaker_probe_after as usize,
            ) as u32;
            cfg.placement.full_watermark =
                p.f64_or("full_watermark", cfg.placement.full_watermark);
        }
        if let Some(a) = j.get("aggregation") {
            cfg.aggregation.enabled = a.bool_or("enabled", cfg.aggregation.enabled);
            cfg.aggregation.group_ranks =
                a.usize_or("group_ranks", cfg.aggregation.group_ranks);
            if let Some(mb) = a.get("flush_mb").and_then(Json::as_f64) {
                if !(mb >= 0.0) {
                    bail!("aggregation.flush_mb must be >= 0, got {mb}");
                }
                cfg.aggregation.flush_bytes = (mb * (1u64 << 20) as f64) as u64;
            }
            if let Some(ms) = a.get("max_delay_ms").and_then(Json::as_f64) {
                if !(ms >= 0.0) {
                    bail!("aggregation.max_delay_ms must be >= 0, got {ms}");
                }
                cfg.aggregation.max_delay = Duration::from_secs_f64(ms / 1e3);
            }
            cfg.aggregation.version_barrier =
                a.bool_or("version_barrier", cfg.aggregation.version_barrier);
            cfg.aggregation.drain_chunk =
                a.usize_or("drain_chunk", cfg.aggregation.drain_chunk);
            cfg.aggregation.target =
                AggTarget::parse(a.str_or("target", cfg.aggregation.target.name()))?;
        }
        if let Some(b) = j.get("backend") {
            if let Some(dir) = b.get("dir").and_then(Json::as_str) {
                cfg.backend.dir = PathBuf::from(dir);
            }
            if let Some(sock) = b.get("socket").and_then(Json::as_str) {
                cfg.backend.socket = Some(PathBuf::from(sock));
            }
            cfg.backend.queue_depth = b.usize_or("queue_depth", cfg.backend.queue_depth);
            if let Some(kb) = b.get("inline_max_kb").and_then(Json::as_f64) {
                if !(kb >= 0.0) {
                    bail!("backend.inline_max_kb must be >= 0, got {kb}");
                }
                cfg.backend.inline_max = (kb * 1024.0) as usize;
            }
            cfg.backend.fsync = b.bool_or("fsync", cfg.backend.fsync);
            if let Some(mb) = b.get("max_frame_body_mb").and_then(Json::as_f64) {
                if !(mb >= 0.0) {
                    bail!("backend.max_frame_body_mb must be >= 0, got {mb}");
                }
                cfg.backend.max_frame_body = (mb * 1048576.0) as usize;
            }
        }
        if let Some(d) = j.get("delta") {
            cfg.delta.enabled = d.bool_or("enabled", cfg.delta.enabled);
            cfg.delta.min_chunk = d.usize_or("min_chunk", cfg.delta.min_chunk);
            cfg.delta.avg_chunk = d.usize_or("avg_chunk", cfg.delta.avg_chunk);
            cfg.delta.max_chunk = d.usize_or("max_chunk", cfg.delta.max_chunk);
            if let Some(c) = d.get("max_chain").and_then(Json::as_u64) {
                cfg.delta.max_chain = c;
            }
        }
        if let Some(r) = j.get("restore") {
            cfg.restore.enabled = r.bool_or("enabled", cfg.restore.enabled);
            if let Some(mb) = r.get("l1_mb").and_then(Json::as_f64) {
                if !(mb >= 0.0) {
                    bail!("restore.l1_mb must be >= 0, got {mb}");
                }
                cfg.restore.l1_bytes = (mb * (1u64 << 20) as f64) as u64;
            }
            if let Some(mb) = r.get("l2_mb").and_then(Json::as_f64) {
                if !(mb >= 0.0) {
                    bail!("restore.l2_mb must be >= 0, got {mb}");
                }
                cfg.restore.l2_bytes = (mb * (1u64 << 20) as f64) as u64;
            }
            if let Some(kb) = r.get("max_entry_kb").and_then(Json::as_f64) {
                if !(kb >= 0.0) {
                    bail!("restore.max_entry_kb must be >= 0, got {kb}");
                }
                cfg.restore.max_entry_bytes = (kb * 1024.0) as u64;
            }
            cfg.restore.prefetch_depth =
                r.usize_or("prefetch_depth", cfg.restore.prefetch_depth);
        }
        if let Some(o) = j.get("obs") {
            cfg.obs.trace = o.bool_or("trace", cfg.obs.trace);
            if let Some(h) = o.get("http").and_then(Json::as_str) {
                cfg.obs.http = Some(h.to_string());
            }
            cfg.obs.span_capacity = o.usize_or("span_capacity", cfg.obs.span_capacity);
            if let Some(d) = o.get("flight_dir").and_then(Json::as_str) {
                cfg.obs.flight_dir = Some(std::path::PathBuf::from(d));
            }
            if let Some(b) = o.get("flight_max_bytes").and_then(Json::as_u64) {
                cfg.obs.flight_max_bytes = b;
            }
            cfg.obs.signals_capacity =
                o.usize_or("signals_capacity", cfg.obs.signals_capacity);
        }
        // KV module needs the KV tier; a burst-buffer drain target needs
        // the burst-buffer tier.
        if cfg.stack.with_kv {
            cfg.fabric.with_kv = true;
        }
        if cfg.aggregation.enabled && cfg.aggregation.target == AggTarget::BurstBuffer {
            cfg.fabric.with_burst_buffer = true;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Reject configurations the runtime would otherwise have to patch up
    /// silently. Called by `from_json` and `VelocRuntime::new`.
    pub fn validate(&self) -> Result<()> {
        if self.stack.flush_chunk < MIN_FLUSH_CHUNK {
            bail!(
                "stack.flush_chunk = {} is below the {} byte minimum: sub-4KiB \
                 PFS writes defeat the flush pacing (raise flush_chunk)",
                self.stack.flush_chunk,
                MIN_FLUSH_CHUNK
            );
        }
        if self.aggregation.drain_chunk < MIN_FLUSH_CHUNK {
            bail!(
                "aggregation.drain_chunk = {} is below the {} byte minimum",
                self.aggregation.drain_chunk,
                MIN_FLUSH_CHUNK
            );
        }
        if self.aggregation.enabled
            && self.aggregation.target == AggTarget::BurstBuffer
            && !self.fabric.with_burst_buffer
        {
            bail!("aggregation targets the burst buffer but fabric.with_burst_buffer is off");
        }
        // Tier identity: duplicate ids or overlapping mounts would let
        // the last definition silently win (two "tiers" backed by the
        // same directory shadow each other's objects). Reject instead.
        const RESERVED: [&str; 6] =
            ["dram", "nvme", "ssd", "burst-buffer", "pfs", "kv-store"];
        let mut seen_ids: Vec<&str> = Vec::new();
        let mut mounts: Vec<(&str, &Path)> = Vec::new();
        if let Some(dir) = &self.fabric.pfs_dir {
            mounts.push(("pfs", dir.as_path()));
        }
        for def in &self.fabric.tiers {
            if def.id.is_empty() {
                bail!("fabric.tiers: empty tier id");
            }
            if RESERVED.contains(&def.id.as_str()) {
                bail!(
                    "fabric.tiers: id {:?} collides with a built-in tier \
                     (reserved: {RESERVED:?})",
                    def.id
                );
            }
            if seen_ids.contains(&def.id.as_str()) {
                bail!(
                    "fabric.tiers: duplicate tier id {:?} — the last \
                     definition would silently win",
                    def.id
                );
            }
            seen_ids.push(def.id.as_str());
            def.spec()?; // shared-kind check
            if def.write_bw <= 0.0 {
                bail!("fabric.tiers {:?}: bw must be > 0", def.id);
            }
            if def.capacity == 0 {
                bail!("fabric.tiers {:?}: capacity must be > 0", def.id);
            }
            if let Some(m) = &def.mount {
                if m.as_os_str().is_empty() {
                    bail!("fabric.tiers {:?}: empty mount path", def.id);
                }
                for (other_id, other) in &mounts {
                    if paths_overlap(m, other) {
                        bail!(
                            "fabric.tiers {:?}: mount {} overlaps tier {:?} \
                             mount {} — two tiers over one directory shadow \
                             each other's objects",
                            def.id,
                            m.display(),
                            other_id,
                            other.display()
                        );
                    }
                }
                mounts.push((def.id.as_str(), m.as_path()));
            }
        }
        self.placement.validate()?;
        self.delta.validate()?;
        self.restore.validate()?;
        self.backend.validate()?;
        self.obs.validate()?;
        Ok(())
    }

    /// Parse a configuration file (see [`Self::from_json`]).
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&crate::util::json::load(path)?)
    }
}

/// Do two mount paths overlap — equal, or one a component-wise prefix of
/// the other? (`/mnt/bb` vs `/mnt/bb/sub` overlap; `/mnt/bb` vs
/// `/mnt/bb2` do not.) Paths are normalized lexically: `.` is dropped
/// and `..` pops the previous component, so `/mnt/bb/../other` compares
/// as `/mnt/other`.
fn paths_overlap(a: &Path, b: &Path) -> bool {
    let comps = |p: &Path| -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for c in p.components() {
            match c {
                Component::Normal(s) => out.push(s.to_string_lossy().into_owned()),
                Component::RootDir => out.push("/".to_string()),
                Component::ParentDir => match out.last().map(String::as_str) {
                    // ".." never climbs above the root.
                    Some("/") => {}
                    // Nothing to pop (or only unresolved ".."s): keep the
                    // ".." as a component — lexical normalization cannot
                    // resolve it, but it must still distinguish "../data"
                    // from "data".
                    Some("..") | None => out.push("..".to_string()),
                    Some(_) => {
                        out.pop();
                    }
                },
                Component::CurDir | Component::Prefix(_) => {}
            }
        }
        out
    };
    let (ca, cb) = (comps(a), comps(b));
    let n = ca.len().min(cb.len());
    ca[..n] == cb[..n]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_consistent() {
        let c = VelocConfig::default();
        assert_eq!(c.nodes, c.fabric.nodes);
        assert_eq!(c.engine_mode, EngineMode::Async);
    }

    #[test]
    fn from_json_overrides() {
        let j = Json::parse(
            r#"{
                "nodes": 8, "ranks_per_node": 4,
                "engine_mode": "sync",
                "scheduler": "predictive",
                "stack": {"tier_policy": "concurrency-aware", "erasure_group": 8,
                          "compression": true, "kvstore": true},
                "fabric": {"pfs_bw": 1e9}
            }"#,
        )
        .unwrap();
        let c = VelocConfig::from_json(&j).unwrap();
        assert_eq!(c.nodes, 8);
        assert_eq!(c.fabric.nodes, 8);
        assert_eq!(c.engine_mode, EngineMode::Sync);
        assert_eq!(c.scheduler, SchedulerPolicy::Predictive);
        assert_eq!(c.stack.tier_policy, TierPolicy::ConcurrencyAware);
        assert_eq!(c.stack.erasure_group, 8);
        assert!(c.stack.with_compression);
        assert!(c.fabric.with_kv, "kv module implies kv tier");
        assert_eq!(c.fabric.pfs_bw, 1e9);
    }

    #[test]
    fn bad_values_rejected() {
        let j = Json::parse(r#"{"engine_mode": "turbo"}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"scheduler": "wat"}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
    }

    #[test]
    fn with_nodes_updates_fabric() {
        let c = VelocConfig::default().with_nodes(16, 1);
        assert_eq!(c.fabric.nodes, 16);
        assert_eq!(c.ranks_per_node, 1);
    }

    #[test]
    fn aggregation_section_parsed() {
        let j = Json::parse(
            r#"{
                "aggregation": {"enabled": true, "group_ranks": 8,
                                "flush_mb": 16, "max_delay_ms": 250,
                                "version_barrier": false,
                                "target": "burst-buffer"}
            }"#,
        )
        .unwrap();
        let c = VelocConfig::from_json(&j).unwrap();
        assert!(c.aggregation.enabled);
        assert_eq!(c.aggregation.group_ranks, 8);
        assert_eq!(c.aggregation.flush_bytes, 16 << 20);
        assert_eq!(c.aggregation.max_delay, Duration::from_millis(250));
        assert!(!c.aggregation.version_barrier);
        assert_eq!(c.aggregation.target, AggTarget::BurstBuffer);
        assert!(
            c.fabric.with_burst_buffer,
            "burst-buffer drain target implies the burst-buffer tier"
        );
    }

    #[test]
    fn bad_aggregation_target_rejected() {
        let j = Json::parse(r#"{"aggregation": {"target": "floppy"}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
    }

    #[test]
    fn negative_aggregation_values_rejected() {
        let j = Json::parse(r#"{"aggregation": {"max_delay_ms": -5}}"#).unwrap();
        let err = VelocConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("max_delay_ms"), "{err}");
        let j = Json::parse(r#"{"aggregation": {"flush_mb": -1}}"#).unwrap();
        let err = VelocConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("flush_mb"), "{err}");
    }

    #[test]
    fn sub_4k_flush_chunk_rejected() {
        let j = Json::parse(r#"{"stack": {"flush_chunk": 512}}"#).unwrap();
        let err = VelocConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("flush_chunk"), "{err}");

        let mut c = VelocConfig::default();
        c.stack.flush_chunk = 1024;
        assert!(c.validate().is_err());
        c.stack.flush_chunk = MIN_FLUSH_CHUNK;
        assert!(c.validate().is_ok());

        let mut c = VelocConfig::default();
        c.aggregation.drain_chunk = 100;
        assert!(c.validate().is_err());
    }

    #[test]
    fn delta_section_parsed_and_validated() {
        let j = Json::parse(
            r#"{
                "delta": {"enabled": true, "min_chunk": 1024,
                          "avg_chunk": 4096, "max_chunk": 32768,
                          "max_chain": 5}
            }"#,
        )
        .unwrap();
        let c = VelocConfig::from_json(&j).unwrap();
        assert!(c.delta.enabled);
        assert_eq!(c.delta.min_chunk, 1024);
        assert_eq!(c.delta.avg_chunk, 4096);
        assert_eq!(c.delta.max_chunk, 32768);
        assert_eq!(c.delta.max_chain, 5);

        // Non-power-of-two average rejected when enabled.
        let j = Json::parse(r#"{"delta": {"enabled": true, "avg_chunk": 5000}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        // Zero chain rejected.
        let j = Json::parse(r#"{"delta": {"enabled": true, "max_chain": 0}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        // Disabled section with odd values still parses (not validated).
        let j = Json::parse(r#"{"delta": {"avg_chunk": 5000}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_ok());
    }

    #[test]
    fn restore_section_parsed_and_validated() {
        let j = Json::parse(
            r#"{
                "restore": {"enabled": true, "l1_mb": 32, "l2_mb": 64,
                            "max_entry_kb": 512, "prefetch_depth": 8}
            }"#,
        )
        .unwrap();
        let c = VelocConfig::from_json(&j).unwrap();
        assert!(c.restore.enabled);
        assert_eq!(c.restore.l1_bytes, 32 << 20);
        assert_eq!(c.restore.l2_bytes, 64 << 20);
        assert_eq!(c.restore.max_entry_bytes, 512 << 10);
        assert_eq!(c.restore.prefetch_depth, 8);
        // A cache too small to hold a single segment is rejected.
        let j = Json::parse(r#"{"restore": {"enabled": true, "l1_mb": 0}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        // Zero prefetch depth rejected (1 = no pipelining, still legal).
        let j =
            Json::parse(r#"{"restore": {"enabled": true, "prefetch_depth": 0}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        // Disabled section with odd values still parses (not validated).
        let j = Json::parse(r#"{"restore": {"l1_mb": 0, "prefetch_depth": 0}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_ok());
    }

    #[test]
    fn placement_section_parsed() {
        let j = Json::parse(
            r#"{
                "placement": {"enabled": true, "policy": "fastest-eligible",
                              "ewma_alpha": 0.5, "breaker_threshold": 2,
                              "breaker_probe_after": 4, "full_watermark": 0.8}
            }"#,
        )
        .unwrap();
        let c = VelocConfig::from_json(&j).unwrap();
        assert!(c.placement.enabled);
        assert_eq!(c.placement.policy, PlacementPolicy::FastestEligible);
        assert_eq!(c.placement.ewma_alpha, 0.5);
        assert_eq!(c.placement.breaker_threshold, 2);
        assert_eq!(c.placement.breaker_probe_after, 4);
        assert_eq!(c.placement.full_watermark, 0.8);
        // Bad policy / knob ranges rejected.
        let j = Json::parse(r#"{"placement": {"policy": "psychic"}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"placement": {"ewma_alpha": 1.5}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"placement": {"full_watermark": 0.0}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
    }

    #[test]
    fn fabric_tiers_parsed() {
        let j = Json::parse(
            r#"{
                "fabric": {"tiers": [
                    {"id": "bb-a", "kind": "burst-buffer", "bw": 2e10,
                     "capacity_gb": 0.5},
                    {"id": "scratch", "kind": "pfs", "bw": 3e9,
                     "capacity": 1073741824, "mount": "/tmp/veloc-scratch"}
                ]}
            }"#,
        )
        .unwrap();
        let c = VelocConfig::from_json(&j).unwrap();
        assert_eq!(c.fabric.tiers.len(), 2);
        assert_eq!(c.fabric.tiers[0].id, "bb-a");
        assert_eq!(c.fabric.tiers[0].capacity, 1 << 29);
        assert_eq!(c.fabric.tiers[1].kind, TierKind::Pfs);
        assert_eq!(
            c.fabric.tiers[1].mount.as_deref(),
            Some(std::path::Path::new("/tmp/veloc-scratch"))
        );
        // Entries without an id are rejected.
        let j = Json::parse(r#"{"fabric": {"tiers": [{"kind": "pfs"}]}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
    }

    #[test]
    fn duplicate_tier_ids_rejected() {
        let def = |id: &str| TierDef {
            id: id.to_string(),
            kind: TierKind::BurstBuffer,
            write_bw: 1e9,
            capacity: 1 << 30,
            mount: None,
        };
        let mut c = VelocConfig::default();
        c.fabric.tiers = vec![def("bb-a"), def("bb-a")];
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("duplicate tier id"), "{err}");
        // A custom id shadowing a built-in tier is just as silent a trap.
        c.fabric.tiers = vec![def("pfs")];
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("built-in"), "{err}");
        c.fabric.tiers = vec![def("bb-a"), def("bb-b")];
        assert!(c.validate().is_ok());
        // Node-local kinds cannot be declared as shared tiers.
        let mut bad = def("local-ish");
        bad.kind = TierKind::Ssd;
        c.fabric.tiers = vec![bad];
        assert!(c.validate().is_err());
    }

    #[test]
    fn overlapping_tier_mounts_rejected() {
        let def = |id: &str, mount: &str| TierDef {
            id: id.to_string(),
            kind: TierKind::BurstBuffer,
            write_bw: 1e9,
            capacity: 1 << 30,
            mount: Some(PathBuf::from(mount)),
        };
        let mut c = VelocConfig::default();
        // Identical mounts.
        c.fabric.tiers = vec![def("a", "/mnt/bb"), def("b", "/mnt/bb")];
        assert!(c.validate().is_err());
        // Nested mounts.
        c.fabric.tiers = vec![def("a", "/mnt/bb"), def("b", "/mnt/bb/sub")];
        assert!(c.validate().is_err());
        // Sibling mounts with a shared name prefix are fine (component
        // comparison, not string prefix).
        c.fabric.tiers = vec![def("a", "/mnt/bb"), def("b", "/mnt/bb2")];
        assert!(c.validate().is_ok());
        // A custom mount nested under the PFS directory is rejected too.
        c.fabric.tiers = vec![def("a", "/scratch/pfs/inner")];
        c.fabric.pfs_dir = Some(PathBuf::from("/scratch/pfs"));
        assert!(c.validate().is_err());
        // `..` components normalize before comparison: /mnt/bb/../other
        // is /mnt/other — distinct from /mnt/bb, identical to /mnt/other.
        c.fabric.pfs_dir = None;
        c.fabric.tiers = vec![def("a", "/mnt/bb"), def("b", "/mnt/bb/../other")];
        assert!(c.validate().is_ok());
        c.fabric.tiers = vec![def("a", "/mnt/other"), def("b", "/mnt/bb/../other")];
        assert!(c.validate().is_err());
    }

    #[test]
    fn backend_section_parsed_and_validated() {
        let j = Json::parse(
            r#"{
                "backend": {"dir": "/tmp/veloc-bd", "socket": "/tmp/veloc-bd/s.sock",
                            "queue_depth": 16, "inline_max_kb": 128,
                            "fsync": false, "max_frame_body_mb": 256}
            }"#,
        )
        .unwrap();
        let c = VelocConfig::from_json(&j).unwrap();
        assert_eq!(c.backend.dir, PathBuf::from("/tmp/veloc-bd"));
        assert_eq!(
            c.backend.socket_path(),
            PathBuf::from("/tmp/veloc-bd/s.sock")
        );
        assert_eq!(c.backend.queue_depth, 16);
        assert_eq!(c.backend.inline_max, 128 << 10);
        assert!(!c.backend.fsync);
        assert_eq!(c.backend.max_frame_body, 256 << 20);
        // A frame cap below inline_max can never admit an inline submit.
        let j = Json::parse(
            r#"{"backend": {"inline_max_kb": 128, "max_frame_body_mb": 0.0625}}"#,
        )
        .unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        // Defaults derive the socket from the home dir.
        let c = VelocConfig::default();
        assert_eq!(c.backend.socket_path(), c.backend.dir.join("veloc.sock"));
        // Zero queue depth rejected.
        let j = Json::parse(r#"{"backend": {"queue_depth": 0}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
    }

    #[test]
    fn obs_section_parsed_and_validated() {
        let j = Json::parse(
            r#"{"obs": {"trace": true, "http": "127.0.0.1:0", "span_capacity": 1024}}"#,
        )
        .unwrap();
        let c = VelocConfig::from_json(&j).unwrap();
        assert!(c.obs.trace);
        assert_eq!(c.obs.http.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(c.obs.span_capacity, 1024);
        // Defaults: tracing off, no endpoint.
        let c = VelocConfig::default();
        assert!(!c.obs.trace);
        assert!(c.obs.http.is_none());
        // Bad values rejected.
        let j = Json::parse(r#"{"obs": {"span_capacity": 0}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"obs": {"http": ""}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
    }

    #[test]
    fn flight_and_signals_settings_parsed_and_validated() {
        let j = Json::parse(
            r#"{"obs": {"flight_dir": "/tmp/fr", "flight_max_bytes": 65536,
                         "signals_capacity": 32}}"#,
        )
        .unwrap();
        let c = VelocConfig::from_json(&j).unwrap();
        assert_eq!(
            c.obs.flight_dir.as_deref(),
            Some(std::path::Path::new("/tmp/fr"))
        );
        assert_eq!(c.obs.flight_max_bytes, 65536);
        assert_eq!(c.obs.signals_capacity, 32);
        // Defaults: flight recorder off, bounded ring.
        let c = VelocConfig::default();
        assert!(c.obs.flight_dir.is_none());
        assert!(c.obs.flight_max_bytes >= 4096);
        // A segment bound below one frame's worth of headroom is rejected.
        let j = Json::parse(r#"{"obs": {"flight_max_bytes": 16}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"obs": {"signals_capacity": 0}}"#).unwrap();
        assert!(VelocConfig::from_json(&j).is_err());
    }

    #[test]
    fn burst_buffer_target_without_tier_rejected() {
        let mut c = VelocConfig::default();
        c.aggregation.enabled = true;
        c.aggregation.target = AggTarget::BurstBuffer;
        assert!(c.validate().is_err());
        c.fabric.with_burst_buffer = true;
        assert!(c.validate().is_ok());
    }
}
