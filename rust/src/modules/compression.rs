//! Custom pipeline module example: zlib compression of the encoded payload
//! before it leaves the node (paper §2: "custom modules can be easily
//! added in the pipeline, e.g. conversion between output formats,
//! compression, integrity checks").
//!
//! Priority 35 places it *after* the node-local levels (which keep the raw
//! container for fast restart) and *before* the remote repositories, so
//! only the expensive PFS/KV traffic pays the CPU cost and enjoys the size
//! reduction. Restore paths sniff the encoding (`transfer::maybe_
//! decompress`).

use crate::pipeline::context::{CkptContext, Outcome};
use crate::pipeline::module::{Module, ModuleSwitch};
use crate::util::bufpool::Bytes;
use anyhow::Result;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use std::io::Write;
use std::sync::Arc;

pub struct CompressionModule {
    level: u32,
    switch: ModuleSwitch,
}

impl CompressionModule {
    pub fn new(enabled: bool, level: u32) -> Arc<Self> {
        Arc::new(CompressionModule {
            level: level.min(9),
            switch: ModuleSwitch::new(enabled),
        })
    }
}

impl Module for CompressionModule {
    fn name(&self) -> &'static str {
        "compress"
    }

    fn priority(&self) -> i32 {
        35
    }

    fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
        if ctx.encoding != "raw" {
            return Ok(Outcome::Skipped); // already transformed
        }
        let mut enc = ZlibEncoder::new(
            Vec::with_capacity(ctx.encoded.len() / 2),
            Compression::new(self.level),
        );
        enc.write_all(&ctx.encoded)?;
        let compressed = enc.finish()?;
        // Only swap if it actually helps (incompressible data would
        // inflate the remote copies).
        if compressed.len() < ctx.encoded.len() {
            // Derived data, not a payload copy: the zlib output is a new
            // byte sequence wrapped without further copying.
            ctx.encoded = Bytes::from(compressed);
            ctx.encoding = "zlib";
        }
        Ok(Outcome::Done)
    }

    fn switch(&self) -> &ModuleSwitch {
        &self.switch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modules::transfer::maybe_decompress;
    use crate::util::bytes::Checkpoint;

    fn ctx_with(data: Vec<u8>) -> CkptContext {
        let mut c = Checkpoint::new("t", 0, 1);
        c.push_region(0, data);
        CkptContext::new("t", 0, 0, 1, c)
    }

    #[test]
    fn compresses_compressible_payload() {
        let m = CompressionModule::new(true, 6);
        let mut ctx = ctx_with(vec![7u8; 100_000]);
        let before = ctx.encoded.len();
        m.process(&mut ctx).unwrap();
        assert_eq!(ctx.encoding, "zlib");
        assert!(ctx.encoded.len() < before / 10);
        // Round-trip through the restore-path sniffing.
        let raw = maybe_decompress(ctx.encoded.to_vec()).unwrap();
        let d = Checkpoint::decode(&raw).unwrap();
        assert_eq!(d.region(0).unwrap().data.len(), 100_000);
    }

    #[test]
    fn skips_incompressible_payload() {
        let mut rng = crate::util::rng::Rng::new(1);
        let mut data = vec![0u8; 50_000];
        rng.fill_bytes(&mut data);
        let m = CompressionModule::new(true, 6);
        let mut ctx = ctx_with(data);
        m.process(&mut ctx).unwrap();
        assert_eq!(ctx.encoding, "raw");
    }

    #[test]
    fn raw_passthrough_decompress() {
        let c = ctx_with(vec![1, 2, 3]);
        let raw = maybe_decompress(c.encoded.to_vec()).unwrap();
        assert_eq!(raw, c.encoded.to_vec());
    }
}
