//! Integrity module: checksums the *captured* container — the canonical
//! pre-transform bytes — so recovery can validate whichever level it
//! restores from (paper §2 lists "integrity checks based on checksumming"
//! as a custom pipeline module).
//!
//! ## What the digest covers
//!
//! The digest is taken at priority 5, before any payload transform runs.
//! Later stages may *swap* the bytes levels actually store: compression
//! (priority 35) re-encodes the remote copies as zlib, delta (priority 8)
//! as a VDLT container. The recorded digest therefore covers the
//! canonical decoded form, **not** necessarily the stored bytes — and
//! restore-side verification is explicitly digest-after-decompress:
//! `recovery::Recovery::validate` first undoes the storage encoding
//! (zlib inflate / delta reassembly), decodes the VCKP container, then
//! re-encodes it (the VCKP encode is deterministic) and digests *that*
//! against the registry record. Corruption of a compressed or delta copy
//! is caught twice: the container CRC fails the decode, and any decode
//! that slips through fails the canonical digest.
//!
//! Two backends: crc32 (native, slice-by-16 word-parallel —
//! [`crate::util::kernels::crc32_wide`], bit-identical to
//! `crc32fast::hash`) or the L1 Pallas `checksum` kernel through PJRT,
//! which reduces the container in fixed (rows x block) i32 tiles and
//! mixes the per-row sums into one 32-bit digest.

use crate::modules::Env;
use crate::pipeline::context::{CkptContext, Outcome};
use crate::pipeline::module::{Module, ModuleSwitch};
use crate::runtime::{PjrtEngine, Tensor};
use crate::util::bytes::bytes_to_i32s_padded;
use anyhow::Result;
use std::sync::Arc;

#[derive(Clone)]
pub enum ChecksumBackend {
    Crc32,
    Kernel(Arc<PjrtEngine>),
}

/// Digest the buffer with the kernel: pad to (rows x block) windows, run
/// the position-weighted row checksum, then fold rows with a 32-bit FNV-ish
/// mix (order-dependent, so row swaps change the digest).
pub fn kernel_digest(engine: &Arc<PjrtEngine>, data: &[u8]) -> Result<u32> {
    let rows = engine.manifest().constant("csum_rows")?;
    let block = engine.manifest().constant("csum_block")?;
    let lanes_per_call = rows * block;
    let lanes = bytes_to_i32s_padded(data, lanes_per_call);
    let mut digest: u32 = 0x811C_9DC5;
    for window in lanes.chunks(lanes_per_call) {
        let out = engine.run(
            "checksum",
            &[Tensor::i32(&[rows, block], window.to_vec())],
        )?;
        for &row_sum in out[0].as_i32()? {
            digest = (digest ^ row_sum as u32).wrapping_mul(0x0100_0193);
        }
    }
    // Mix in the true length so zero-padding is not ambiguous.
    digest = (digest ^ data.len() as u32).wrapping_mul(0x0100_0193);
    Ok(digest)
}

pub fn digest(backend: &ChecksumBackend, data: &[u8]) -> Result<u32> {
    match backend {
        // Same IEEE polynomial as crc32fast::hash (property-tested equal);
        // the slice-by-16 kernel keeps the digest off the capture path's
        // critical byte-serial loop.
        ChecksumBackend::Crc32 => Ok(crate::util::kernels::crc32_wide(data)),
        ChecksumBackend::Kernel(e) => kernel_digest(e, data),
    }
}

pub struct ChecksumModule {
    env: Arc<Env>,
    backend: ChecksumBackend,
    switch: ModuleSwitch,
}

impl ChecksumModule {
    pub fn new(env: Arc<Env>, backend: ChecksumBackend, enabled: bool) -> Arc<Self> {
        Arc::new(ChecksumModule {
            env,
            backend,
            switch: ModuleSwitch::new(enabled),
        })
    }

    pub fn backend(&self) -> &ChecksumBackend {
        &self.backend
    }
}

impl Module for ChecksumModule {
    fn name(&self) -> &'static str {
        "checksum"
    }

    fn priority(&self) -> i32 {
        5 // before any copy is made
    }

    fn blocking(&self) -> bool {
        // The digest must be recorded before any level stores a copy (and
        // before delta/compression swap the payload): it covers the
        // canonical captured container, which restore-side validation
        // reproduces by decode + deterministic re-encode — see the module
        // docs for the digest-after-decompress contract.
        true
    }

    fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
        let crc = digest(&self.backend, &ctx.encoded)?;
        self.env
            .registry
            .set_checksum(&ctx.name, ctx.version, ctx.rank, crc);
        Ok(Outcome::Done)
    }

    fn switch(&self) -> &ModuleSwitch {
        &self.switch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_backend_stable() {
        let a = digest(&ChecksumBackend::Crc32, b"hello").unwrap();
        let b = digest(&ChecksumBackend::Crc32, b"hello").unwrap();
        let c = digest(&ChecksumBackend::Crc32, b"hellp").unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn kernel_digest_detects_corruption_and_length() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let eng = PjrtEngine::load(&dir).unwrap();
        let mut rng = crate::util::rng::Rng::new(4);
        let mut data = vec![0u8; 300_000];
        rng.fill_bytes(&mut data);
        let base = kernel_digest(&eng, &data).unwrap();
        assert_eq!(base, kernel_digest(&eng, &data).unwrap());
        // single bit flip
        data[123_456] ^= 1;
        assert_ne!(base, kernel_digest(&eng, &data).unwrap());
        data[123_456] ^= 1;
        // appended zero byte (padding ambiguity) must change the digest
        let mut longer = data.clone();
        longer.push(0);
        assert_ne!(base, kernel_digest(&eng, &longer).unwrap());
    }
}
