//! Level-1 module: write the checkpoint to node-local storage.
//!
//! This is the *blocking* stage — the only one the application waits for in
//! async mode (paper §1: "block the application only while writing to the
//! fastest level"). Tier choice is a policy:
//!
//! - `FastestFirst` — always the fastest local tier with capacity. The
//!   obvious choice, and the baseline of the E5 experiment.
//! - `ConcurrencyAware` — picks the tier with the best *effective* service
//!   time given current concurrent transfers. Under I/O concurrency
//!   (e.g. the async flush still draining the previous checkpoint from the
//!   fast tier) a nominally slower idle tier wins — the non-obvious
//!   producer-consumer result of paper ref [4].

use crate::modules::Env;
use crate::pipeline::context::{CkptContext, Outcome, RestoreContext, LEVEL_LOCAL};
use crate::pipeline::module::{Module, ModuleSwitch};
use crate::storage::StorageTier;
use crate::util::bytes::Checkpoint;
use anyhow::{bail, Result};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TierPolicy {
    FastestFirst,
    ConcurrencyAware,
}

pub struct LocalModule {
    env: Arc<Env>,
    policy: TierPolicy,
    switch: ModuleSwitch,
}

impl LocalModule {
    pub fn new(env: Arc<Env>, policy: TierPolicy) -> Arc<Self> {
        Arc::new(LocalModule {
            env,
            policy,
            switch: ModuleSwitch::new(true),
        })
    }

    pub fn policy(&self) -> TierPolicy {
        self.policy
    }

    /// Pick the target tier under the configured policy.
    fn select_tier<'a>(
        &self,
        tiers: &'a [Arc<StorageTier>],
        bytes: u64,
    ) -> Option<&'a Arc<StorageTier>> {
        let fits =
            |t: &&'a Arc<StorageTier>| t.used_bytes() + bytes <= t.spec().capacity;
        match self.policy {
            TierPolicy::FastestFirst => tiers.iter().find(fits),
            TierPolicy::ConcurrencyAware => tiers
                .iter()
                .filter(fits)
                .min_by(|a, b| {
                    let score = |t: &Arc<StorageTier>| {
                        let n = if t.spec().shared {
                            t.active_transfers() + 1
                        } else {
                            1
                        };
                        // effective seconds to land the checkpoint
                        t.spec().latency.as_secs_f64()
                            + bytes as f64 * n as f64 / t.spec().write_bw
                    };
                    score(a).partial_cmp(&score(b)).unwrap()
                }),
        }
    }
}

impl Module for LocalModule {
    fn name(&self) -> &'static str {
        "local"
    }

    fn priority(&self) -> i32 {
        10
    }

    fn level(&self) -> u8 {
        LEVEL_LOCAL
    }

    fn blocking(&self) -> bool {
        true
    }

    fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
        let tiers = self.env.fabric.local_tiers(ctx.node);
        let bytes = ctx.encoded.len() as u64;
        let Some(tier) = self.select_tier(tiers, bytes) else {
            bail!("no local tier has {bytes} bytes of capacity");
        };
        let stat = tier.put_bytes(&ctx.key("local"), &ctx.encoded)?;
        ctx.record(self.name(), LEVEL_LOCAL, stat.modeled, stat.bytes);
        Ok(Outcome::Done)
    }

    fn restore(&self, ctx: &RestoreContext) -> Result<Option<Checkpoint>> {
        let Some(version) = ctx.version else {
            return Ok(None);
        };
        let tiers = self.env.fabric.local_tiers(ctx.node);
        let fetch_at = |v: u64| -> Option<Vec<u8>> {
            let key = crate::pipeline::storage_key("local", &ctx.name, ctx.rank, v);
            tiers.iter().find_map(|t| t.get(&key).map(|(d, _)| d))
        };
        // Delta containers reassemble through the node chunk store and,
        // for anything the store lost, the local manifest chain; raw VCKP
        // passes straight through.
        let store = self.env.delta.as_ref().map(|d| d.store(ctx.node).as_ref());
        // Restore plane: cache + single-flight + chain prefetch.
        if let Some(eng) = &self.env.restore {
            let fetch = |v: u64| -> Result<Option<Vec<u8>>> { Ok(fetch_at(v)) };
            return eng.materialize(
                "local", &ctx.name, ctx.rank, ctx.node, version, store, &fetch,
            );
        }
        let Some(data) = fetch_at(version) else {
            return Ok(None);
        };
        Ok(Some(crate::delta::materialize(data, store, &fetch_at)?))
    }

    fn switch(&self) -> &ModuleSwitch {
        &self.switch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::modules::VersionRegistry;
    use crate::storage::{presets, FabricConfig, StorageFabric, TimeMode};

    fn env_with_tiers() -> Arc<Env> {
        Arc::new(Env {
            topology: Topology::new(2, 1),
            fabric: Arc::new(
                StorageFabric::build(&FabricConfig {
                    nodes: 2,
                    ..Default::default()
                })
                .unwrap(),
            ),
            pjrt: None,
            registry: VersionRegistry::new(),
            scheduler_gate: None,
            aggregator: None,
            delta: None,
            placement: None,
            restore: None,
        })
    }

    #[test]
    fn fastest_first_prefers_dram() {
        let env = env_with_tiers();
        let m = LocalModule::new(Arc::clone(&env), TierPolicy::FastestFirst);
        let tiers = env.fabric.local_tiers(0);
        let t = m.select_tier(tiers, 1024).unwrap();
        assert_eq!(t.kind(), crate::storage::TierKind::Dram);
    }

    #[test]
    fn fastest_first_falls_back_on_capacity() {
        let env = env_with_tiers();
        let m = LocalModule::new(Arc::clone(&env), TierPolicy::FastestFirst);
        let tiers = env.fabric.local_tiers(0);
        // Larger than the DRAM staging area (1 GiB default).
        let t = m.select_tier(tiers, 2 << 30).unwrap();
        assert_ne!(t.kind(), crate::storage::TierKind::Dram);
    }

    #[test]
    fn concurrency_aware_avoids_contended_shared_tier() {
        // Build a 2-tier node where the nominally faster tier is shared
        // and busy, the slower one idle.
        let fast = StorageTier::memory(presets::nvme(u64::MAX / 2), TimeMode::Model);
        let slow = StorageTier::memory(presets::ssd(u64::MAX / 2), TimeMode::Model);
        let env = env_with_tiers();
        let m = LocalModule::new(env, TierPolicy::ConcurrencyAware);
        let tiers = vec![Arc::clone(&fast), Arc::clone(&slow)];
        // Idle: fast wins despite being shared.
        let t = m.select_tier(&tiers, 64 << 20).unwrap();
        assert_eq!(t.kind(), crate::storage::TierKind::Nvme);
        // Six concurrent flush readbacks on the fast tier: effective
        // service flips to the idle SSD (paper [4]).
        let _guards: Vec<_> = (0..6).map(|_| fast.hold_transfer()).collect();
        let t = m.select_tier(&tiers, 64 << 20).unwrap();
        assert_eq!(t.kind(), crate::storage::TierKind::Ssd);
    }

    #[test]
    fn no_capacity_anywhere_is_none() {
        let env = env_with_tiers();
        let m = LocalModule::new(Arc::clone(&env), TierPolicy::FastestFirst);
        let tiers = env.fabric.local_tiers(0);
        assert!(m.select_tier(tiers, u64::MAX / 2).is_none());
    }
}
