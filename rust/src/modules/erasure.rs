//! Level-3 module: XOR erasure coding across a group of ranks.
//!
//! RAID-5-style rotated parity over erasure groups of size `k` (node-
//! disjoint members, see `Topology::erasure_group`). Storage overhead is
//! `1/(k-1)` of the checkpoint instead of the full copy partner
//! replication costs, and any *single* member loss per group is
//! recoverable — including losses where a partner pair died together
//! (the multi-node failure class).
//!
//! ## Scheme
//!
//! Group member index `j` holds data `D_j` (its level-1 local copy),
//! zero-padded to `(k-1) * h` bytes where `h = ceil(max_len / (k-1))`
//! (lane-aligned). `D_j` is split into `k-1` chunks `C_j[0..k-1)` of `h`
//! bytes. Member `r` additionally stores the parity
//!
//! ```text
//!   P_r = XOR_{j != r} C_j[(r - j - 1) mod k]
//! ```
//!
//! Every chunk of every member appears in exactly one parity row, and
//! never in a row held by its owner. Losing member `f` loses `D_f` and
//! `P_f`; each chunk `C_f[c]` is rebuilt from row `r = (f + 1 + c) mod k`
//! (always a survivor) as `P_r XOR (other survivors' chunks of row r)`.
//!
//! The XOR itself goes through [`crate::modules::xor`] — Pallas kernel via
//! PJRT or a native fold, selected by config (E10 ablation).
//!
//! Modeling note: each member reads every other member's local copy
//! directly from the fabric (standing in for the group reduce-scatter);
//! read costs are charged by the source tiers.

use crate::modules::xor::{xor_fold, xor_into, xor_into_scalar, XorBackend};
use crate::modules::Env;
use crate::pipeline::context::{CkptContext, Outcome, RestoreContext, LEVEL_ERASURE};
use crate::pipeline::module::{Module, ModuleSwitch};
use crate::util::bufpool::Bytes;
use crate::util::bytes::Checkpoint;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

const PARITY_MAGIC: &[u8; 4] = b"VXOR";

pub struct ErasureModule {
    env: Arc<Env>,
    /// Group size k (nodes must be a multiple; k >= 2).
    k: usize,
    backend: XorBackend,
    /// How long to wait for group members' local copies to appear.
    member_timeout: Duration,
    switch: ModuleSwitch,
}

/// Parity container: magic, k, holder index, member lengths, h, parity.
fn encode_parity(k: usize, me: usize, lens: &[u64], h: usize, parity: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 8 + lens.len() * 8 + 8 + parity.len());
    out.extend_from_slice(PARITY_MAGIC);
    out.extend_from_slice(&(k as u32).to_le_bytes());
    out.extend_from_slice(&(me as u32).to_le_bytes());
    for &l in lens {
        out.extend_from_slice(&l.to_le_bytes());
    }
    out.extend_from_slice(&(h as u64).to_le_bytes());
    out.extend_from_slice(parity);
    out
}

struct ParityBlob {
    k: usize,
    #[allow(dead_code)]
    holder: usize,
    lens: Vec<u64>,
    h: usize,
    parity: Vec<u8>,
}

fn decode_parity(buf: &[u8]) -> Result<ParityBlob> {
    if buf.len() < 12 || &buf[0..4] != PARITY_MAGIC {
        bail!("bad parity container");
    }
    let k = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    let holder = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    let mut off = 12;
    let mut lens = Vec::with_capacity(k);
    for _ in 0..k {
        lens.push(u64::from_le_bytes(buf[off..off + 8].try_into().unwrap()));
        off += 8;
    }
    let h = u64::from_le_bytes(buf[off..off + 8].try_into().unwrap()) as usize;
    off += 8;
    let parity = buf[off..].to_vec();
    if parity.len() != h {
        bail!("parity length {} != h {}", parity.len(), h);
    }
    Ok(ParityBlob {
        k,
        holder,
        lens,
        h,
        parity,
    })
}

/// chunk index of member j covered by parity row r (r != j).
fn chunk_of(j: usize, r: usize, k: usize) -> usize {
    (r + k - j - 1) % k
}

/// Stripe height: max length split over k-1 chunks, 8-byte aligned.
fn stripe_h(max_len: usize, k: usize) -> usize {
    let h = max_len.div_ceil(k - 1);
    h.div_ceil(8) * 8
}

/// Zero-padded chunk c of a buffer under stripe height h.
fn chunk_bytes(data: &[u8], c: usize, h: usize) -> Vec<u8> {
    let mut out = vec![0u8; h];
    let start = c * h;
    if start < data.len() {
        let end = (start + h).min(data.len());
        out[..end - start].copy_from_slice(&data[start..end]);
    }
    out
}

/// The raw (unpadded, possibly empty) sub-slice of chunk c under stripe
/// height h. XOR-accumulating this into a zeroed h-byte row is equivalent
/// to XORing [`chunk_bytes`]'s padded copy — without materializing it.
fn chunk_slice(data: &[u8], c: usize, h: usize) -> &[u8] {
    let start = (c * h).min(data.len());
    let end = (c * h + h).min(data.len());
    &data[start..end]
}

impl ErasureModule {
    pub fn new(
        env: Arc<Env>,
        k: usize,
        backend: XorBackend,
        member_timeout: Duration,
    ) -> Arc<Self> {
        Arc::new(ErasureModule {
            env,
            k,
            backend,
            member_timeout,
            switch: ModuleSwitch::new(true),
        })
    }

    fn group_supported(&self) -> bool {
        self.k >= 2 && self.env.topology.nodes % self.k == 0 && self.env.topology.nodes >= self.k
    }

    /// Find a member's level-1 copy across its node's tiers.
    fn read_local_copy(&self, member: usize, name: &str, version: u64) -> Option<Vec<u8>> {
        let node = self.env.topology.node_of(member);
        let key = crate::pipeline::storage_key("local", name, member, version);
        for tier in self.env.fabric.local_tiers(node) {
            if let Some((data, _)) = tier.get(&key) {
                return Some(data);
            }
        }
        None
    }

    /// Zero-copy variant for the capture path: borrows the member's
    /// level-1 copy out of a memory tier instead of cloning it.
    fn read_local_copy_shared(
        &self,
        member: usize,
        name: &str,
        version: u64,
    ) -> Option<Bytes> {
        let node = self.env.topology.node_of(member);
        let key = crate::pipeline::storage_key("local", name, member, version);
        for tier in self.env.fabric.local_tiers(node) {
            if let Some((data, _)) = tier.get_shared(&key) {
                return Some(data);
            }
        }
        None
    }

    fn wait_for_members(
        &self,
        group: &[usize],
        name: &str,
        version: u64,
    ) -> Result<Vec<Bytes>> {
        let deadline = Instant::now() + self.member_timeout;
        let mut copies: Vec<Option<Bytes>> = vec![None; group.len()];
        loop {
            let mut missing = 0;
            for (i, &m) in group.iter().enumerate() {
                if copies[i].is_none() {
                    copies[i] = self.read_local_copy_shared(m, name, version);
                    if copies[i].is_none() {
                        missing += 1;
                    }
                }
            }
            if missing == 0 {
                return Ok(copies.into_iter().map(Option::unwrap).collect());
            }
            if Instant::now() >= deadline {
                bail!(
                    "erasure: {missing}/{} group members never produced local copies for {name} v{version}",
                    group.len()
                );
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn read_parity(&self, member: usize, name: &str, version: u64) -> Option<ParityBlob> {
        let node = self.env.topology.node_of(member);
        let key = format!("erasure.{name}.r{member}.v{version}");
        for tier in self.env.fabric.local_tiers(node) {
            if let Some((data, _)) = tier.get(&key) {
                return decode_parity(&data).ok();
            }
        }
        None
    }

    /// Rebuild the rank's container bytes for one version from the other
    /// group members' local copies plus the rotated parity. `None` when
    /// this level cannot serve the version (e.g. a second loss in the
    /// group).
    fn rebuild_bytes(&self, name: &str, rank: usize, version: u64) -> Result<Option<Vec<u8>>> {
        if !self.group_supported() {
            return Ok(None);
        }
        let k = self.k;
        let group = self.env.topology.erasure_group(rank, k);
        let me = self.env.topology.erasure_index(rank, k);
        // Survivors' data.
        let mut data: Vec<Option<Vec<u8>>> = vec![None; k];
        for (j, &m) in group.iter().enumerate() {
            if j != me {
                data[j] = self.read_local_copy(m, name, version);
                if data[j].is_none() {
                    return Ok(None); // second loss in group: not our level
                }
            }
        }
        // Parities of all rows != me (rows are held by the member with the
        // same index).
        let mut lens: Option<Vec<u64>> = None;
        let mut h = 0usize;
        let mut parities: Vec<Option<Vec<u8>>> = vec![None; k];
        for (r, &m) in group.iter().enumerate() {
            if r == me {
                continue;
            }
            let Some(blob) = self.read_parity(m, name, version) else {
                return Ok(None);
            };
            if blob.k != k {
                return Ok(None);
            }
            h = blob.h;
            lens.get_or_insert(blob.lens.clone());
            parities[r] = Some(blob.parity);
        }
        let lens = lens.ok_or_else(|| anyhow!("no parity found"))?;
        let my_len = lens[me] as usize;
        // Rebuild my k-1 chunks.
        let mut rebuilt = Vec::with_capacity((k - 1) * h);
        for c in 0..k - 1 {
            let r = (me + 1 + c) % k;
            let parity = parities[r].as_ref().unwrap();
            let mut pieces: Vec<Vec<u8>> = vec![parity.clone()];
            for j in 0..k {
                if j == r || j == me {
                    continue;
                }
                pieces.push(chunk_bytes(
                    data[j].as_ref().unwrap(),
                    chunk_of(j, r, k),
                    h,
                ));
            }
            let refs: Vec<&[u8]> = pieces.iter().map(|p| p.as_slice()).collect();
            rebuilt.extend_from_slice(&xor_fold(&refs, &self.backend)?);
        }
        rebuilt.truncate(my_len);
        Ok(Some(rebuilt))
    }
}

impl Module for ErasureModule {
    fn name(&self) -> &'static str {
        "erasure"
    }

    fn priority(&self) -> i32 {
        30
    }

    fn level(&self) -> u8 {
        LEVEL_ERASURE
    }

    fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
        if !self.group_supported() {
            return Ok(Outcome::Skipped);
        }
        let t0 = Instant::now();
        let k = self.k;
        let group = self.env.topology.erasure_group(ctx.rank, k);
        let me = self.env.topology.erasure_index(ctx.rank, k);
        let copies = self.wait_for_members(&group, &ctx.name, ctx.version)?;
        let lens: Vec<u64> = copies.iter().map(|c| c.len() as u64).collect();
        let max_len = *lens.iter().max().unwrap() as usize;
        let h = stripe_h(max_len, k);
        // P_me = XOR over members j != me of their chunk (me - j - 1) mod k.
        let parity = match &self.backend {
            // Native paths accumulate each member's raw chunk sub-slice
            // into one zeroed stripe row: `xor_into` zero-extends short
            // slices, so no padded staging copies are materialized.
            XorBackend::NativeScalar | XorBackend::NativeWide => {
                let wide = matches!(self.backend, XorBackend::NativeWide);
                let mut acc = vec![0u8; h];
                for (j, _) in group.iter().enumerate().filter(|(j, _)| *j != me) {
                    let src = chunk_slice(&copies[j], chunk_of(j, me, k), h);
                    if wide {
                        xor_into(&mut acc, src);
                    } else {
                        xor_into_scalar(&mut acc, src);
                    }
                }
                acc
            }
            // The PJRT kernel consumes fixed-shape tiles; it keeps the
            // padded staging copies.
            backend @ XorBackend::Kernel(_) => {
                let chunks: Vec<Vec<u8>> = group
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != me)
                    .map(|(j, _)| chunk_bytes(&copies[j], chunk_of(j, me, k), h))
                    .collect();
                let refs: Vec<&[u8]> = chunks.iter().map(|c| c.as_slice()).collect();
                xor_fold(&refs, backend)?
            }
        };
        let blob = encode_parity(k, me, &lens, h, &parity);
        // Store on my node (fastest tier with capacity). The parity
        // container is derived data handed over without a further copy.
        let tiers = self.env.fabric.local_tiers(ctx.node);
        let tier = tiers
            .iter()
            .find(|t| t.used_bytes() + blob.len() as u64 <= t.spec().capacity)
            .ok_or_else(|| anyhow!("no local capacity for parity"))?;
        let stat = tier.put_bytes(&ctx.key("erasure"), &Bytes::from(blob))?;
        ctx.record(self.name(), LEVEL_ERASURE, t0.elapsed().max(stat.modeled), stat.bytes);
        Ok(Outcome::Done)
    }

    fn restore(&self, ctx: &RestoreContext) -> Result<Option<Checkpoint>> {
        let Some(version) = ctx.version else {
            return Ok(None);
        };
        // Delta chains prefer the rank's own surviving local copy of an
        // ancestor and fall back to rebuilding the ancestor from the
        // group, exactly like the primary version.
        let fetch_at = |v: u64| -> Option<Vec<u8>> {
            self.read_local_copy(ctx.rank, &ctx.name, v)
                .or_else(|| self.rebuild_bytes(&ctx.name, ctx.rank, v).unwrap_or(None))
        };
        let store = self.env.delta.as_ref().map(|d| d.store(ctx.node).as_ref());
        // Restore plane: rebuilt group parities are the most expensive
        // bytes in the system to re-derive, so cache them preferentially.
        if let Some(eng) = &self.env.restore {
            let fetch = |v: u64| -> Result<Option<Vec<u8>>> {
                if let Some(d) = self.read_local_copy(ctx.rank, &ctx.name, v) {
                    return Ok(Some(d));
                }
                self.rebuild_bytes(&ctx.name, ctx.rank, v)
            };
            return eng.materialize(
                "erasure", &ctx.name, ctx.rank, ctx.node, version, store, &fetch,
            );
        }
        let Some(bytes) = self.rebuild_bytes(&ctx.name, ctx.rank, version)? else {
            return Ok(None);
        };
        Ok(Some(crate::delta::materialize(bytes, store, &fetch_at)?))
    }

    fn switch(&self) -> &ModuleSwitch {
        &self.switch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_mapping_bijective_and_owner_free() {
        for k in [2usize, 3, 4, 8] {
            for j in 0..k {
                let mut seen = vec![false; k - 1];
                for r in (0..k).filter(|&r| r != j) {
                    let c = chunk_of(j, r, k);
                    assert!(c < k - 1, "k={k} j={j} r={r} c={c}");
                    assert!(!seen[c], "duplicate chunk k={k} j={j}");
                    seen[c] = true;
                }
                assert!(seen.iter().all(|&s| s));
            }
        }
    }

    #[test]
    fn rebuild_row_is_survivor() {
        for k in [2usize, 4, 8] {
            for f in 0..k {
                for c in 0..k - 1 {
                    let r = (f + 1 + c) % k;
                    assert_ne!(r, f, "k={k} f={f} c={c}");
                    assert_eq!(chunk_of(f, r, k), c);
                }
            }
        }
    }

    #[test]
    fn stripe_alignment() {
        assert_eq!(stripe_h(100, 4), 40); // ceil(100/3)=34 -> 40
        assert_eq!(stripe_h(24, 4), 8);
        assert_eq!(stripe_h(1, 2), 8);
    }

    #[test]
    fn chunk_bytes_pads() {
        let d = vec![1u8, 2, 3];
        assert_eq!(chunk_bytes(&d, 0, 8), vec![1, 2, 3, 0, 0, 0, 0, 0]);
        assert_eq!(chunk_bytes(&d, 1, 8), vec![0u8; 8]);
    }

    #[test]
    fn chunk_slice_accumulates_like_padded_chunk() {
        // XORing the raw sub-slice into a zeroed row must equal the padded
        // chunk copy, including the partial-tail and past-the-end cases.
        let d: Vec<u8> = (0..23u8).collect();
        for c in 0..4 {
            let mut acc = vec![0u8; 8];
            xor_into(&mut acc, chunk_slice(&d, c, 8));
            assert_eq!(acc, chunk_bytes(&d, c, 8), "chunk {c}");
        }
    }

    #[test]
    fn parity_container_roundtrip() {
        let blob = encode_parity(4, 2, &[10, 20, 30, 40], 8, &[7u8; 8]);
        let p = decode_parity(&blob).unwrap();
        assert_eq!(p.k, 4);
        assert_eq!(p.holder, 2);
        assert_eq!(p.lens, vec![10, 20, 30, 40]);
        assert_eq!(p.h, 8);
        assert_eq!(p.parity, vec![7u8; 8]);
        assert!(decode_parity(&blob[..10]).is_err());
    }
}
