//! Resilience / I/O pipeline modules (paper §2) and their shared
//! environment.

pub mod checksum;
pub mod compression;
pub mod delta;
pub mod erasure;
pub mod kvstore;
pub mod local;
pub mod partner;
pub mod transfer;
pub mod version;
pub mod xor;

pub use checksum::{ChecksumBackend, ChecksumModule};
pub use compression::CompressionModule;
pub use delta::DeltaModule;
pub use erasure::ErasureModule;
pub use kvstore::KvStoreModule;
pub use local::{LocalModule, TierPolicy};
pub use partner::PartnerModule;
pub use transfer::TransferModule;
pub use version::{VersionModule, VersionRegistry};
pub use xor::{xor_fold, xor_into, xor_into_scalar, XorBackend, XorError};

use crate::cluster::Topology;
use crate::pipeline::module::Module;
use crate::runtime::PjrtEngine;
use crate::storage::StorageFabric;
use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

/// Throttle hook the transfer module consults between flush chunks — the
/// interference-mitigation lever (implemented by `crate::scheduler`).
pub trait FlushGate: Send + Sync {
    /// Called before flushing `bytes` more bytes; may sleep (priority
    /// throttling) or block until a predicted-idle phase.
    fn before_chunk(&self, bytes: usize);

    /// Has a (simulated) failure landed that kills `rank`'s in-flight
    /// transfer? Flushers poll this between chunks and abandon the stream
    /// when it turns true, modeling a process that dies mid-flush without
    /// publishing its object. The scheduler gates never abort; only the
    /// fault-injecting gate of [`crate::sim`] overrides this.
    fn aborted_for(&self, _rank: usize) -> bool {
        false
    }
}

/// Shared environment every module sees.
pub struct Env {
    pub topology: Topology,
    pub fabric: Arc<StorageFabric>,
    /// PJRT engine for kernel-backed modules (None = native backends only).
    pub pjrt: Option<Arc<PjrtEngine>>,
    pub registry: Arc<VersionRegistry>,
    /// Optional flush throttle installed by the scheduler.
    pub scheduler_gate: Option<Arc<dyn FlushGate>>,
    /// When set, level-4 flushes route through the write-combining
    /// aggregator instead of writing one shared-tier object per rank.
    pub aggregator: Option<Arc<crate::aggregation::Aggregator>>,
    /// When set, checkpoints pass through the content-defined dedup stage
    /// and every level moves thin delta containers; restore paths
    /// reassemble through the manifest chain (`crate::delta`).
    pub delta: Option<Arc<crate::delta::DeltaState>>,
    /// When set, shared-tier flushes (direct level-4 transfers and
    /// aggregated container drains) route through the adaptive placement
    /// engine instead of writing straight to their configured tier
    /// (`crate::storage::placement`).
    pub placement: Option<Arc<crate::storage::PlacementEngine>>,
    /// When set, every module `restore()` path serves container bytes
    /// through the restore-side plane — read-through cache, single-flight
    /// dedup and parallel chain prefetch (`crate::restore`).
    pub restore: Option<Arc<crate::restore::RestoreEngine>>,
}

/// Configuration of the default module stack.
#[derive(Clone)]
pub struct StackConfig {
    /// Tier selection policy for the level-1 capture.
    pub tier_policy: TierPolicy,
    /// Erasure group size (0 disables the erasure module).
    pub erasure_group: usize,
    /// Use the Pallas kernels through PJRT where available.
    pub use_kernels: bool,
    /// Enable the integrity checksum stage.
    pub with_checksum: bool,
    /// Enable zlib compression of remote copies.
    pub with_compression: bool,
    /// Enable the KV repository module.
    pub with_kv: bool,
    /// Enable partner replication.
    pub with_partner: bool,
    /// Enable the PFS flush.
    pub with_transfer: bool,
    /// Versions retained per checkpoint name.
    pub keep_versions: usize,
    /// PFS flush chunk size (scheduler pacing granularity).
    pub flush_chunk: usize,
    /// How long erasure waits for group members.
    pub erasure_timeout: Duration,
}

impl Default for StackConfig {
    fn default() -> Self {
        StackConfig {
            tier_policy: TierPolicy::FastestFirst,
            erasure_group: 4,
            use_kernels: false,
            with_checksum: true,
            with_compression: false,
            with_kv: false,
            with_partner: true,
            with_transfer: true,
            keep_versions: 2,
            flush_chunk: 4 << 20,
            erasure_timeout: Duration::from_secs(10),
        }
    }
}

/// Build the default module stack (checksum < delta < local < partner <
/// erasure < compression < transfer < kv < version) for one rank's engine.
/// The delta stage joins the stack whenever the environment carries a
/// [`crate::delta::DeltaState`] (i.e. `VelocConfig::delta.enabled`).
pub fn build_stack(env: &Arc<Env>, cfg: &StackConfig) -> Result<Vec<Arc<dyn Module>>> {
    let mut stack: Vec<Arc<dyn Module>> = Vec::new();
    if cfg.with_checksum {
        let backend = match (&env.pjrt, cfg.use_kernels) {
            (Some(e), true) => ChecksumBackend::Kernel(Arc::clone(e)),
            _ => ChecksumBackend::Crc32,
        };
        stack.push(ChecksumModule::new(Arc::clone(env), backend, true));
    }
    if env.delta.is_some() {
        stack.push(DeltaModule::new(Arc::clone(env)));
    }
    stack.push(LocalModule::new(Arc::clone(env), cfg.tier_policy));
    if cfg.with_partner {
        stack.push(PartnerModule::new(Arc::clone(env)));
    }
    if cfg.erasure_group >= 2 {
        let backend = match (&env.pjrt, cfg.use_kernels) {
            (Some(e), true) => XorBackend::Kernel(Arc::clone(e)),
            _ => XorBackend::NativeWide,
        };
        stack.push(ErasureModule::new(
            Arc::clone(env),
            cfg.erasure_group,
            backend,
            cfg.erasure_timeout,
        ));
    }
    if cfg.with_compression {
        stack.push(CompressionModule::new(true, 3));
    }
    if cfg.with_transfer {
        stack.push(TransferModule::new(Arc::clone(env), cfg.flush_chunk));
    }
    if cfg.with_kv {
        stack.push(KvStoreModule::new(Arc::clone(env), true));
    }
    stack.push(VersionModule::new(
        Arc::clone(&env.registry),
        Arc::clone(&env.fabric),
        env.aggregator.clone(),
        env.delta.clone(),
        env.topology,
        cfg.keep_versions,
    ));
    Ok(stack)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::FabricConfig;

    fn env() -> Arc<Env> {
        Arc::new(Env {
            topology: Topology::new(4, 1),
            fabric: Arc::new(
                StorageFabric::build(&FabricConfig {
                    nodes: 4,
                    with_kv: true,
                    ..Default::default()
                })
                .unwrap(),
            ),
            pjrt: None,
            registry: VersionRegistry::new(),
            scheduler_gate: None,
            aggregator: None,
            delta: None,
            placement: None,
            restore: None,
        })
    }

    #[test]
    fn default_stack_order() {
        let e = env();
        let stack = build_stack(&e, &StackConfig::default()).unwrap();
        let names: Vec<&str> = stack.iter().map(|m| m.name()).collect();
        assert_eq!(
            names,
            vec!["checksum", "local", "partner", "erasure", "transfer", "version"]
        );
        // priorities strictly increasing
        let prios: Vec<i32> = stack.iter().map(|m| m.priority()).collect();
        assert!(prios.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn optional_modules_toggle() {
        let e = env();
        let cfg = StackConfig {
            with_checksum: false,
            with_partner: false,
            erasure_group: 0,
            with_compression: true,
            with_kv: true,
            ..Default::default()
        };
        let names: Vec<&str> = build_stack(&e, &cfg)
            .unwrap()
            .iter()
            .map(|m| m.name())
            .collect();
        assert_eq!(names, vec!["local", "compress", "transfer", "kvstore", "version"]);
    }
}
