//! XOR fold over equal-length byte buffers — the erasure-coding primitive.
//!
//! Three backends (the E10 ablation in DESIGN.md):
//! - `NativeScalar` — byte-at-a-time loop (naive baseline).
//! - `NativeWide`   — u64-word loop (what an optimized CPU library does).
//! - `Kernel`       — the L1 Pallas `xor_parity` kernel through PJRT,
//!   tiled into the AOT-compiled (XOR_SHARDS x XOR_CHUNK) i32 blocks.
//!
//! All three produce identical bytes; `modules::erasure` picks one via
//! config and the bench compares their throughput.

use crate::runtime::{PjrtEngine, Tensor};
use anyhow::Result;
use std::sync::Arc;

/// Typed failure for the XOR fold API. A library misuse (empty input,
/// ragged buffer lengths) must surface as an error the caller can handle
/// — not an `assert!` that aborts the whole daemon process.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum XorError {
    /// No input buffers were supplied.
    Empty,
    /// One buffer's length disagreed with buffer 0's.
    UnequalLengths {
        /// Index of the offending buffer.
        index: usize,
        /// Required length (buffer 0's).
        expect: usize,
        /// Actual length of the offending buffer.
        got: usize,
    },
}

impl std::fmt::Display for XorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            XorError::Empty => write!(f, "xor_fold: no input buffers"),
            XorError::UnequalLengths { index, expect, got } => write!(
                f,
                "xor_fold: buffer {index} is {got} bytes, expected {expect}"
            ),
        }
    }
}

impl std::error::Error for XorError {}

#[derive(Clone)]
pub enum XorBackend {
    NativeScalar,
    NativeWide,
    Kernel(Arc<PjrtEngine>),
}

impl std::fmt::Debug for XorBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            XorBackend::NativeScalar => write!(f, "NativeScalar"),
            XorBackend::NativeWide => write!(f, "NativeWide"),
            XorBackend::Kernel(_) => write!(f, "Kernel"),
        }
    }
}

/// XOR all buffers into a fresh output. All buffers must share a length;
/// violations return a typed [`XorError`] instead of panicking.
pub fn xor_fold(bufs: &[&[u8]], backend: &XorBackend) -> Result<Vec<u8>> {
    if bufs.is_empty() {
        return Err(XorError::Empty.into());
    }
    let len = bufs[0].len();
    for (index, b) in bufs.iter().enumerate() {
        if b.len() != len {
            return Err(XorError::UnequalLengths {
                index,
                expect: len,
                got: b.len(),
            }
            .into());
        }
    }
    match backend {
        XorBackend::NativeScalar => {
            let mut out = bufs[0].to_vec();
            for b in &bufs[1..] {
                for (o, x) in out.iter_mut().zip(b.iter()) {
                    *o ^= x;
                }
            }
            Ok(out)
        }
        XorBackend::NativeWide => Ok(xor_fold_wide(bufs)),
        XorBackend::Kernel(engine) => xor_fold_kernel(bufs, engine),
    }
}

/// u64-word XOR with byte tail.
///
/// §Perf: the original implementation decoded/encoded every word through
/// `from_le_bytes`/`copy_from_slice` (≈1.5 GB/s). Reinterpreting the
/// aligned body via `align_to::<u64>` lets the compiler autovectorize the
/// plain `^=` loop (≈10x, see EXPERIMENTS.md §Perf). The accumulator is a
/// fresh `Vec<u8>` whose body is 8-aligned in practice; `align_to` handles
/// any misaligned prefix correctly regardless.
fn xor_fold_wide(bufs: &[&[u8]]) -> Vec<u8> {
    let mut out = bufs[0].to_vec();
    for b in &bufs[1..] {
        xor_into(&mut out, b);
    }
    out
}

/// XOR `src` into the front of `acc` (u64-word body, byte head/tail).
/// A `src` shorter than `acc` is implicitly zero-extended — XOR with zero
/// is a no-op — which is what lets the erasure module accumulate raw
/// unpadded member sub-slices into one stripe-height accumulator without
/// materializing padded copies.
pub fn xor_into(acc: &mut [u8], src: &[u8]) {
    let n = acc.len().min(src.len());
    let acc = &mut acc[..n];
    let src = &src[..n];
    // SAFETY: u64 has no invalid bit patterns; align_to yields only
    // correctly-aligned, in-bounds subslices.
    let (head, body, tail) = unsafe { acc.align_to_mut::<u64>() };
    let split0 = head.len();
    let split1 = split0 + body.len() * 8;
    for (o, x) in head.iter_mut().zip(&src[..split0]) {
        *o ^= x;
    }
    // The matching source body may be unaligned; read via chunks.
    // from_ne_bytes matches the native reinterpretation of `acc`, so
    // byte lanes pair correctly on any endianness.
    for (o, x) in body.iter_mut().zip(src[split0..split1].chunks_exact(8)) {
        *o ^= u64::from_ne_bytes(x.try_into().unwrap());
    }
    for (o, x) in tail.iter_mut().zip(&src[split1..]) {
        *o ^= x;
    }
}

/// Byte-serial variant of [`xor_into`] — the scalar baseline benches and
/// property tests compare against.
pub fn xor_into_scalar(acc: &mut [u8], src: &[u8]) {
    for (o, x) in acc.iter_mut().zip(src.iter()) {
        *o ^= x;
    }
}

/// PJRT path: tile the fold into the AOT-compiled (k_rows x chunk) blocks.
fn xor_fold_kernel(bufs: &[&[u8]], engine: &Arc<PjrtEngine>) -> Result<Vec<u8>> {
    let k_rows = engine.manifest().constant("xor_shards")?; // rows per call
    let chunk = engine.manifest().constant("xor_chunk")?; // i32 lanes per call
    let len = bufs[0].len();
    let lanes_total = len.div_ceil(4);
    let mut out = vec![0u8; len];

    // Fold the m buffers in groups of k_rows (the accumulator occupies one
    // row in every call after the first).
    let mut lane_off = 0;
    while lane_off < lanes_total {
        let window = chunk.min(lanes_total - lane_off); // lanes this call
        let byte_off = lane_off * 4;
        let mut acc: Option<Vec<i32>> = None;
        let mut idx = 0;
        while idx < bufs.len() {
            let mut rows: Vec<Vec<i32>> = Vec::with_capacity(k_rows);
            if let Some(a) = acc.take() {
                rows.push(a);
            }
            while rows.len() < k_rows && idx < bufs.len() {
                rows.push(slice_to_lanes(bufs[idx], byte_off, window, chunk));
                idx += 1;
            }
            while rows.len() < k_rows {
                rows.push(vec![0i32; chunk]); // identity rows
            }
            let flat: Vec<i32> = rows.into_iter().flatten().collect();
            let res = engine.run(
                "xor_parity",
                &[Tensor::i32(&[k_rows, chunk], flat)],
            )?;
            acc = Some(res.into_iter().next().unwrap().into_i32()?);
        }
        let acc = acc.unwrap();
        for (j, lane) in acc.iter().take(window).enumerate() {
            let b = lane.to_le_bytes();
            let dst = byte_off + j * 4;
            let take = (len - dst).min(4);
            out[dst..dst + take].copy_from_slice(&b[..take]);
        }
        lane_off += window;
    }
    Ok(out)
}

/// Extract `window` i32 lanes starting at `byte_off`, zero-padded to
/// `chunk` lanes (the kernel's fixed width).
fn slice_to_lanes(buf: &[u8], byte_off: usize, window: usize, chunk: usize) -> Vec<i32> {
    let mut lanes = vec![0i32; chunk];
    for (j, lane) in lanes.iter_mut().enumerate().take(window) {
        let i = byte_off + j * 4;
        if i >= buf.len() {
            break;
        }
        let mut w = [0u8; 4];
        let take = (buf.len() - i).min(4);
        w[..take].copy_from_slice(&buf[i..i + take]);
        *lane = i32::from_le_bytes(w);
    }
    lanes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn bufs(n: usize, len: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut b = vec![0u8; len];
                rng.fill_bytes(&mut b);
                b
            })
            .collect()
    }

    #[test]
    fn scalar_and_wide_agree() {
        for len in [0usize, 1, 7, 8, 9, 1000, 4096, 10_001] {
            let bs = bufs(3, len, len as u64 + 1);
            let refs: Vec<&[u8]> = bs.iter().map(|b| b.as_slice()).collect();
            let a = xor_fold(&refs, &XorBackend::NativeScalar).unwrap();
            let b = xor_fold(&refs, &XorBackend::NativeWide).unwrap();
            assert_eq!(a, b, "len {len}");
        }
    }

    #[test]
    fn xor_self_inverse() {
        let bs = bufs(4, 1024, 9);
        let refs: Vec<&[u8]> = bs.iter().map(|b| b.as_slice()).collect();
        let parity = xor_fold(&refs, &XorBackend::NativeWide).unwrap();
        // parity ^ b1 ^ b2 ^ b3 == b0
        let rebuild = xor_fold(
            &[&parity, &bs[1], &bs[2], &bs[3]],
            &XorBackend::NativeWide,
        )
        .unwrap();
        assert_eq!(rebuild, bs[0]);
    }

    #[test]
    fn single_buffer_is_identity() {
        let bs = bufs(1, 100, 3);
        let out = xor_fold(&[&bs[0]], &XorBackend::NativeScalar).unwrap();
        assert_eq!(out, bs[0]);
    }

    #[test]
    fn empty_input_is_a_typed_error_not_a_panic() {
        let err = xor_fold(&[], &XorBackend::NativeWide).unwrap_err();
        assert_eq!(err.downcast_ref::<XorError>(), Some(&XorError::Empty));
    }

    #[test]
    fn unequal_lengths_are_a_typed_error_not_a_panic() {
        let a = vec![1u8; 10];
        let b = vec![2u8; 9];
        let err = xor_fold(&[&a, &b], &XorBackend::NativeScalar).unwrap_err();
        assert_eq!(
            err.downcast_ref::<XorError>(),
            Some(&XorError::UnequalLengths {
                index: 1,
                expect: 10,
                got: 9
            })
        );
    }

    #[test]
    fn xor_into_zero_extends_short_sources() {
        let mut rng = Rng::new(42);
        for (acc_len, src_len) in [(100usize, 100usize), (100, 37), (64, 0), (9, 9), (8, 3)] {
            let mut acc = vec![0u8; acc_len];
            rng.fill_bytes(&mut acc);
            let mut src = vec![0u8; src_len];
            rng.fill_bytes(&mut src);
            // Reference: pad src to acc_len with zeros, XOR byte-wise.
            let mut expect = acc.clone();
            let mut wide = acc.clone();
            let mut padded = src.clone();
            padded.resize(acc_len, 0);
            xor_into_scalar(&mut expect, &padded);
            xor_into(&mut wide, &src);
            assert_eq!(wide, expect, "acc {acc_len} src {src_len}");
            // Misaligned accumulator view.
            if acc_len > 3 && src_len > 3 {
                let mut w2 = acc.clone();
                let mut s2 = acc.clone();
                xor_into(&mut w2[3..], &src[3..]);
                xor_into_scalar(&mut s2[3..], &src[3..]);
                assert_eq!(w2, s2);
            }
        }
    }

    #[test]
    fn kernel_matches_native() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping kernel test: run `make artifacts`");
            return;
        }
        let eng = PjrtEngine::load(&dir).unwrap();
        // Cover: fewer buffers than k rows, more than k rows, non-lane-
        // aligned lengths, multi-window lengths.
        for (n, len) in [(2usize, 100usize), (4, 4096), (7, 300_001)] {
            let bs = bufs(n, len, (n * len) as u64);
            let refs: Vec<&[u8]> = bs.iter().map(|b| b.as_slice()).collect();
            let native = xor_fold(&refs, &XorBackend::NativeWide).unwrap();
            let kern =
                xor_fold(&refs, &XorBackend::Kernel(eng.clone())).unwrap();
            assert_eq!(native, kern, "n={n} len={len}");
        }
    }
}
