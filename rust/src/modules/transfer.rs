//! Level-4 module: asynchronous flush to the parallel file system.
//!
//! In async engine mode this stage runs on the active backend, so the
//! application never blocks on PFS bandwidth — the core VeloC claim (the
//! Summit run: "negligible runtime overhead for flushing the local
//! checkpoints to Lustre in the background"). The flush *reads back* the
//! level-1 copy from whichever local tier holds it (charging that tier's
//! read cost — this read traffic is what makes fastest-tier-always
//! suboptimal, paper [4] / experiment E5), then streams it to the PFS in
//! chunks so the scheduler can throttle between chunks.

use crate::modules::Env;
use crate::pipeline::context::{CkptContext, Outcome, RestoreContext, LEVEL_PFS};
use crate::pipeline::module::{Module, ModuleSwitch};
use crate::util::bufpool::Bytes;
use crate::util::bytes::Checkpoint;
use anyhow::Result;
use std::io::Read;
use std::sync::Arc;
use std::time::Instant;

pub struct TransferModule {
    env: Arc<Env>,
    /// Stream chunk size: between chunks the module consults the scheduler
    /// gate (throttle/pause), bounding interference bursts.
    chunk: usize,
    switch: ModuleSwitch,
}

impl TransferModule {
    pub fn new(env: Arc<Env>, chunk: usize) -> Arc<Self> {
        // Config validation rejects sub-4KiB chunks (`VelocConfig::
        // validate`); a direct caller bypassing it fails loudly here
        // instead of getting a silently patched value.
        assert!(
            chunk >= 4096,
            "transfer chunk {chunk} below the 4096-byte minimum"
        );
        Arc::new(TransferModule {
            env,
            chunk,
            switch: ModuleSwitch::new(true),
        })
    }

    /// Read back the level-1 copy (preferred: charges the local tier's
    /// read cost, modeling the real producer-consumer pattern); fall back
    /// to the in-context bytes if the local copy is gone. Either way the
    /// result is a shared view — no payload copy on this path.
    fn read_back(&self, ctx: &CkptContext) -> (Bytes, bool) {
        let key = ctx.key("local");
        for tier in self.env.fabric.local_tiers(ctx.node) {
            if let Some((data, _)) = tier.get_shared(&key) {
                return (data, true);
            }
        }
        (ctx.encoded.clone(), false)
    }

    /// Find one version's level-4 object: the recorded placement
    /// destination first, then a probe of the whole shared pool (the
    /// object may have failed over anywhere), the legacy direct-PFS
    /// location, and finally the aggregated containers.
    fn fetch_level4(&self, name: &str, rank: usize, version: u64) -> Result<Option<Vec<u8>>> {
        let key = crate::pipeline::storage_key("pfs", name, rank, version);
        if let Some(p) = &self.env.placement {
            let dest = self
                .env
                .registry
                .info(name, version, rank)
                .and_then(|i| i.dest);
            if let Some((data, _, _)) = p.get_recorded(dest.as_deref(), &key) {
                return Ok(Some(data));
            }
        } else if let Some((data, _)) = self.env.fabric.pfs().get(&key) {
            return Ok(Some(data));
        }
        match &self.env.aggregator {
            Some(agg) => agg.restore(name, version, rank),
            None => Ok(None),
        }
    }
}

/// Sniff the payload encoding: raw VCKP / VDLT delta containers pass
/// through, anything else is treated as zlib (compression module).
pub fn maybe_decompress(data: Vec<u8>) -> Result<Vec<u8>> {
    if data.starts_with(crate::util::bytes::MAGIC)
        || data.starts_with(crate::delta::VDLT_MAGIC)
    {
        return Ok(data);
    }
    // zlib stream (RFC 1950): 0x78 CMF for 32K window deflate.
    let mut out = Vec::new();
    flate2::read::ZlibDecoder::new(&data[..]).read_to_end(&mut out)?;
    Ok(out)
}

impl Module for TransferModule {
    fn name(&self) -> &'static str {
        "transfer"
    }

    fn priority(&self) -> i32 {
        40
    }

    fn level(&self) -> u8 {
        LEVEL_PFS
    }

    fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
        let t0 = Instant::now();
        // Compressed payloads travel from the context (compression runs
        // after local capture, so the local copy is raw).
        let (data, _from_tier) = if ctx.encoding == "raw" {
            self.read_back(ctx)
        } else {
            (ctx.encoded.clone(), false)
        };
        // Aggregated path: hand the payload to the write-combining
        // aggregator (it paces its own container drains under the gate)
        // instead of writing a file-per-rank object to the shared tier.
        if let Some(agg) = &self.env.aggregator {
            let stat = agg.submit(&ctx.name, ctx.version, ctx.rank, ctx.encoding, data)?;
            // Level-4 completion is only recorded once the bytes are
            // durable: either here (this submit triggered the container
            // drain) or by the aggregator itself when another rank's
            // submit, the age ticker or a runtime drain flushes the
            // group. A buffered segment is still volatile node memory.
            if stat.drained {
                ctx.record(self.name(), LEVEL_PFS, t0.elapsed().max(stat.modeled), stat.bytes);
            }
            return Ok(Outcome::Done);
        }
        let key = ctx.key("pfs");
        // Pace the flush chunk by chunk under the scheduler gate (priority
        // throttling / predicted-idle pausing), then publish the object in
        // one atomic put whose model charges the PFS bandwidth. A failure
        // landing mid-stream (fault-injecting gate) abandons the transfer
        // before the atomic publish — no partial object ever appears.
        if let Some(gate) = &self.env.scheduler_gate {
            let mut off = 0;
            while off < data.len() {
                gate.before_chunk(self.chunk.min(data.len() - off));
                if gate.aborted_for(ctx.rank) {
                    anyhow::bail!(
                        "flush aborted: rank {} failed mid-transfer at offset {off}",
                        ctx.rank
                    );
                }
                off += self.chunk;
            }
        }
        // Adaptive placement: route to the best eligible shared tier
        // (failing over past down/read-only/full ones) and record where
        // the object actually landed so restores can find it. Without
        // placement the object goes straight to the PFS, as ever.
        let stat = match &self.env.placement {
            Some(p) => {
                let (dest, stat) = p.put_bytes(&key, &data)?;
                self.env
                    .registry
                    .set_destination(&ctx.name, ctx.version, ctx.rank, &dest);
                ctx.route_tier = Some(dest);
                stat
            }
            None => {
                ctx.route_tier = Some("pfs".to_string());
                self.env.fabric.pfs().put_bytes(&key, &data)?
            }
        };
        ctx.record(self.name(), LEVEL_PFS, t0.elapsed().max(stat.modeled), stat.bytes);
        Ok(Outcome::Done)
    }

    fn restore(&self, ctx: &RestoreContext) -> Result<Option<Checkpoint>> {
        let Some(version) = ctx.version else {
            return Ok(None);
        };
        let store = self.env.delta.as_ref().map(|d| d.store(ctx.node).as_ref());
        // Restore plane: the level-4 read is the restart-storm hot spot —
        // N clients cold-restoring one container set must not multiply
        // PFS reads, so this path leans hardest on the cache and the
        // single-flight table.
        if let Some(eng) = &self.env.restore {
            let fetch =
                |v: u64| -> Result<Option<Vec<u8>>> { self.fetch_level4(&ctx.name, ctx.rank, v) };
            return eng.materialize(
                "pfs", &ctx.name, ctx.rank, ctx.node, version, store, &fetch,
            );
        }
        // Primary lookup: the file-per-rank object first (wherever
        // placement landed it), then the aggregated containers (index
        // lookup with persisted-index and header-rebuild fallbacks).
        // Aggregator errors propagate here — a corrupt level-4 copy must
        // surface, not read as "no copy".
        let Some(data) = self.fetch_level4(&ctx.name, ctx.rank, version)? else {
            return Ok(None);
        };
        // Chain-ancestor fetches use miss semantics (a miss legitimately
        // means "chain broken"; materialize reports it).
        let fetch_at = |v: u64| -> Option<Vec<u8>> {
            self.fetch_level4(&ctx.name, ctx.rank, v).ok().flatten()
        };
        Ok(Some(crate::delta::materialize(data, store, &fetch_at)?))
    }

    fn switch(&self) -> &ModuleSwitch {
        &self.switch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::modules::VersionRegistry;
    use crate::storage::{FabricConfig, StorageFabric};

    fn env() -> Arc<Env> {
        Arc::new(Env {
            topology: Topology::new(2, 1),
            fabric: Arc::new(
                StorageFabric::build(&FabricConfig {
                    nodes: 2,
                    ..Default::default()
                })
                .unwrap(),
            ),
            pjrt: None,
            registry: VersionRegistry::new(),
            scheduler_gate: None,
            aggregator: None,
            delta: None,
            placement: None,
            restore: None,
        })
    }

    fn ctx() -> CkptContext {
        let mut c = crate::util::bytes::Checkpoint::new("t", 0, 1);
        c.push_region(0, vec![9u8; 8 << 10]);
        CkptContext::new("t", 0, 0, 1, c)
    }

    /// Regression: the flush must succeed from the in-context bytes when
    /// the level-1 copy was evicted (or never landed) before the async
    /// flush runs — and must not charge any local-tier read for the
    /// fallback probe (misses are free).
    #[test]
    fn read_back_falls_back_to_context_bytes_after_eviction() {
        let env = env();
        let t = TransferModule::new(Arc::clone(&env), 4096);
        let mut c = ctx();
        // No local module ran: every local tier misses.
        t.process(&mut c).unwrap();
        assert_eq!(c.max_level(), LEVEL_PFS);
        assert!(env.fabric.pfs().exists("pfs.t.r0.v1"));
        for tier in env.fabric.local_tiers(0) {
            assert_eq!(
                tier.get_count(),
                0,
                "{}: evicted-copy fallback must not charge local reads",
                tier.spec().kind.name()
            );
        }
        // And the flushed object restores.
        let rc = RestoreContext {
            name: "t".to_string(),
            rank: 0,
            node: 0,
            version: Some(1),
        };
        let restored = t.restore(&rc).unwrap().unwrap();
        assert_eq!(restored.region(0).unwrap().data, vec![9u8; 8 << 10]);
    }

    /// Placement path: a read-only primary makes the flush fail over to
    /// the burst buffer, the destination is recorded in the registry, and
    /// the restore finds the object although the PFS never stored it.
    #[test]
    fn placement_failover_records_destination_and_restores() {
        use crate::storage::{PlacementConfig, PlacementEngine};
        let fabric = Arc::new(
            StorageFabric::build(&FabricConfig {
                nodes: 2,
                with_burst_buffer: true,
                ..Default::default()
            })
            .unwrap(),
        );
        let placement = PlacementEngine::new(
            fabric.shared_tiers(),
            PlacementConfig {
                enabled: true,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let env = Arc::new(Env {
            topology: Topology::new(2, 1),
            fabric: Arc::clone(&fabric),
            pjrt: None,
            registry: VersionRegistry::new(),
            scheduler_gate: None,
            aggregator: None,
            delta: None,
            placement: Some(placement),
            restore: None,
        });
        fabric.pfs().set_read_only(true);
        let t = TransferModule::new(Arc::clone(&env), 4096);
        let mut c = ctx();
        t.process(&mut c).unwrap();
        assert_eq!(
            env.registry.info("t", 1, 0).unwrap().dest.as_deref(),
            Some("burst-buffer")
        );
        assert!(!fabric.pfs().exists("pfs.t.r0.v1"));
        assert!(fabric.burst_buffer().unwrap().exists("pfs.t.r0.v1"));
        let rc = RestoreContext {
            name: "t".to_string(),
            rank: 0,
            node: 0,
            version: Some(1),
        };
        let restored = t.restore(&rc).unwrap().unwrap();
        assert_eq!(restored.region(0).unwrap().data, vec![9u8; 8 << 10]);
    }

    /// The preferred path still reads back the level-1 copy (charging the
    /// holding tier's read) when one exists.
    #[test]
    fn read_back_prefers_local_copy_when_present() {
        let env = env();
        let t = TransferModule::new(Arc::clone(&env), 4096);
        let mut c = ctx();
        let tier = &env.fabric.local_tiers(0)[0];
        tier.put_bytes(&c.key("local"), &c.encoded).unwrap();
        t.process(&mut c).unwrap();
        assert_eq!(tier.get_count(), 1, "local read-back must be charged");
    }
}
