//! Delta stage: content-defined dedup ahead of the level-1 capture.
//!
//! Runs between the integrity checksum (which digests the full VCKP, so
//! restore validation stays end-to-end: a chain reassembly that is not
//! bit-for-bit fails the recorded digest) and the local module. It chunks
//! every protected region, diffs the fingerprints against the previous
//! version's manifest chain, publishes chunk payloads into the node's
//! refcounted store and swaps the context's encoded payload for the thin
//! VDLT container — so every downstream level (local, partner, erasure,
//! PFS flush, VAGG containers) moves only the manifest plus chain-novel
//! chunks instead of a full snapshot.
//!
//! Blocking: the swap must happen before the level-1 capture, which is
//! itself blocking — the chunk/diff cost is part of the paper's "blocked
//! only while writing to the fastest level" window and is what buys the
//! much smaller writes at every level after it.

use crate::modules::Env;
use crate::pipeline::context::{CkptContext, Outcome};
use crate::pipeline::module::{Module, ModuleSwitch};
use crate::util::bufpool::Bytes;
use anyhow::Result;
use std::sync::Arc;

pub struct DeltaModule {
    env: Arc<Env>,
    switch: ModuleSwitch,
}

impl DeltaModule {
    pub fn new(env: Arc<Env>) -> Arc<Self> {
        Arc::new(DeltaModule {
            env,
            switch: ModuleSwitch::new(true),
        })
    }
}

impl Module for DeltaModule {
    fn name(&self) -> &'static str {
        "delta"
    }

    fn priority(&self) -> i32 {
        8 // after checksum (5), before the level-1 capture (10)
    }

    fn blocking(&self) -> bool {
        true
    }

    fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
        let Some(delta) = &self.env.delta else {
            return Ok(Outcome::Skipped);
        };
        // Base-durability probe: a version is an acceptable chain base
        // only if its level-1 container actually landed (a checkpoint
        // whose pipeline failed after the delta stage must not become a
        // phantom chain link). `exists` is free — no modeled read charge.
        let tiers = self.env.fabric.local_tiers(ctx.node);
        let base_ok = |v: u64| {
            let key = crate::pipeline::storage_key("local", &ctx.name, ctx.rank, v);
            tiers.iter().any(|t| t.exists(&key))
        };
        let container =
            delta.encode_checkpoint(&ctx.ckpt, ctx.version, ctx.node, &base_ok)?;
        // Derived data, not a payload copy: the thin VDLT container is a
        // new byte sequence wrapped without further copying.
        ctx.encoded = Bytes::from(container);
        ctx.encoding = "delta";
        Ok(Outcome::Done)
    }

    fn switch(&self) -> &ModuleSwitch {
        &self.switch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::delta::{self, DeltaConfig, DeltaState};
    use crate::modules::VersionRegistry;
    use crate::storage::{FabricConfig, StorageFabric};
    use crate::util::bytes::Checkpoint;

    fn env(with_delta: bool) -> Arc<Env> {
        let fabric = Arc::new(
            StorageFabric::build(&FabricConfig {
                nodes: 2,
                ..Default::default()
            })
            .unwrap(),
        );
        let cfg = DeltaConfig {
            enabled: true,
            min_chunk: 64,
            avg_chunk: 256,
            max_chunk: 1024,
            max_chain: 4,
        };
        let state = if with_delta {
            Some(DeltaState::new(cfg, &fabric, None).unwrap())
        } else {
            None
        };
        Arc::new(Env {
            topology: Topology::new(2, 1),
            fabric,
            pjrt: None,
            registry: VersionRegistry::new(),
            scheduler_gate: None,
            aggregator: None,
            delta: state,
            placement: None,
            restore: None,
        })
    }

    fn ctx(version: u64, data: Vec<u8>) -> CkptContext {
        let mut c = Checkpoint::new("t", 0, version);
        c.push_region(0, data);
        CkptContext::new("t", 0, 0, version, c)
    }

    #[test]
    fn swaps_payload_for_delta_container() {
        let e = env(true);
        let m = DeltaModule::new(Arc::clone(&e));
        let data: Vec<u8> = (0..8_192u32)
            .map(|i| (i.wrapping_mul(2_654_435_761) >> 13) as u8)
            .collect();
        let mut c1 = ctx(1, data.clone());
        m.process(&mut c1).unwrap();
        assert_eq!(c1.encoding, "delta");
        assert!(delta::is_delta(&c1.encoded));
        // The base-durability probe checks the level-1 copy; stand in for
        // the local module (this unit test runs the delta stage alone).
        e.fabric.local_tiers(0)[0]
            .put(&c1.key("local"), &c1.encoded)
            .unwrap();
        // Second version with a tiny edit: far smaller container.
        let mut edited = data;
        edited[4_000] ^= 0xFF;
        let mut c2 = ctx(2, edited);
        m.process(&mut c2).unwrap();
        assert!(
            c2.encoded.len() * 3 < c1.encoded.len(),
            "incremental container {} vs full {}",
            c2.encoded.len(),
            c1.encoded.len()
        );
        // The container materializes bit-for-bit through the node store.
        let state = e.delta.as_ref().unwrap();
        let out = delta::materialize(
            c2.encoded.to_vec(),
            Some(state.store(0).as_ref()),
            &|_| None,
        )
        .unwrap();
        assert_eq!(out, *c2.ckpt);
    }

    #[test]
    fn without_state_the_stage_skips() {
        let e = env(false);
        let m = DeltaModule::new(e);
        let mut c = ctx(1, vec![1u8; 512]);
        assert_eq!(m.process(&mut c).unwrap(), Outcome::Skipped);
        assert_eq!(c.encoding, "raw");
    }
}
