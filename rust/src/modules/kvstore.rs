//! Level-5 module: key-value object repository (the DAOS integration of
//! paper §4: "an experimental module that leverages an optimized low-level
//! put/get API for key-value pairs").
//!
//! Unlike the PFS flush (one big POSIX-ish object), the KV module stores
//! each *region* as its own object plus a small index object — the
//! fine-grained put/get pattern an object store is good at, and what makes
//! its low per-op latency pay off for many-region checkpoints (E11).

use crate::modules::Env;
use crate::pipeline::context::{CkptContext, Outcome, RestoreContext, LEVEL_KV};
use crate::pipeline::module::{Module, ModuleSwitch};
use crate::util::bytes::Checkpoint;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Instant;

pub struct KvStoreModule {
    env: Arc<Env>,
    switch: ModuleSwitch,
}

impl KvStoreModule {
    pub fn new(env: Arc<Env>, enabled: bool) -> Arc<Self> {
        Arc::new(KvStoreModule {
            env,
            switch: ModuleSwitch::new(enabled),
        })
    }
}

impl Module for KvStoreModule {
    fn name(&self) -> &'static str {
        "kvstore"
    }

    fn priority(&self) -> i32 {
        41
    }

    fn level(&self) -> u8 {
        LEVEL_KV
    }

    fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
        let Some(kv) = self.env.fabric.kv() else {
            return Ok(Outcome::Skipped);
        };
        let t0 = Instant::now();
        let base = ctx.key("kv");
        let mut total = 0u64;
        let mut index = Vec::new();
        for region in &ctx.ckpt.regions {
            let okey = format!("{base}.obj{}", region.id);
            let stat = kv.put(&okey, &region.data)?;
            total += stat.bytes;
            index.push(
                Json::obj()
                    .set("id", region.id as u64)
                    .set("len", region.data.len() as u64),
            );
        }
        let idx = Json::obj()
            .set("name", ctx.name.as_str())
            .set("rank", ctx.rank)
            .set("iteration", ctx.ckpt.meta.iteration)
            .set("regions", Json::Arr(index))
            .to_string();
        let stat = kv.put(&format!("{base}.index"), idx.as_bytes())?;
        total += stat.bytes;
        ctx.record(self.name(), LEVEL_KV, t0.elapsed().max(stat.modeled), total);
        Ok(Outcome::Done)
    }

    fn restore(&self, ctx: &RestoreContext) -> Result<Option<Checkpoint>> {
        let Some(version) = ctx.version else {
            return Ok(None);
        };
        let Some(kv) = self.env.fabric.kv() else {
            return Ok(None);
        };
        let base = format!("kv.{}.r{}.v{}", ctx.name, ctx.rank, version);
        let Some((idx_bytes, _)) = kv.get(&format!("{base}.index")) else {
            return Ok(None);
        };
        let idx = Json::parse(std::str::from_utf8(&idx_bytes)?)
            .map_err(|e| anyhow!("kv index: {e}"))?;
        let iteration = idx
            .get("iteration")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("kv index missing iteration"))?;
        let mut ckpt = Checkpoint::new(&ctx.name, ctx.rank, iteration);
        for r in idx
            .get("regions")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("kv index missing regions"))?
        {
            let id = r
                .get("id")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("region id"))? as u32;
            let len = r
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("region len"))?;
            let Some((data, _)) = kv.get(&format!("{base}.obj{id}")) else {
                return Ok(None); // partial object set: not usable
            };
            if data.len() != len {
                return Ok(None);
            }
            ckpt.push_region(id, data);
        }
        Ok(Some(ckpt))
    }

    fn switch(&self) -> &ModuleSwitch {
        &self.switch
    }
}
