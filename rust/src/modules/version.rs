//! Version registry + version module (pipeline tail).
//!
//! Tracks which versions of which named checkpoint reached which resilience
//! level on which rank — the lineage that makes snapshots "discoverable and
//! accessible", the *data states* idea the paper cites ([2]). The registry
//! also drives restart (latest complete version) and garbage collection
//! (keep the last K versions per level).

use crate::pipeline::context::{CkptContext, Outcome};
use crate::pipeline::module::{Module, ModuleSwitch};
use crate::util::json::Json;
use anyhow::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

/// Per (name, version, rank) record.
#[derive(Clone, Debug, Default)]
pub struct VersionInfo {
    /// Levels that completed for this rank.
    pub levels: Vec<u8>,
    pub bytes: u64,
    /// Payload encoding of remote copies ("raw" or "zlib").
    pub encoding: String,
    /// Integrity checksum of the encoded container (crc32 or kernel).
    pub checksum: Option<u32>,
    /// Shared tier that actually stored the level-4 copy when adaptive
    /// placement routed it (None = the static default target). Restores
    /// probe this tier first, then fall back to the whole shared pool.
    pub dest: Option<String>,
}

#[derive(Default)]
struct RegistryInner {
    /// name -> version -> rank -> info
    entries: HashMap<String, BTreeMap<u64, HashMap<usize, VersionInfo>>>,
}

/// Global (process-wide) version registry shared by all ranks.
#[derive(Default)]
pub struct VersionRegistry {
    inner: Mutex<RegistryInner>,
}

impl VersionRegistry {
    pub fn new() -> Arc<Self> {
        Arc::new(VersionRegistry::default())
    }

    pub fn record_level(
        &self,
        name: &str,
        version: u64,
        rank: usize,
        level: u8,
        bytes: u64,
        encoding: &str,
    ) {
        let mut g = self.inner.lock().unwrap();
        let info = g
            .entries
            .entry(name.to_string())
            .or_default()
            .entry(version)
            .or_default()
            .entry(rank)
            .or_default();
        if !info.levels.contains(&level) {
            info.levels.push(level);
            info.levels.sort_unstable();
        }
        info.bytes = bytes;
        info.encoding = encoding.to_string();
    }

    /// Append a completed level without touching the recorded payload
    /// size (the aggregator calls this at container-drain time, when the
    /// payload became durable — it only knows encoded container bytes, and
    /// the pipeline already recorded the accurate payload size).
    pub fn record_level_only(
        &self,
        name: &str,
        version: u64,
        rank: usize,
        level: u8,
        encoding: &str,
    ) {
        let mut g = self.inner.lock().unwrap();
        let info = g
            .entries
            .entry(name.to_string())
            .or_default()
            .entry(version)
            .or_default()
            .entry(rank)
            .or_default();
        if !info.levels.contains(&level) {
            info.levels.push(level);
            info.levels.sort_unstable();
        }
        if info.encoding.is_empty() {
            info.encoding = encoding.to_string();
        }
    }

    /// Record which shared tier a level-4 flush actually landed on (the
    /// placement engine's failover/adaptive choice). Restores consult it
    /// via [`VersionInfo::dest`].
    pub fn set_destination(&self, name: &str, version: u64, rank: usize, tier_id: &str) {
        let mut g = self.inner.lock().unwrap();
        g.entries
            .entry(name.to_string())
            .or_default()
            .entry(version)
            .or_default()
            .entry(rank)
            .or_default()
            .dest = Some(tier_id.to_string());
    }

    pub fn set_checksum(&self, name: &str, version: u64, rank: usize, crc: u32) {
        let mut g = self.inner.lock().unwrap();
        g.entries
            .entry(name.to_string())
            .or_default()
            .entry(version)
            .or_default()
            .entry(rank)
            .or_default()
            .checksum = Some(crc);
    }

    pub fn info(&self, name: &str, version: u64, rank: usize) -> Option<VersionInfo> {
        let g = self.inner.lock().unwrap();
        g.entries.get(name)?.get(&version)?.get(&rank).cloned()
    }

    /// All versions of `name`, newest first.
    pub fn versions(&self, name: &str) -> Vec<u64> {
        let g = self.inner.lock().unwrap();
        g.entries
            .get(name)
            .map(|m| m.keys().rev().copied().collect())
            .unwrap_or_default()
    }

    /// Has every one of `world` ranks recorded at least one level for this
    /// version (i.e. every rank's pipeline tail finished it)?
    pub fn complete(&self, name: &str, version: u64, world: usize) -> bool {
        let g = self.inner.lock().unwrap();
        g.entries
            .get(name)
            .and_then(|m| m.get(&version))
            .map(|ranks| {
                ranks.len() == world && ranks.values().all(|i| !i.levels.is_empty())
            })
            .unwrap_or(false)
    }

    /// Latest version for which every one of `world` ranks reached at least
    /// one level (the restartable frontier).
    pub fn latest_complete(&self, name: &str, world: usize) -> Option<u64> {
        let g = self.inner.lock().unwrap();
        let versions = g.entries.get(name)?;
        versions
            .iter()
            .rev()
            .find(|(_, ranks)| {
                ranks.len() == world
                    && ranks.values().all(|i| !i.levels.is_empty())
            })
            .map(|(&v, _)| v)
    }

    /// Versions older than the newest `keep` for `name` (GC candidates).
    pub fn gc_candidates(&self, name: &str, keep: usize) -> Vec<u64> {
        let vs = self.versions(name);
        vs.into_iter().skip(keep).collect()
    }

    pub fn forget(&self, name: &str, version: u64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(m) = g.entries.get_mut(name) {
            m.remove(&version);
        }
    }

    /// Rehydrate a registry entry from a persisted lineage JSON (cold
    /// restart: the in-process registry is empty but the PFS survived).
    pub fn load_json(&self, j: &Json) -> anyhow::Result<()> {
        use anyhow::anyhow;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("lineage missing name"))?;
        for v in j
            .get("versions")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("lineage missing versions"))?
        {
            let version = v
                .get("version")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("version entry missing number"))?;
            for r in v.get("ranks").and_then(Json::as_arr).unwrap_or(&[]) {
                let rank = r
                    .get("rank")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("rank entry missing rank"))?;
                let bytes = r.get("bytes").and_then(Json::as_u64).unwrap_or(0);
                let encoding = r.str_or("encoding", "raw").to_string();
                for l in r.get("levels").and_then(Json::as_arr).unwrap_or(&[]) {
                    if let Some(level) = l.as_u64() {
                        self.record_level(
                            name,
                            version,
                            rank,
                            level as u8,
                            bytes,
                            &encoding,
                        );
                    }
                }
                if let Some(c) = r.get("checksum").and_then(Json::as_u64) {
                    self.set_checksum(name, version, rank, c as u32);
                }
                if let Some(d) = r.get("dest").and_then(Json::as_str) {
                    self.set_destination(name, version, rank, d);
                }
            }
        }
        Ok(())
    }

    /// JSON dump (persisted to the PFS by the version module so that a
    /// cold restart can rediscover the lineage).
    pub fn to_json(&self, name: &str) -> Json {
        let g = self.inner.lock().unwrap();
        let mut versions = Vec::new();
        if let Some(m) = g.entries.get(name) {
            for (&v, ranks) in m {
                let mut rank_arr = Vec::new();
                for (&r, info) in ranks {
                    let mut entry = Json::obj()
                        .set("rank", r)
                        .set(
                            "levels",
                            info.levels
                                .iter()
                                .map(|&l| Json::Num(l as f64))
                                .collect::<Vec<_>>(),
                        )
                        .set("bytes", info.bytes)
                        .set("encoding", info.encoding.as_str());
                    if let Some(c) = info.checksum {
                        entry = entry.set("checksum", c as u64);
                    }
                    if let Some(d) = &info.dest {
                        entry = entry.set("dest", d.as_str());
                    }
                    rank_arr.push(entry);
                }
                versions.push(
                    Json::obj()
                        .set("version", v)
                        .set("ranks", Json::Arr(rank_arr)),
                );
            }
        }
        Json::obj()
            .set("name", name)
            .set("versions", Json::Arr(versions))
    }
}

/// Pipeline tail: records completion in the registry and garbage-collects
/// old versions from every tier.
pub struct VersionModule {
    registry: Arc<VersionRegistry>,
    fabric: Arc<crate::storage::StorageFabric>,
    /// When aggregation is on, GC also reclaims orphaned containers.
    aggregator: Option<Arc<crate::aggregation::Aggregator>>,
    /// When delta is on, GC pins chain ancestors of retained versions and
    /// releases chunk refcounts of the versions it collects.
    delta: Option<Arc<crate::delta::DeltaState>>,
    /// Cluster shape: partner copies live on the partner's node, so GC
    /// must reach across to reclaim them.
    topology: crate::cluster::Topology,
    /// Keep this many newest versions per name (per rank).
    keep: usize,
    /// World size: GC only touches versions every rank has finished
    /// (otherwise a fast rank could delete local copies a slow peer's
    /// erasure stage is still reading — a real race observed under a
    /// saturated active backend).
    world: usize,
    switch: ModuleSwitch,
}

impl VersionModule {
    pub fn new(
        registry: Arc<VersionRegistry>,
        fabric: Arc<crate::storage::StorageFabric>,
        aggregator: Option<Arc<crate::aggregation::Aggregator>>,
        delta: Option<Arc<crate::delta::DeltaState>>,
        topology: crate::cluster::Topology,
        keep: usize,
    ) -> Arc<Self> {
        Arc::new(VersionModule {
            registry,
            fabric,
            aggregator,
            delta,
            topology,
            keep: keep.max(1),
            world: topology.world_size().max(1),
            switch: ModuleSwitch::new(true),
        })
    }

    /// GC candidates: strictly older than the `keep` newest versions AND
    /// fully recorded by all ranks (pipeline tails complete everywhere).
    /// Under delta, additionally spare any version a retained version's
    /// manifest chain still references — deleting a chain link would break
    /// bit-for-bit reassembly of checkpoints we promised to keep.
    fn safe_gc_candidates(&self, name: &str) -> Vec<u64> {
        let mut candidates: Vec<u64> = self
            .registry
            .gc_candidates(name, self.keep)
            .into_iter()
            .filter(|&v| self.registry.complete(name, v, self.world))
            .collect();
        if let Some(delta) = &self.delta {
            let doomed: std::collections::BTreeSet<u64> =
                candidates.iter().copied().collect();
            let mut pinned = std::collections::BTreeSet::new();
            for kept in self
                .registry
                .versions(name)
                .into_iter()
                .filter(|v| !doomed.contains(v))
            {
                let ancestors = delta.chain_ancestors(name, kept);
                if ancestors.is_empty() && !delta.has_manifest(name, kept) {
                    // No in-memory manifest at all for a retained version:
                    // the chain knowledge died with a node or process. If
                    // the registry says it was delta-encoded, its chain is
                    // unknowable — skip GC for this name entirely until
                    // the version ages out (the next forced full restarts
                    // normal collection).
                    let delta_encoded = (0..self.world).any(|r| {
                        self.registry
                            .info(name, kept, r)
                            .map_or(false, |i| i.encoding == "delta")
                    });
                    if delta_encoded {
                        return Vec::new();
                    }
                }
                pinned.extend(ancestors);
            }
            candidates.retain(|v| !pinned.contains(v));
        }
        candidates
    }

    fn delete_version_keys(&self, name: &str, rank: usize, node: usize, version: u64) {
        let suffix = format!("{name}.r{rank}.v{version}");
        for tier in self.fabric.local_tiers(node) {
            for prefix in ["local", "erasure"] {
                tier.delete(&format!("{prefix}.{suffix}"));
            }
        }
        // My partner copy lives on my *partner's* node (keyed by source
        // rank); deleting `partner.{suffix}` on my own node would hit a
        // key that never exists there and leak the replica forever.
        if self.topology.nodes >= 2 {
            let pnode = self.topology.node_of(self.topology.partner_of(rank));
            for tier in self.fabric.local_tiers(pnode) {
                tier.delete(&format!("partner.{suffix}"));
            }
        }
        // Level-4 objects keep their "pfs." key prefix wherever placement
        // landed them, so GC sweeps the whole shared pool (deletes are
        // bookkeeping and work even on down/read-only tiers).
        for tier in self.fabric.shared_tiers() {
            tier.delete(&format!("pfs.{suffix}"));
        }
        if let Some(kv) = self.fabric.kv() {
            kv.delete(&format!("kv.{suffix}"));
        }
        // Aggregated copies: drop the version from the segment index and
        // delete containers it orphaned (idempotent across ranks).
        if let Some(agg) = &self.aggregator {
            let _ = agg.gc_version(name, version);
        }
        // Delta bookkeeping: forget this rank's manifest and drop its
        // chunk references (reclaiming payloads whose count hits zero,
        // under the store's crash-replayable intent ledger).
        if let Some(delta) = &self.delta {
            let _ = delta.retire(name, version, rank, node);
        }
    }
}

impl Module for VersionModule {
    fn name(&self) -> &'static str {
        "version"
    }

    fn priority(&self) -> i32 {
        50
    }

    fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
        // Record every level the earlier stages completed.
        for r in &ctx.results {
            if r.level > 0 {
                self.registry.record_level(
                    &ctx.name,
                    ctx.version,
                    ctx.rank,
                    r.level,
                    ctx.ckpt.payload_bytes(),
                    ctx.encoding,
                );
            }
        }
        // GC old versions for this rank (only globally-complete ones).
        for v in self.safe_gc_candidates(&ctx.name) {
            self.delete_version_keys(&ctx.name, ctx.rank, ctx.node, v);
        }
        // Persist the lineage (DataStates, paper [2]): small JSON,
        // last-writer-wins; every rank's view converges as the pipeline
        // tails complete. A cold restart reloads it via
        // `VersionRegistry::load_json` / `VelocRuntime::reload_lineage`.
        // The PFS is the home, but the lineage now carries placement
        // destinations — during a PFS outage it must fail over to another
        // shared tier like the data it describes, or a cold restart could
        // not find the failed-over checkpoints (reload_lineage probes and
        // merges every shared tier's copy).
        let lineage = crate::util::bufpool::Bytes::from(
            self.registry.to_json(&ctx.name).to_string().into_bytes(),
        );
        let key = format!("lineage.{}.json", ctx.name);
        let tiers = self.fabric.shared_tiers();
        let mut wrote: Option<String> = None;
        for tier in &tiers {
            if tier.put_bytes(&key, &lineage).is_ok() {
                wrote = Some(tier.id().to_string());
                break;
            }
        }
        // Scrub stale failover copies (best effort) — but only after a
        // successful *primary* write, and never the primary copy itself.
        // Ranks write concurrently: if a failed-over rank could delete
        // the primary copy (or a primary-writing rank delete a
        // failed-over one racing it), an unlucky interleaving would leave
        // zero lineage copies anywhere. With this rule the primary copy
        // is never deleted, so at least the latest successful primary
        // write always survives; failover copies linger only until the
        // primary is writable again (and merging a stale copy is benign —
        // records accumulate).
        if wrote.as_deref() == tiers.first().map(|t| t.id()) {
            for tier in tiers.iter().skip(1) {
                tier.delete(&key);
            }
        }
        Ok(Outcome::Done)
    }

    fn switch(&self) -> &ModuleSwitch {
        &self.switch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let r = VersionRegistry::new();
        r.record_level("app", 1, 0, 1, 100, "raw");
        r.record_level("app", 1, 0, 4, 100, "raw");
        r.record_level("app", 1, 1, 1, 100, "raw");
        let info = r.info("app", 1, 0).unwrap();
        assert_eq!(info.levels, vec![1, 4]);
        assert_eq!(r.versions("app"), vec![1]);
        assert_eq!(r.latest_complete("app", 2), Some(1));
        assert_eq!(r.latest_complete("app", 3), None);
    }

    #[test]
    fn latest_complete_requires_all_ranks() {
        let r = VersionRegistry::new();
        r.record_level("a", 1, 0, 1, 10, "raw");
        r.record_level("a", 1, 1, 1, 10, "raw");
        r.record_level("a", 2, 0, 1, 10, "raw"); // rank 1 missing at v2
        assert_eq!(r.latest_complete("a", 2), Some(1));
        r.record_level("a", 2, 1, 2, 10, "raw");
        assert_eq!(r.latest_complete("a", 2), Some(2));
    }

    #[test]
    fn gc_candidates_skip_newest() {
        let r = VersionRegistry::new();
        for v in 1..=5 {
            r.record_level("a", v, 0, 1, 10, "raw");
        }
        assert_eq!(r.gc_candidates("a", 2), vec![3, 2, 1]);
        r.forget("a", 1);
        assert_eq!(r.versions("a"), vec![5, 4, 3, 2]);
    }

    #[test]
    fn checksum_round_trip() {
        let r = VersionRegistry::new();
        r.set_checksum("a", 1, 3, 0xDEADBEEF);
        assert_eq!(r.info("a", 1, 3).unwrap().checksum, Some(0xDEADBEEF));
    }

    #[test]
    fn json_dump_shape() {
        let r = VersionRegistry::new();
        r.record_level("a", 7, 0, 1, 10, "raw");
        let j = r.to_json("a");
        assert_eq!(j.str_or("name", ""), "a");
        let v = j.get("versions").unwrap().idx(0).unwrap();
        assert_eq!(v.usize_or("version", 0), 7);
    }

    #[test]
    fn destination_recorded_and_survives_lineage_roundtrip() {
        let r = VersionRegistry::new();
        r.record_level("a", 1, 0, 4, 10, "raw");
        r.set_destination("a", 1, 0, "burst-buffer");
        assert_eq!(
            r.info("a", 1, 0).unwrap().dest.as_deref(),
            Some("burst-buffer")
        );
        // Cold restart: a fresh registry rehydrated from the lineage JSON
        // must still know where the flush landed.
        let fresh = VersionRegistry::new();
        fresh.load_json(&r.to_json("a")).unwrap();
        assert_eq!(
            fresh.info("a", 1, 0).unwrap().dest.as_deref(),
            Some("burst-buffer")
        );
    }
}
