//! Level-2 module: partner replication.
//!
//! Each rank pushes its encoded checkpoint to the node-local storage of its
//! ring partner (same slot, next node — a distinct failure domain, see
//! `cluster::topology::Topology::partner_of`). A node failure then leaves a
//! full copy of every lost rank's state on a surviving node.
//!
//! Modeling note: the push is a direct write into the partner node's tier
//! (standing in for the RDMA/interconnect transfer the real system does);
//! the charged cost is the partner tier's write cost, which dominates the
//! network hop on the machines the paper targets.

use crate::modules::Env;
use crate::pipeline::context::{CkptContext, Outcome, RestoreContext, LEVEL_PARTNER};
use crate::pipeline::module::{Module, ModuleSwitch};
use crate::util::bytes::Checkpoint;
use anyhow::{bail, Result};
use std::sync::Arc;

pub struct PartnerModule {
    env: Arc<Env>,
    switch: ModuleSwitch,
}

impl PartnerModule {
    pub fn new(env: Arc<Env>) -> Arc<Self> {
        Arc::new(PartnerModule {
            env,
            switch: ModuleSwitch::new(true),
        })
    }

    /// Partner copies go to the partner node's *largest* local tier so they
    /// do not evict the partner's own level-1 copies from the fast tier.
    fn target_tier(
        &self,
        node: usize,
        bytes: u64,
    ) -> Option<Arc<crate::storage::StorageTier>> {
        let tiers = self.env.fabric.local_tiers(node);
        tiers
            .iter()
            .rev() // slowest/biggest first
            .find(|t| t.used_bytes() + bytes <= t.spec().capacity)
            .cloned()
    }
}

impl Module for PartnerModule {
    fn name(&self) -> &'static str {
        "partner"
    }

    fn priority(&self) -> i32 {
        20
    }

    fn level(&self) -> u8 {
        LEVEL_PARTNER
    }

    fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
        if self.env.topology.nodes < 2 {
            // No distinct failure domain to replicate into.
            return Ok(Outcome::Skipped);
        }
        let partner = self.env.topology.partner_of(ctx.rank);
        let pnode = self.env.topology.node_of(partner);
        let bytes = ctx.encoded.len() as u64;
        let Some(tier) = self.target_tier(pnode, bytes) else {
            bail!("partner node {pnode} has no capacity for {bytes} bytes");
        };
        // Keyed by the *source* rank so recovery of rank r knows where to
        // look regardless of which rank stored it.
        let stat = tier.put_bytes(&ctx.key("partner"), &ctx.encoded)?;
        ctx.record(self.name(), LEVEL_PARTNER, stat.modeled, stat.bytes);
        Ok(Outcome::Done)
    }

    fn restore(&self, ctx: &RestoreContext) -> Result<Option<Checkpoint>> {
        let Some(version) = ctx.version else {
            return Ok(None);
        };
        if self.env.topology.nodes < 2 {
            return Ok(None);
        }
        // My copy lives on my partner's node.
        let partner = self.env.topology.partner_of(ctx.rank);
        let pnode = self.env.topology.node_of(partner);
        let tiers = self.env.fabric.local_tiers(pnode);
        let fetch_at = |v: u64| -> Option<Vec<u8>> {
            let key = crate::pipeline::storage_key("partner", &ctx.name, ctx.rank, v);
            tiers.iter().find_map(|t| t.get(&key).map(|(d, _)| d))
        };
        // Delta chains walk the partner copies of older versions on the
        // same node; the partner node's chunk store is consulted first
        // (fingerprint-verified, so cross-rank hits are safe and misses
        // just fall through to the chain).
        let store = self.env.delta.as_ref().map(|d| d.store(pnode).as_ref());
        // Restore plane: cached entries live on the partner node (its
        // tiers hold the real copies the cache mirrors).
        if let Some(eng) = &self.env.restore {
            let fetch = |v: u64| -> Result<Option<Vec<u8>>> { Ok(fetch_at(v)) };
            return eng.materialize(
                "partner", &ctx.name, ctx.rank, pnode, version, store, &fetch,
            );
        }
        let Some(data) = fetch_at(version) else {
            return Ok(None);
        };
        Ok(Some(crate::delta::materialize(data, store, &fetch_at)?))
    }

    fn switch(&self) -> &ModuleSwitch {
        &self.switch
    }
}
