//! The *active backend* as a real subsystem: an out-of-process checkpoint
//! engine (`veloc daemon`) with an IPC client, a crash-safe job journal
//! and multi-client fair scheduling.
//!
//! VeloC's defining design split (paper §3) is a thin client library in
//! front of an active backend that runs the multi-level resilience
//! pipeline *outside* the application process: post-processing survives
//! independently of the app, costs it almost nothing, and one backend can
//! serve many jobs. This module realizes that split on top of the
//! existing in-process [`VelocRuntime`](crate::api::VelocRuntime):
//!
//! - [`daemon`] — [`BackendDaemon`]: hosts the runtime, admits and
//!   fair-schedules submissions from many jobs, journals every accepted
//!   checkpoint before acknowledging it, and replays the journal after a
//!   crash so *a backend failure never loses an acked checkpoint*.
//! - [`wire`] — the length-prefixed Unix-domain-socket frame protocol
//!   (register job/rank, submit via staged payload handoff, poll/wait
//!   status, restart query, stats, shutdown).
//! - [`journal`] — the write-ahead pending-job journal: payload staged
//!   durably + `begin` record fsynced *before* the ack; `end` records
//!   settle entries; open-time replay returns what was acked but never
//!   settled.
//! - [`queue`] — per-job bounded FIFO queues with round-robin dispatch:
//!   concurrent jobs share drain bandwidth predictably, and a job that
//!   outruns its queue depth is pushed back with a typed
//!   [`Backpressure`] rejection instead of unbounded buffering.
//! - [`client`] — [`BackendClient`]/[`SocketTransport`]: the socket
//!   implementation of [`Transport`](crate::api::Transport), so daemon
//!   clients are ordinary [`VelocClient`](crate::api::VelocClient)s.
//!
//! In-process and out-of-process paths sit behind the same public API:
//!
//! ```no_run
//! use veloc::backend::BackendClient;
//! let backend = BackendClient::connect("/tmp/veloc-daemon/veloc.sock");
//! let client = backend.client("train-a", 0).unwrap();
//! client.mem_protect(0, vec![0u8; 1 << 20]);
//! client.checkpoint("model", 1).unwrap();
//! client.checkpoint_wait("model", 1).unwrap();
//! ```

pub mod client;
pub mod daemon;
pub mod journal;
pub mod queue;
pub mod wire;

#[cfg(unix)]
pub use client::{BackendClient, SocketTransport};
pub use daemon::{BackendDaemon, DaemonTransport, Payload, SubmitAck};
pub use journal::{scan_records, Journal, PendingEntry};
pub use queue::{FairQueue, Submission};

use anyhow::{bail, Result};
use std::path::PathBuf;

/// Configuration of the backend daemon (the `backend` JSON section and
/// the `veloc daemon` CLI flags).
#[derive(Clone, Debug)]
pub struct BackendConfig {
    /// Daemon home directory: holds `journal/` (WAL + pending payloads),
    /// `staging/` (client payload handoff on the local tier) and, unless
    /// overridden, the listening socket.
    pub dir: PathBuf,
    /// Unix-domain-socket path; `None` derives `<dir>/veloc.sock`.
    pub socket: Option<PathBuf>,
    /// Admission bound per job: acked-but-unsettled checkpoints beyond
    /// this are rejected with [`Backpressure`].
    pub queue_depth: usize,
    /// Payloads at most this large travel inline in the submit frame;
    /// larger ones are staged as files and handed off by name.
    pub inline_max: usize,
    /// Fsync the staged payload and the WAL record before acknowledging a
    /// submit (the durability contract; disable only for benchmarks).
    pub fsync: bool,
    /// Largest inline frame body the daemon will read from a client
    /// socket before rejecting the frame with a typed
    /// [`WireError::BodyTooLarge`](wire::WireError::BodyTooLarge).
    /// Defaults to the protocol maximum [`wire::MAX_BODY`]; deployments
    /// whose clients always stage large payloads can run much tighter.
    pub max_frame_body: usize,
}

impl Default for BackendConfig {
    fn default() -> Self {
        BackendConfig {
            dir: PathBuf::from("veloc-daemon"),
            socket: None,
            queue_depth: 64,
            inline_max: 64 << 10,
            fsync: true,
            max_frame_body: wire::MAX_BODY,
        }
    }
}

impl BackendConfig {
    /// The socket the daemon listens on (explicit or derived from `dir`).
    pub fn socket_path(&self) -> PathBuf {
        self.socket
            .clone()
            .unwrap_or_else(|| self.dir.join("veloc.sock"))
    }

    /// Reject configurations the daemon would have to patch up silently.
    pub fn validate(&self) -> Result<()> {
        if self.queue_depth == 0 {
            bail!("backend.queue_depth must be >= 1 (0 would reject every submit)");
        }
        if self.queue_depth > crate::pipeline::TRACKER_KEEP {
            bail!(
                "backend.queue_depth ({}) exceeds the engine's status-retention \
                 window ({}): a burst that deep could outlive its own completion \
                 records",
                self.queue_depth,
                crate::pipeline::TRACKER_KEEP
            );
        }
        if self.inline_max > wire::MAX_BODY {
            bail!(
                "backend.inline_max ({}) exceeds the wire frame limit ({})",
                self.inline_max,
                wire::MAX_BODY
            );
        }
        if self.max_frame_body < self.inline_max {
            bail!(
                "backend.max_frame_body ({}) is below inline_max ({}): every \
                 inline submit would be rejected at the socket",
                self.max_frame_body,
                self.inline_max
            );
        }
        Ok(())
    }
}

/// Typed admission-control rejection: the job's acked-but-unsettled
/// checkpoint count reached the configured queue depth. Callers back off
/// and resubmit (or raise `backend.queue_depth`); recover it with
/// `err.downcast_ref::<Backpressure>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Backpressure {
    /// The job that hit its bound.
    pub job: String,
    /// The job's unsettled checkpoint count at rejection time — at least
    /// the configured `queue_depth` bound, and possibly above it (journal
    /// replay re-admits acked work unconditionally).
    pub depth: usize,
}

impl std::fmt::Display for Backpressure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "backend backpressure: job {:?} has {} unsettled checkpoints queued",
            self.job, self.depth
        )
    }
}

impl std::error::Error for Backpressure {}

/// Internal checkpoint namespace of one job: two jobs both checkpointing
/// `"app"` must never collide in the version registry or on storage keys,
/// so every daemon-side name is scoped as `<len>.job@name`. The length
/// prefix keeps the job boundary unambiguous even on dir-backed tiers,
/// whose key sanitization maps both `@` and a job id's legal `_` to `_`
/// (without it, job `train` + name `a_x` and job `train_a` + name `x`
/// would share one file name).
pub fn scoped_name(job: &str, name: &str) -> String {
    format!("{}.{job}@{name}", job.len())
}

/// Is `job` a legal job id? Job ids travel into storage keys and staged
/// file names, so they are restricted to `[A-Za-z0-9._-]` and must be
/// non-empty and free of the `@` scoping separator.
pub fn valid_job_id(job: &str) -> bool {
    !job.is_empty()
        && job
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_id_validation() {
        assert!(valid_job_id("train-a"));
        assert!(valid_job_id("hacc_2.run"));
        assert!(!valid_job_id(""));
        assert!(!valid_job_id("a@b"));
        assert!(!valid_job_id("a/b"));
        assert!(!valid_job_id("a b"));
    }

    #[test]
    fn scoped_names_are_disjoint_across_jobs() {
        assert_ne!(scoped_name("a", "app"), scoped_name("b", "app"));
        assert_eq!(scoped_name("a", "app"), "1.a@app");
        // Disjoint even after dir-tier sanitization ('@' and '_' both
        // map to '_'): the length prefix pins the job boundary.
        let sanitize = |s: &str| -> String {
            s.chars()
                .map(|c| {
                    if c.is_ascii_alphanumeric() || c == '.' || c == '-' {
                        c
                    } else {
                        '_'
                    }
                })
                .collect()
        };
        assert_ne!(
            sanitize(&scoped_name("train", "a_x")),
            sanitize(&scoped_name("train_a", "x"))
        );
    }

    #[test]
    fn config_validation() {
        let mut c = BackendConfig::default();
        c.validate().unwrap();
        assert_eq!(c.socket_path(), c.dir.join("veloc.sock"));
        c.queue_depth = 0;
        assert!(c.validate().is_err());
        c.queue_depth = crate::pipeline::TRACKER_KEEP + 1;
        assert!(c.validate().is_err(), "depth beyond status retention");
        c.queue_depth = 4;
        c.max_frame_body = c.inline_max - 1;
        assert!(c.validate().is_err(), "frame cap below inline_max");
    }

    #[test]
    fn backpressure_downcasts() {
        let err = anyhow::Error::new(Backpressure {
            job: "j".to_string(),
            depth: 4,
        });
        let bp = err.downcast_ref::<Backpressure>().unwrap();
        assert_eq!(bp.depth, 4);
        assert!(err.to_string().contains("backpressure"));
    }
}
