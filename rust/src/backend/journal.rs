//! Crash-safe pending-job journal: a write-ahead log of every accepted
//! checkpoint, fsynced *before* the submit is acknowledged.
//!
//! The durability contract of the active backend is that an acked
//! checkpoint survives a backend crash. The journal realizes it with two
//! artifacts under `<dir>`:
//!
//! - `payloads/<id>.vckp` — the full submitted container, durable before
//!   its `begin` record is written (staged handoffs are renamed in, so the
//!   bytes the client fsynced become the journal copy without a rewrite);
//! - `wal.log` — framed records `[u32 len][json][u32 crc]`:
//!   `{"t":"begin", id, job, rank, name, version, payload}` appended (and
//!   fsynced) before the ack, `{"t":"end", id, ok}` appended when the
//!   pipeline settles (its loss is harmless: replaying a settled
//!   checkpoint re-runs an idempotent pipeline).
//!
//! [`Journal::open`] replays the log — tolerating a torn tail — returns
//! every acked-but-unsettled entry for resubmission, and compacts the log
//! down to exactly those entries.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One acked-but-unsettled checkpoint recovered from the WAL.
#[derive(Clone, Debug, PartialEq)]
pub struct PendingEntry {
    /// Journal id (monotonic per journal lifetime).
    pub id: u64,
    /// Owning job.
    pub job: String,
    /// Submitting rank.
    pub rank: usize,
    /// Daemon-scoped checkpoint name (`job@name`).
    pub name: String,
    /// Checkpoint version.
    pub version: u64,
    /// Durable payload container.
    pub payload: PathBuf,
}

/// The write-ahead journal. All appends are serialized; `begin` returns
/// only after the payload and the record are durable (when `fsync` is on).
pub struct Journal {
    wal: Mutex<File>,
    payloads: PathBuf,
    fsync: bool,
    next_id: AtomicU64,
}

fn encode_record(j: &Json) -> Vec<u8> {
    let body = j.to_string().into_bytes();
    let mut out = Vec::with_capacity(body.len() + 8);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out.extend_from_slice(&crc32fast::hash(&body).to_le_bytes());
    out
}

/// Parse one record at `buf[at..]`; `None` = torn/corrupt tail (stop).
/// All arithmetic on the untrusted length prefix is checked — a hostile
/// length can only end the scan, never overflow or slice out of bounds.
fn decode_record(buf: &[u8], at: usize) -> Option<(Json, usize)> {
    if at.checked_add(4)? > buf.len() {
        return None;
    }
    let len = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap()) as usize;
    let body_start = at + 4;
    let crc_start = body_start.checked_add(len)?;
    let end = crc_start.checked_add(4)?;
    if end > buf.len() {
        return None;
    }
    let body = &buf[body_start..crc_start];
    let stored = u32::from_le_bytes(buf[crc_start..end].try_into().unwrap());
    if crc32fast::hash(body) != stored {
        return None;
    }
    let text = std::str::from_utf8(body).ok()?;
    let j = Json::parse(text).ok()?;
    Some((j, end))
}

/// Scan a WAL image into its intact records, in append order, stopping at
/// the first torn or corrupt frame (everything behind a tear is
/// unreachable by construction: record boundaries cannot be re-found).
///
/// This is the exact parser [`Journal::open`] replays through, exposed so
/// the corruption suite and the fuzz harness can drive it against hostile
/// bytes directly: for any input it must return normally — typed absence,
/// never a panic or an allocation derived from an untrusted length.
pub fn scan_records(buf: &[u8]) -> Vec<Json> {
    let mut out = Vec::new();
    let mut at = 0usize;
    while let Some((j, next)) = decode_record(buf, at) {
        out.push(j);
        at = next;
    }
    out
}

impl Journal {
    /// Open (or create) the journal under `dir`; returns the journal and
    /// every acked-but-unsettled entry, in ack order. The log is
    /// compacted to exactly those entries.
    pub fn open(dir: &Path, fsync: bool) -> Result<(Journal, Vec<PendingEntry>)> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create journal dir {}", dir.display()))?;
        let payloads = dir.join("payloads");
        std::fs::create_dir_all(&payloads)?;
        let wal_path = dir.join("wal.log");

        // Replay: begins without a matching end, whose payload survives.
        let mut begins: Vec<PendingEntry> = Vec::new();
        let mut begun: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
        let mut ended: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
        let mut max_id = 0u64;
        if wal_path.exists() {
            let mut buf = Vec::new();
            File::open(&wal_path)?.read_to_end(&mut buf)?;
            for j in scan_records(&buf) {
                let id = j.get("id").and_then(Json::as_u64).unwrap_or(0);
                max_id = max_id.max(id);
                match j.str_or("t", "") {
                    "begin" => {
                        // Replay is idempotent per id: a duplicated begin
                        // (compaction rewrite interrupted mid-rename, or a
                        // replayed-then-recrashed daemon) resubmits once,
                        // under the first record's fields.
                        if !begun.insert(id) {
                            continue;
                        }
                        begins.push(PendingEntry {
                            id,
                            job: j.str_or("job", "").to_string(),
                            rank: j.usize_or("rank", 0),
                            name: j.str_or("name", "").to_string(),
                            version: j.get("version").and_then(Json::as_u64).unwrap_or(0),
                            payload: payloads.join(j.str_or("payload", "")),
                        });
                    }
                    "end" => {
                        ended.insert(id);
                    }
                    _ => {} // unknown record kind: skip (forward compat)
                }
            }
        }
        let mut pending: Vec<PendingEntry> = Vec::new();
        for e in begins {
            if ended.contains(&e.id) {
                continue;
            }
            if e.payload.exists() {
                pending.push(e);
            } else {
                // Most likely the end record was lost after the payload
                // delete (settled, benign) — but it is indistinguishable
                // from a lost payload, so say it out loud instead of
                // silently dropping an acked checkpoint.
                eprintln!(
                    "veloc journal: begin #{} ({} v{} rank {}) has no payload \
                     file; treating as settled (end record lost) — if this \
                     checkpoint never completed, it is gone",
                    e.id, e.name, e.version, e.rank
                );
            }
        }

        // Compact: rewrite the log with only the pending begins, so the
        // WAL stays bounded by the admission depth, not by history.
        let tmp = dir.join("wal.tmp");
        {
            let mut f = File::create(&tmp)?;
            for e in &pending {
                f.write_all(&encode_record(&begin_json(e)))?;
            }
            if fsync {
                f.sync_data()?;
            }
        }
        std::fs::rename(&tmp, &wal_path)?;
        if fsync {
            // Make the rename durable (best effort — not all filesystems
            // support directory fsync).
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }

        // Sweep payloads no begin record references (a crash landed
        // between payload create and WAL append): nothing can ever replay
        // them, so they must not accumulate on the fast tier.
        let referenced: std::collections::BTreeSet<std::ffi::OsString> = pending
            .iter()
            .filter_map(|e| e.payload.file_name().map(|f| f.to_os_string()))
            .collect();
        if let Ok(entries) = std::fs::read_dir(&payloads) {
            for entry in entries.flatten() {
                if !referenced.contains(&entry.file_name()) {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }

        let wal = OpenOptions::new().append(true).open(&wal_path)?;
        Ok((
            Journal {
                wal: Mutex::new(wal),
                payloads,
                fsync,
                next_id: AtomicU64::new(max_id + 1),
            },
            pending,
        ))
    }

    fn payload_file(id: u64) -> String {
        format!("{id}.vckp")
    }

    /// Journal an inline submission: persist the payload, then the begin
    /// record; both durable before this returns (fsync mode). The returned
    /// entry is what the dispatcher queues.
    pub fn begin(
        &self,
        job: &str,
        rank: usize,
        name: &str,
        version: u64,
        payload: &[u8],
    ) -> Result<PendingEntry> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let path = self.payloads.join(Self::payload_file(id));
        {
            let mut f = File::create(&path)
                .with_context(|| format!("journal payload {}", path.display()))?;
            f.write_all(payload)?;
            if self.fsync {
                f.sync_data()?;
            }
        }
        self.sync_payload_dir();
        self.append_begin(id, job, rank, name, version, &path)
            .map_err(|e| {
                // No begin record means no replay will ever reference this
                // payload: reclaim it instead of leaking it (ENOSPC on the
                // WAL would otherwise strand payloads on the fast tier).
                let _ = std::fs::remove_file(&path);
                e
            })
    }

    /// Make the payload's directory entry durable before the begin record
    /// is — a power loss must never leave a fsynced `begin` pointing at a
    /// file whose directory entry evaporated (replay would misread that
    /// as "settled"). Best effort: not every filesystem supports
    /// directory fsync.
    fn sync_payload_dir(&self) {
        if !self.fsync {
            return;
        }
        if let Ok(d) = File::open(&self.payloads) {
            let _ = d.sync_all();
        }
    }

    /// Journal a staged submission: adopt the client's already-durable
    /// staged file by renaming it into the payload store (no byte copy),
    /// then append the begin record.
    pub fn begin_staged(
        &self,
        job: &str,
        rank: usize,
        name: &str,
        version: u64,
        staged: &Path,
    ) -> Result<PendingEntry> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let path = self.payloads.join(Self::payload_file(id));
        std::fs::rename(staged, &path).with_context(|| {
            format!("adopt staged payload {} -> {}", staged.display(), path.display())
        })?;
        self.sync_payload_dir();
        self.append_begin(id, job, rank, name, version, &path)
            .map_err(|e| {
                // The submit errors back to the client (never acked), so
                // the adopted payload must not linger unreferenced.
                let _ = std::fs::remove_file(&path);
                e
            })
    }

    fn append_begin(
        &self,
        id: u64,
        job: &str,
        rank: usize,
        name: &str,
        version: u64,
        path: &Path,
    ) -> Result<PendingEntry> {
        let entry = PendingEntry {
            id,
            job: job.to_string(),
            rank,
            name: name.to_string(),
            version,
            payload: path.to_path_buf(),
        };
        let rec = encode_record(&begin_json(&entry));
        let mut wal = self.wal.lock().unwrap();
        wal.write_all(&rec)?;
        if self.fsync {
            wal.sync_data()?;
        }
        Ok(entry)
    }

    /// Settle an entry: append the end record and drop the payload. Never
    /// fsynced — losing an end record merely replays an idempotent,
    /// already-settled checkpoint.
    pub fn settle(&self, id: u64, ok: bool) -> Result<()> {
        let rec = encode_record(
            &Json::obj()
                .set("t", "end")
                .set("id", id)
                .set("ok", ok),
        );
        {
            let mut wal = self.wal.lock().unwrap();
            wal.write_all(&rec)?;
        }
        let _ = std::fs::remove_file(self.payloads.join(Self::payload_file(id)));
        Ok(())
    }
}

fn begin_json(e: &PendingEntry) -> Json {
    Json::obj()
        .set("t", "begin")
        .set("id", e.id)
        .set("job", e.job.as_str())
        .set("rank", e.rank)
        .set("name", e.name.as_str())
        .set("version", e.version)
        .set(
            "payload",
            e.payload
                .file_name()
                .map(|f| f.to_string_lossy().into_owned())
                .unwrap_or_default(),
        )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as Counter;

    static DIRS: Counter = Counter::new(0);

    fn tmp() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "veloc-journal-test-{}-{}",
            std::process::id(),
            DIRS.fetch_add(1, Ordering::SeqCst)
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn unsettled_entries_replay_settled_ones_do_not() {
        let dir = tmp();
        {
            let (j, pending) = Journal::open(&dir, true).unwrap();
            assert!(pending.is_empty());
            let a = j.begin("jobA", 0, "jobA@app", 1, b"VCKPaaaa").unwrap();
            let _b = j.begin("jobB", 1, "jobB@app", 1, b"VCKPbbbb").unwrap();
            j.settle(a.id, true).unwrap();
            // Journal dropped with B unsettled — the "crash".
        }
        let (_j2, pending) = Journal::open(&dir, true).unwrap();
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].job, "jobB");
        assert_eq!(pending[0].name, "jobB@app");
        assert_eq!(std::fs::read(&pending[0].payload).unwrap(), b"VCKPbbbb");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_ignored() {
        let dir = tmp();
        {
            let (j, _) = Journal::open(&dir, true).unwrap();
            j.begin("j", 0, "j@a", 1, b"payload-1").unwrap();
        }
        // Tear the log: append garbage that is not a whole record.
        {
            let mut f = OpenOptions::new()
                .append(true)
                .open(dir.join("wal.log"))
                .unwrap();
            f.write_all(&[0xFF, 0x13, 0x37]).unwrap();
        }
        let (_j, pending) = Journal::open(&dir, true).unwrap();
        assert_eq!(pending.len(), 1, "intact prefix survives the torn tail");
        assert_eq!(pending[0].version, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_record_followed_by_valid_record_stops_at_the_tear() {
        // A tear mid-log makes everything behind it unreachable: record
        // boundaries cannot be re-found, so a valid-looking record after
        // the tear must NOT be resurrected (it may be a stale leftover
        // from before a compaction that the tear destroyed).
        let dir = tmp();
        {
            let (j, _) = Journal::open(&dir, true).unwrap();
            j.begin("j", 0, "j@a", 1, b"payload-1").unwrap();
        }
        {
            let mut f = OpenOptions::new()
                .append(true)
                .open(dir.join("wal.log"))
                .unwrap();
            // Torn frame: a length prefix promising more than follows of
            // what would itself be a valid record...
            let torn = encode_record(
                &Json::obj().set("t", "begin").set("id", 7u64).set("version", 7u64),
            );
            f.write_all(&torn[..torn.len() - 6]).unwrap();
            // ...directly followed by a bytewise-valid record.
            f.write_all(&encode_record(
                &Json::obj().set("t", "begin").set("id", 8u64).set("version", 8u64),
            ))
            .unwrap();
        }
        let (_j, pending) = Journal::open(&dir, true).unwrap();
        assert_eq!(pending.len(), 1, "only the intact prefix replays");
        assert_eq!(pending[0].version, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicated_begin_replays_once() {
        let dir = tmp();
        let first = {
            let (j, _) = Journal::open(&dir, true).unwrap();
            j.begin("j", 0, "j@a", 1, b"payload-1").unwrap()
        };
        // Append a byte-identical duplicate of the begin record (what an
        // interrupted compaction rewrite can leave behind).
        {
            let mut f = OpenOptions::new()
                .append(true)
                .open(dir.join("wal.log"))
                .unwrap();
            f.write_all(&encode_record(&begin_json(&first))).unwrap();
        }
        let (_j, pending) = Journal::open(&dir, true).unwrap();
        assert_eq!(pending.len(), 1, "duplicate begin must not double-submit");
        assert_eq!(pending[0].id, first.id);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_stops_clean_on_hostile_lengths() {
        // Length prefix claiming usize-overflow territory: scan must end,
        // not panic or allocate.
        let mut buf = encode_record(&Json::obj().set("t", "end").set("id", 1u64));
        let intact = scan_records(&buf).len();
        assert_eq!(intact, 1);
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        buf.extend_from_slice(b"xx");
        assert_eq!(scan_records(&buf).len(), 1);
        // A record whose CRC does not match ends the scan too.
        let mut rec = encode_record(&Json::obj().set("t", "end").set("id", 2u64));
        let n = rec.len();
        rec[n - 1] ^= 0xFF;
        let mut buf2 = encode_record(&Json::obj().set("t", "end").set("id", 1u64));
        buf2.extend_from_slice(&rec);
        assert_eq!(scan_records(&buf2).len(), 1);
    }

    #[test]
    fn compaction_bounds_the_log() {
        let dir = tmp();
        {
            let (j, _) = Journal::open(&dir, true).unwrap();
            for v in 1..=20u64 {
                let e = j.begin("j", 0, "j@a", v, b"x").unwrap();
                j.settle(e.id, true).unwrap();
            }
        }
        let before = std::fs::metadata(dir.join("wal.log")).unwrap().len();
        // Re-open compacts away all settled history.
        let (_j, pending) = Journal::open(&dir, true).unwrap();
        assert!(pending.is_empty());
        let after = std::fs::metadata(dir.join("wal.log")).unwrap().len();
        assert_eq!(after, 0, "fully settled journal compacts to empty ({before} before)");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn staged_adoption_renames_without_copy() {
        let dir = tmp();
        let (j, _) = Journal::open(&dir, true).unwrap();
        let staged = dir.join("incoming.vckp");
        std::fs::write(&staged, b"staged-bytes").unwrap();
        let e = j.begin_staged("j", 2, "j@a", 3, &staged).unwrap();
        assert!(!staged.exists(), "staged file was adopted");
        assert_eq!(std::fs::read(&e.payload).unwrap(), b"staged-bytes");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ids_resume_past_history() {
        let dir = tmp();
        let first = {
            let (j, _) = Journal::open(&dir, true).unwrap();
            j.begin("j", 0, "j@a", 1, b"x").unwrap().id
        };
        let (j2, _) = Journal::open(&dir, true).unwrap();
        let second = j2.begin("j", 0, "j@a", 2, b"y").unwrap().id;
        assert!(second > first, "{second} must not collide with {first}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
