//! The backend daemon: hosts a [`VelocRuntime`] out of the application
//! process and serves many jobs through admission-controlled, journaled,
//! fair-scheduled submission queues.
//!
//! ## Lifecycle of one submit
//!
//! 1. **Admit** — the job's unsettled count is checked against
//!    `backend.queue_depth`; beyond it the submit is rejected with
//!    [`Backpressure`] (typed, client-visible) instead of buffering.
//! 2. **Journal** — the payload is made durable in the journal's payload
//!    store (staged handoffs are renamed in without a copy) and the
//!    `begin` record fsynced. Only then is the submit **acked**: from
//!    this point a daemon crash cannot lose the checkpoint.
//! 3. **Dispatch** — the fair queue feeds the dispatcher round-robin
//!    across jobs; the dispatcher decodes the payload and submits it to
//!    the rank's pipeline engine (blocking prefix on the dispatcher
//!    thread, async tail on the runtime's backend pool, gated by the
//!    existing scheduler).
//! 4. **Settle** — a single settle-poller thread multiplexes every
//!    outstanding submission: when a command reaches its terminal status
//!    it appends the journal `end` record and releases the admission
//!    slot (no per-submission thread, so slow flushes cannot head-of-line
//!    block settlement bookkeeping).
//!
//! ## Crash and replay
//!
//! [`BackendDaemon::crash`] models a daemon death (used by the
//! `backend-crash` scenarios): queued work is dropped, in-flight tails are
//! killed, nothing settles. A fresh daemon over the same journal
//! directory replays every acked-but-unsettled entry from the durable
//! payload copies and resubmits it — the paper's claim that a backend
//! failure never loses an acked checkpoint.

use crate::api::{SimHooks, Transport, VelocClient, VelocConfig, VelocRuntime};
use crate::backend::journal::Journal;
use crate::backend::queue::{FairQueue, Submission};
use crate::backend::{scoped_name, valid_job_id, Backpressure, BackendConfig};
use crate::obs::{FlightRecorder, ObsHandle, ObsServer, ObsState, SpanId};
use crate::pipeline::{CkptContext, CkptStatus};
use crate::recovery::Restored;
use crate::util::bytes::Checkpoint;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One dispatched-but-unsettled submission the settle poller tracks. The
/// list doubles as the in-flight dedup set: a same-(rank, name, version)
/// resubmission is held back until the earlier one settles, because the
/// engine tracker keys commands by that triple and two concurrent
/// submissions would make the terminal status ambiguous (the first tail's
/// `Done` must never settle the second's journal entry).
#[derive(Clone)]
struct Watch {
    id: u64,
    job: String,
    rank: usize,
    name: String,
    version: u64,
    /// Open "settle" span (NONE when tracing is off), closed by the
    /// settle poller at the terminal status.
    span: SpanId,
}

/// Outcome of an accepted-or-rejected submit.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitAck {
    /// Journaled durably; the daemon now owns the checkpoint.
    Acked,
    /// Admission control pushed back: the job has `unsettled` checkpoints
    /// outstanding, at or beyond the configured depth.
    Busy {
        /// The job's unsettled count at rejection time.
        unsettled: usize,
    },
}

/// How a submit's payload arrives.
pub enum Payload {
    /// The encoded container travels in the request itself. Owned (and
    /// shared): the daemon keeps the same allocation for the dispatcher's
    /// decode, so the hot inline path never copies or re-reads it.
    Inline(Arc<Vec<u8>>),
    /// The client staged the (already fsynced) container as a file in the
    /// daemon's staging directory — the local-tier handoff; the daemon
    /// adopts the file by rename.
    Staged(PathBuf),
}

/// The out-of-process checkpoint engine.
pub struct BackendDaemon {
    cfg: BackendConfig,
    runtime: Arc<VelocRuntime>,
    journal: Arc<Journal>,
    queue: Arc<FairQueue>,
    /// Dispatched-but-unsettled submissions, multiplexed by the single
    /// settle-poller thread (no per-submission thread is ever pinned, so
    /// a slow flush cannot head-of-line block settlement bookkeeping).
    watches: Arc<Mutex<Vec<Watch>>>,
    stop: Arc<AtomicBool>,
    serve_stop: AtomicBool,
    dispatch_paused: Arc<AtomicBool>,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    settler: Mutex<Option<std::thread::JoinHandle<()>>>,
    jobs: Mutex<BTreeSet<String>>,
    staging: PathBuf,
    /// Uniquifies staged *restore* handoff files (containers too large
    /// for one response frame travel back through the staging dir).
    restore_seq: std::sync::atomic::AtomicU64,
    /// Exclusive flock on `<dir>/daemon.lock` for this daemon's lifetime
    /// (unix): a second daemon on the same home dir would rewrite the
    /// live WAL and sweep the first one's payloads — refused instead.
    _dir_lock: Option<std::fs::File>,
    /// `/readyz` truth: journal replayed and the queues accepting. Flips
    /// false again on shutdown/crash.
    ready: Arc<AtomicBool>,
    /// The `/metrics` + health HTTP endpoint, when `obs.http` configured.
    obs_server: Mutex<Option<ObsServer>>,
    /// The daemon's own flight stream (`<flight_dir>/daemon.vfr`):
    /// lifecycle transitions, ack/settle edges and replay markers — the
    /// durable record `veloc postmortem` pairs into the crash story.
    flight: Option<Arc<FlightRecorder>>,
}

/// Take the daemon-home flock, retrying briefly: a crashed predecessor's
/// lock is held only by lingering connection handlers and releases within
/// moments of their sockets closing.
#[cfg(unix)]
fn lock_daemon_dir(dir: &Path) -> Result<Option<std::fs::File>> {
    use std::os::unix::io::AsRawFd;
    let path = dir.join("daemon.lock");
    let f = std::fs::File::create(&path)
        .with_context(|| format!("create {}", path.display()))?;
    let deadline = std::time::Instant::now() + Duration::from_secs(2);
    loop {
        let rc = unsafe { libc::flock(f.as_raw_fd(), libc::LOCK_EX | libc::LOCK_NB) };
        if rc == 0 {
            return Ok(Some(f));
        }
        if std::time::Instant::now() >= deadline {
            bail!(
                "daemon home {} is owned by a live daemon (flock on {} busy); \
                 running two daemons over one journal would corrupt it",
                dir.display(),
                path.display()
            );
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[cfg(not(unix))]
fn lock_daemon_dir(_dir: &Path) -> Result<Option<std::fs::File>> {
    Ok(None)
}

/// Owner-only permissions on a daemon-owned directory (best effort; the
/// wire protocol is unauthenticated, so filesystem permissions *are* the
/// access control for both the socket and the payload bytes).
fn harden_dir(dir: &Path) {
    #[cfg(unix)]
    {
        use std::os::unix::fs::PermissionsExt;
        let _ = std::fs::set_permissions(dir, std::fs::Permissions::from_mode(0o700));
    }
    #[cfg(not(unix))]
    let _ = dir;
}

impl BackendDaemon {
    /// Build and start a daemon from a full runtime configuration (its
    /// `backend` section configures the daemon itself). Replays the
    /// journal before accepting new work.
    pub fn start(config: VelocConfig) -> Result<Arc<BackendDaemon>> {
        Self::start_with_hooks(config, SimHooks::default())
    }

    /// [`BackendDaemon::start`] with fault-injection instrumentation (the
    /// backend-crash scenarios pass a shared fabric through
    /// [`SimHooks::fabric`] so storage survives the simulated crash).
    pub fn start_with_hooks(
        config: VelocConfig,
        hooks: SimHooks,
    ) -> Result<Arc<BackendDaemon>> {
        let cfg = config.backend.clone();
        cfg.validate()?;
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("create daemon dir {}", cfg.dir.display()))?;
        // The home dir holds checkpoint payloads and the socket: owner-
        // only, so other local users can neither read jobs' bytes nor
        // reach the (unauthenticated) wire protocol.
        harden_dir(&cfg.dir);
        // Single-instance guard before any journal/staging mutation.
        let dir_lock = lock_daemon_dir(&cfg.dir)?;
        let staging = cfg.dir.join("staging");
        std::fs::create_dir_all(&staging)?;
        harden_dir(&staging);
        // Clients resolve staged file names against this path, possibly
        // from another working directory: hand out the canonical form.
        let staging = std::fs::canonicalize(&staging)?;
        // No client is connected yet, so anything still in staging/ is an
        // orphan from a died-mid-handoff client or a rejected submit of a
        // previous incarnation: sweep it.
        if let Ok(entries) = std::fs::read_dir(&staging) {
            for e in entries.flatten() {
                let _ = std::fs::remove_file(e.path());
            }
        }

        let obs_http = config.obs.http.clone();
        let flight_dir = config.obs.flight_dir.clone();
        let flight_max = config.obs.flight_max_bytes;
        let runtime = VelocRuntime::new_with_hooks(config, hooks)?;
        let metrics = Arc::clone(runtime.metrics());
        let flight = match &flight_dir {
            Some(dir) => Some(FlightRecorder::open(dir, "daemon", flight_max)?),
            None => None,
        };
        if let Some(f) = &flight {
            f.event("daemon.start", &[("dir", &cfg.dir.display().to_string())]);
        }
        let (journal, pending) = Journal::open(&cfg.dir.join("journal"), cfg.fsync)?;
        let journal = Arc::new(journal);
        let queue = FairQueue::new(cfg.queue_depth, Some(Arc::clone(&metrics)));
        queue.set_signals(Arc::clone(runtime.signals()));

        // Cold start with pending work: merge whatever lineage the previous
        // incarnation persisted *before* re-running the pipeline, so the
        // replay's own lineage writes extend the history instead of
        // replacing it with only the replayed versions.
        let mut seen_names: BTreeSet<&str> = BTreeSet::new();
        for e in &pending {
            if seen_names.insert(e.name.as_str()) {
                let _ = runtime.reload_lineage(&e.name);
            }
        }
        // Journal replay: everything acked before the crash re-enters the
        // queue (bypassing admission — those acks already happened) and
        // resumes its flush from the durable payload copy.
        for e in &pending {
            queue.admit_replay(&e.job);
            queue.push(Submission {
                id: e.id,
                job: e.job.clone(),
                rank: e.rank,
                name: e.name.clone(),
                version: e.version,
                payload: e.payload.clone(),
                bytes: None,
                queued_at: std::time::Instant::now(),
            });
            metrics.incr("backend.journal.replayed", 1);
            if let Some(f) = &flight {
                f.event(
                    "journal.replayed",
                    &[
                        ("id", &e.id.to_string()),
                        ("job", &e.job),
                        // "ckpt", not "name": a "name" label would shadow
                        // the event's own name in the frame body.
                        ("ckpt", &e.name),
                        ("version", &e.version.to_string()),
                    ],
                );
            }
        }

        let daemon = Arc::new(BackendDaemon {
            cfg,
            runtime,
            journal,
            queue,
            watches: Arc::new(Mutex::new(Vec::new())),
            stop: Arc::new(AtomicBool::new(false)),
            serve_stop: AtomicBool::new(false),
            dispatch_paused: Arc::new(AtomicBool::new(false)),
            dispatcher: Mutex::new(None),
            settler: Mutex::new(None),
            jobs: Mutex::new(BTreeSet::new()),
            staging,
            restore_seq: std::sync::atomic::AtomicU64::new(0),
            _dir_lock: dir_lock,
            ready: Arc::new(AtomicBool::new(false)),
            obs_server: Mutex::new(None),
            flight,
        });
        if let Some(bind) = obs_http {
            let state = ObsState {
                metrics: Arc::clone(daemon.runtime.metrics()),
                ready: Arc::clone(&daemon.ready),
            };
            *daemon.obs_server.lock().unwrap() = Some(ObsServer::start(&bind, state)?);
        }
        daemon.spawn_dispatcher();
        daemon.spawn_settler();
        // Journal replayed, queues accepting, workers live: ready.
        daemon.ready.store(true, Ordering::SeqCst);
        if let Some(f) = &daemon.flight {
            f.event("daemon.ready", &[("replayed", &pending.len().to_string())]);
            f.flush();
        }
        Ok(daemon)
    }

    fn spawn_dispatcher(self: &Arc<Self>) {
        let runtime = Arc::clone(&self.runtime);
        let journal = Arc::clone(&self.journal);
        let queue = Arc::clone(&self.queue);
        let watches = Arc::clone(&self.watches);
        let stop = Arc::clone(&self.stop);
        let paused = Arc::clone(&self.dispatch_paused);
        let handle = std::thread::Builder::new()
            .name("veloc-dispatch".to_string())
            .spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    if paused.load(Ordering::SeqCst) {
                        std::thread::sleep(Duration::from_millis(5));
                        continue;
                    }
                    let Some(sub) = queue.pop(Duration::from_millis(25)) else {
                        continue;
                    };
                    dispatch_one(&runtime, &journal, &queue, &watches, sub);
                }
            })
            .expect("spawn dispatcher");
        *self.dispatcher.lock().unwrap() = Some(handle);
    }

    /// One poller multiplexes settlement for every outstanding
    /// submission: peek the engine tracker, append the journal `end`
    /// record on a terminal status, release the admission slot.
    fn spawn_settler(self: &Arc<Self>) {
        let runtime = Arc::clone(&self.runtime);
        let journal = Arc::clone(&self.journal);
        let queue = Arc::clone(&self.queue);
        let watches = Arc::clone(&self.watches);
        let stop = Arc::clone(&self.stop);
        let flight = self.flight.clone();
        let handle = std::thread::Builder::new()
            .name("veloc-settle".to_string())
            .spawn(move || {
                let metrics = Arc::clone(runtime.metrics());
                while !stop.load(Ordering::SeqCst) {
                    let mut settled: Vec<(Watch, Option<String>)> = Vec::new();
                    {
                        let mut w = watches.lock().unwrap();
                        w.retain(|x| {
                            match runtime.engine(x.rank).status(x.rank, &x.name, x.version)
                            {
                                Some(CkptStatus::Done(_)) => {
                                    settled.push((x.clone(), None));
                                    false
                                }
                                Some(CkptStatus::Failed(msg)) => {
                                    settled.push((x.clone(), Some(msg)));
                                    false
                                }
                                _ => true,
                            }
                        });
                    }
                    let any_settled = !settled.is_empty();
                    for (x, failure) in settled {
                        runtime.tracer().close(x.span);
                        let ok = failure.is_none();
                        match failure {
                            None => {
                                let _ = journal.settle(x.id, true);
                                metrics.incr("backend.settled", 1);
                                metrics.incr_with(
                                    "backend.settled",
                                    &[("job", x.job.as_str())],
                                    1,
                                );
                            }
                            Some(msg) => {
                                eprintln!(
                                    "veloc backend: {} v{} rank {} failed: {msg}",
                                    x.name, x.version, x.rank
                                );
                                let _ = journal.settle(x.id, false);
                                metrics.incr("backend.failed", 1);
                            }
                        }
                        queue.settled(&x.job);
                        if let Some(f) = &flight {
                            f.event(
                                "backend.settle",
                                &[
                                    ("id", &x.id.to_string()),
                                    ("job", &x.job),
                                    ("version", &x.version.to_string()),
                                    ("ok", if ok { "true" } else { "false" }),
                                ],
                            );
                        }
                    }
                    if any_settled {
                        // Settlement activity paces the durable trail:
                        // span-loss gauge, signals snapshot, fsync.
                        metrics.set("obs.spans.dropped", runtime.tracer().dropped());
                        if let Some(f) = &flight {
                            f.signals(&runtime.signals().snapshot());
                            f.flush();
                        }
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            })
            .expect("spawn settler");
        *self.settler.lock().unwrap() = Some(handle);
    }

    /// The backend daemon's configuration.
    pub fn backend_config(&self) -> &BackendConfig {
        &self.cfg
    }

    /// The hosted runtime (metrics, recovery, fabric).
    pub fn runtime(&self) -> &Arc<VelocRuntime> {
        &self.runtime
    }

    /// The daemon's own flight stream, when `obs.flight_dir` is set.
    pub fn flight(&self) -> Option<&Arc<FlightRecorder>> {
        self.flight.as_ref()
    }

    /// Where clients stage large payloads for handoff (canonicalized).
    pub fn staging_dir(&self) -> &Path {
        &self.staging
    }

    /// Bound address of the observability HTTP endpoint, when enabled
    /// (resolves `:0` binds to the actual port for tests and the CLI).
    pub fn obs_addr(&self) -> Option<std::net::SocketAddr> {
        self.obs_server.lock().unwrap().as_ref().map(|s| s.addr())
    }

    /// Register a job/rank pair. Returns the rank's node id. Idempotent;
    /// submits require a prior registration of their job.
    pub fn register(&self, job: &str, rank: usize) -> Result<usize> {
        if !valid_job_id(job) {
            bail!("invalid job id {job:?} (use [A-Za-z0-9._-], no '@')");
        }
        let world = self.runtime.topology().world_size();
        if rank >= world {
            bail!("rank {rank} out of range (world size {world})");
        }
        self.jobs.lock().unwrap().insert(job.to_string());
        // Opportunistic hygiene on a rare op: reclaim staged files whose
        // client died mid-handoff (a live handoff spans seconds; anything
        // this old is garbage), so a long-running daemon does not fill
        // the fast tier between restarts.
        self.sweep_stale_staging(Duration::from_secs(600));
        Ok(self.runtime.topology().node_of(rank))
    }

    fn sweep_stale_staging(&self, max_age: Duration) {
        let Ok(entries) = std::fs::read_dir(&self.staging) else {
            return;
        };
        for e in entries.flatten() {
            let stale = e
                .metadata()
                .and_then(|m| m.modified())
                .ok()
                .and_then(|t| t.elapsed().ok())
                .map(|age| age > max_age)
                .unwrap_or(false);
            if stale {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }

    /// Admission probe: would a submit for `job` be admitted right now?
    /// No slot is reserved — large-payload clients ask before paying the
    /// staging write, and the race (the window filling between probe and
    /// submit) degrades to an ordinary rejected submit.
    pub fn admission_room(&self, job: &str) -> Result<bool> {
        if !self.jobs.lock().unwrap().contains(job) {
            bail!("job {job:?} is not registered");
        }
        Ok(self.queue.unsettled_of(job) < self.cfg.queue_depth)
    }

    /// Submit one encoded checkpoint container for `(job, rank, name,
    /// version)`. On `Acked` the checkpoint is durably journaled; `Busy`
    /// is the admission-control rejection.
    pub fn submit(
        &self,
        job: &str,
        rank: usize,
        name: &str,
        version: u64,
        payload: Payload,
    ) -> Result<SubmitAck> {
        // The daemon owns a staged handoff the moment the frame arrives:
        // rejected submits must not leak the file in staging/.
        let discard_staged = |payload: &Payload| {
            if let Payload::Staged(path) = payload {
                let _ = std::fs::remove_file(path);
            }
        };
        if !self.jobs.lock().unwrap().contains(job) {
            discard_staged(&payload);
            bail!("job {job:?} is not registered");
        }
        let world = self.runtime.topology().world_size();
        if rank >= world {
            discard_staged(&payload);
            bail!("rank {rank} out of range (world size {world})");
        }
        if self.stop.load(Ordering::SeqCst) {
            discard_staged(&payload);
            bail!("backend daemon is shutting down");
        }
        if let Err(depth) = self.queue.try_admit(job) {
            discard_staged(&payload);
            if let Some(f) = &self.flight {
                f.event(
                    "backend.busy",
                    &[("job", job), ("unsettled", &depth.to_string())],
                );
            }
            // The depth try_admit observed at rejection time — not a
            // racy re-read that a concurrent settle could undercut below
            // the documented bound.
            return Ok(SubmitAck::Busy { unsettled: depth });
        }
        let scoped = scoped_name(job, name);
        // Inline submits keep the bytes for the dispatcher, so the hot
        // path decodes from memory instead of re-reading what the journal
        // just wrote; replay and staged handoffs use the durable file.
        let mut kept: Option<Arc<Vec<u8>>> = None;
        let journaled = match payload {
            Payload::Inline(bytes) => {
                let r = self.journal.begin(job, rank, &scoped, version, &bytes);
                kept = Some(bytes);
                r
            }
            Payload::Staged(path) => {
                self.journal.begin_staged(job, rank, &scoped, version, &path)
            }
        };
        let entry = match journaled {
            Ok(e) => e,
            Err(e) => {
                // Nothing was acked: release the admission slot.
                self.queue.settled(job);
                return Err(e);
            }
        };
        // The ack edge is durable *before* the client learns of it: a
        // crash after this line leaves both the journal entry and the
        // flight-stream ack for the post-mortem pairing.
        if let Some(f) = &self.flight {
            f.event(
                "backend.ack",
                &[
                    ("id", &entry.id.to_string()),
                    ("job", job),
                    ("rank", &rank.to_string()),
                    // "ckpt", not "name": a "name" label would shadow the
                    // event's own name and break the post-mortem pairing.
                    ("ckpt", &scoped),
                    ("version", &version.to_string()),
                ],
            );
            f.flush();
        }
        self.queue.push(Submission {
            id: entry.id,
            job: job.to_string(),
            rank,
            name: scoped,
            version,
            payload: entry.payload,
            bytes: kept,
            queued_at: std::time::Instant::now(),
        });
        self.runtime.metrics().incr("backend.submits", 1);
        Ok(SubmitAck::Acked)
    }

    /// Wait (or poll, with a zero timeout) for a submitted checkpoint's
    /// status. A command that is journaled but not yet dispatched reports
    /// [`CkptStatus::InFlight`] on polls.
    pub fn wait(
        &self,
        job: &str,
        rank: usize,
        name: &str,
        version: u64,
        timeout: Duration,
    ) -> Result<CkptStatus> {
        let world = self.runtime.topology().world_size();
        if rank >= world {
            bail!("rank {rank} out of range (world size {world})");
        }
        let scoped = scoped_name(job, name);
        if timeout.is_zero() {
            return Ok(self
                .runtime
                .engine(rank)
                .status(rank, &scoped, version)
                .unwrap_or(CkptStatus::InFlight));
        }
        self.runtime
            .engine(rank)
            .wait(rank, &scoped, version, timeout)
    }

    /// Restart query: restore `version` (or the freshest restorable
    /// version) of one job's checkpoint for `rank`. Cold daemons reload
    /// the persisted lineage before probing, so restores work across
    /// daemon restarts even for checkpoints the journal already settled.
    pub fn restore(
        &self,
        job: &str,
        rank: usize,
        name: &str,
        version: Option<u64>,
    ) -> Result<Option<Restored>> {
        let world = self.runtime.topology().world_size();
        if rank >= world {
            bail!("rank {rank} out of range (world size {world})");
        }
        let scoped = scoped_name(job, name);
        if self.runtime.env().registry.versions(&scoped).is_empty() {
            // Cold start: merge whatever lineage a previous incarnation
            // persisted on the shared tiers. Absence is not an error —
            // the job may simply never have checkpointed.
            let _ = self.runtime.reload_lineage(&scoped);
        }
        let engine = self.runtime.engine(rank);
        let restored = match version {
            Some(v) => self
                .runtime
                .recovery()
                .restore_version(engine, &scoped, rank, v)?,
            None => self.runtime.recovery().restore_latest(engine, &scoped, rank)?,
        };
        if restored.is_some() {
            self.runtime.metrics().incr("backend.restores", 1);
        }
        Ok(restored)
    }

    /// Pause/resume dispatching (maintenance lever: submits keep being
    /// acked and journaled, nothing enters the pipeline until resumed).
    pub fn pause_dispatch(&self, paused: bool) {
        self.dispatch_paused.store(paused, Ordering::SeqCst);
    }

    /// Wait until every queued submission was handed to the pipeline
    /// (dispatched — not necessarily settled). The backend-crash
    /// scenarios use it to land the crash deterministically *after* the
    /// blocking prefixes and acks, mid-drain.
    pub fn wait_dispatched(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while self.queue.queued_total() > 0 {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        true
    }

    /// Wait until every accepted submission settled (bounded by
    /// `timeout`), then drain the runtime's own buffers. Returns whether
    /// full settlement was reached.
    pub fn drain(&self, timeout: Duration) -> bool {
        let idle = self.queue.wait_idle(timeout);
        self.runtime.drain();
        idle
    }

    fn join_workers(&self) {
        if let Some(h) = self.dispatcher.lock().unwrap().take() {
            let _ = h.join();
        }
        if let Some(h) = self.settler.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    /// Graceful stop: drain, then stop the dispatcher and the settle
    /// poller. Returns whether the drain settled everything within
    /// `timeout`.
    pub fn shutdown(&self, timeout: Duration) -> bool {
        self.ready.store(false, Ordering::SeqCst);
        let idle = self.drain(timeout);
        self.stop.store(true, Ordering::SeqCst);
        self.join_workers();
        if let Some(mut s) = self.obs_server.lock().unwrap().take() {
            s.stop();
        }
        if let Some(f) = &self.flight {
            f.event(
                "daemon.shutdown",
                &[("idle", if idle { "true" } else { "false" })],
            );
            f.signals(&self.runtime.signals().snapshot());
            f.flush();
        }
        idle
    }

    /// Simulated daemon death (the `backend-crash` injection point):
    /// queued submissions are dropped, in-flight async tails are killed
    /// mid-drain, nothing further settles and the journal keeps every
    /// acked-but-unsettled record. The only thing that survives is what
    /// the contract requires: durable storage and the journal.
    pub fn crash(&self) {
        self.ready.store(false, Ordering::SeqCst);
        self.stop.store(true, Ordering::SeqCst);
        self.queue.clear_queued();
        // The settle poller exits on `stop` without settling anything
        // further; outstanding watches are abandoned with their journal
        // entries pending — exactly what the replay needs.
        self.join_workers();
        // In-flight and queued pipeline tails die mid-drain.
        self.runtime.backend().kill();
        // The death marker and the last signals snapshot go out *after*
        // the workers stopped — everything the stream holds past this
        // point is what the post-mortem must explain.
        self.runtime.signals().note_failure();
        if let Some(f) = &self.flight {
            f.event("daemon.crash", &[]);
            f.signals(&self.runtime.signals().snapshot());
            f.flush();
        }
    }

    /// Build an ordinary [`VelocClient`] wired straight into this daemon
    /// (no socket): the deterministic path the scenario engine and the
    /// benchmarks use. `wait_timeout` bounds `checkpoint_wait`.
    pub fn client(
        self: &Arc<Self>,
        job: &str,
        rank: usize,
        wait_timeout: Duration,
    ) -> Result<VelocClient> {
        self.register(job, rank)?;
        Ok(VelocClient::with_transport(
            Arc::new(DaemonTransport {
                daemon: Arc::clone(self),
                job: job.to_string(),
                wait_timeout,
            }),
            rank,
        ))
    }
}

impl Drop for BackendDaemon {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.join_workers();
    }
}

/// Decode, submit and register one queued submission for settlement
/// watching. Runs on the dispatcher thread; the settle poller does the
/// bookkeeping.
fn dispatch_one(
    runtime: &Arc<VelocRuntime>,
    journal: &Arc<Journal>,
    queue: &Arc<FairQueue>,
    watches: &Arc<Mutex<Vec<Watch>>>,
    sub: Submission,
) {
    // Same-command dedup: the engine tracker keys by (rank, name,
    // version), so a resubmission of a still-settling command must wait —
    // otherwise the first tail's terminal status would settle the second
    // entry's journal record while its flushes are still running.
    {
        let held = watches.lock().unwrap().iter().any(|x| {
            x.rank == sub.rank && x.version == sub.version && x.name == sub.name
        });
        if held {
            queue.push(sub);
            // The requeued item is immediately poppable again: breathe so
            // this does not busy-spin while the first settles.
            std::thread::sleep(Duration::from_millis(2));
            return;
        }
    }
    let metrics = Arc::clone(runtime.metrics());
    let world = runtime.topology().world_size();
    if sub.rank >= world {
        // No engine exists for this rank, so the tracker cannot carry the
        // failure; the journal settle + stderr line are all there is.
        metrics.incr("backend.failed", 1);
        eprintln!(
            "veloc backend: {} v{} rank {}: rank out of range (world size {world})",
            sub.name, sub.version, sub.rank
        );
        let _ = journal.settle(sub.id, false);
        queue.settled(&sub.job);
        return;
    }
    let fail = |why: &str| {
        metrics.incr("backend.failed", 1);
        eprintln!(
            "veloc backend: {} v{} rank {}: {why}",
            sub.name, sub.version, sub.rank
        );
        // Surface the terminal failure to waiters (otherwise a client
        // blocks its whole budget into a TimedOut for a checkpoint the
        // daemon just discarded).
        runtime.engine(sub.rank).reject(
            sub.rank,
            &sub.name,
            sub.version,
            format!("backend dispatch failed: {why}"),
        );
        let _ = journal.settle(sub.id, false);
        queue.settled(&sub.job);
    };
    // Inline payloads wrap the submit's allocation; staged payloads read
    // the journal file once. Either way `bytes` is the same shared slice
    // the pipeline context below captures — the IPC boundary re-clone is
    // gone.
    let read: std::io::Result<crate::util::bufpool::Bytes> = match &sub.bytes {
        Some(b) => Ok(crate::util::bufpool::Bytes::from_arc(Arc::clone(b))),
        None => std::fs::read(&sub.payload).map(crate::util::bufpool::Bytes::from),
    };
    let bytes = match read {
        Ok(b) => b,
        Err(e) => {
            // A read error on an *acked* payload may be transient (flaky
            // mount, ENOSPC recovery). Deleting the only durable copy
            // would turn it permanent: leave the journal entry pending —
            // the next daemon start replays it — and only release the
            // admission slot.
            metrics.incr("backend.dispatch.deferred", 1);
            eprintln!(
                "veloc backend: {} v{} rank {}: payload unreadable, left \
                 journaled for replay: {e}",
                sub.name, sub.version, sub.rank
            );
            queue.settled(&sub.job);
            return;
        }
    };
    let ckpt = match Checkpoint::decode(&bytes) {
        Ok(c) => c,
        // A CRC/decode failure is permanent — no replay can fix it.
        Err(e) => {
            fail(&format!("payload corrupt: {e:#}"));
            return;
        }
    };
    let node = runtime.topology().node_of(sub.rank);
    let mut ctx =
        CkptContext::from_encoded(&sub.name, sub.rank, node, sub.version, ckpt, bytes);
    let tracer = runtime.tracer();
    let mut settle_span = SpanId::NONE;
    if tracer.is_enabled() {
        let wave = tracer.wave_root(sub.version);
        let vs = sub.version.to_string();
        let rs = sub.rank.to_string();
        let cmd = tracer.open(
            "dispatch",
            wave,
            &[
                ("job", sub.job.as_str()),
                ("rank", rs.as_str()),
                ("name", sub.name.as_str()),
                ("version", vs.as_str()),
            ],
            sub.rank as u64,
        );
        // The settle span outlives the pipeline command: parent it on the
        // wave root, which only closes once the daemon drains.
        settle_span =
            tracer.open("settle", wave, &[("job", sub.job.as_str())], sub.rank as u64);
        ctx.obs = ObsHandle {
            tracer: Some(Arc::clone(tracer)),
            metrics: Some(Arc::clone(&metrics)),
            parent: cmd,
        };
    } else {
        ctx.obs.metrics = Some(Arc::clone(&metrics));
    }
    if let Err(e) = runtime.engine(sub.rank).submit(ctx) {
        tracer.close(settle_span);
        fail(&format!("pipeline rejected: {e:#}"));
        return;
    }
    metrics.incr_with("backend.dispatched", &[("job", sub.job.as_str())], 1);
    watches.lock().unwrap().push(Watch {
        id: sub.id,
        job: sub.job,
        rank: sub.rank,
        name: sub.name,
        version: sub.version,
        span: settle_span,
    });
}

/// The in-process [`Transport`] over a daemon instance: identical
/// semantics to the socket path minus the socket (fsync-before-ack,
/// admission control, fair dispatch). Used by the scenario engine and
/// `ipc_bench`; applications normally use
/// [`SocketTransport`](crate::backend::SocketTransport).
pub struct DaemonTransport {
    daemon: Arc<BackendDaemon>,
    job: String,
    wait_timeout: Duration,
}

impl Transport for DaemonTransport {
    fn submit(
        &self,
        rank: usize,
        name: &str,
        version: u64,
        ckpt: Checkpoint,
        _started: std::time::Instant,
    ) -> Result<()> {
        let bytes = Arc::new(ckpt.encode());
        match self
            .daemon
            .submit(&self.job, rank, name, version, Payload::Inline(bytes))?
        {
            SubmitAck::Acked => Ok(()),
            SubmitAck::Busy { unsettled } => Err(anyhow::Error::new(Backpressure {
                job: self.job.clone(),
                depth: unsettled,
            })),
        }
    }

    fn wait(&self, rank: usize, name: &str, version: u64) -> Result<CkptStatus> {
        self.daemon
            .wait(&self.job, rank, name, version, self.wait_timeout)
    }

    fn restore(
        &self,
        rank: usize,
        name: &str,
        version: Option<u64>,
    ) -> Result<Option<Restored>> {
        self.daemon.restore(&self.job, rank, name, version)
    }
}

// ---------------------------------------------------------------------------
// Socket front-end (Unix domain sockets).
// ---------------------------------------------------------------------------

#[cfg(unix)]
impl BackendDaemon {
    /// Bind the configured Unix socket and serve clients until a
    /// `shutdown` request arrives; then drain gracefully. Each connection
    /// gets a handler thread; a stale socket file is replaced.
    pub fn serve(self: &Arc<Self>) -> Result<()> {
        use std::os::unix::net::UnixListener;
        let path = self.cfg.socket_path();
        if path.exists() {
            std::fs::remove_file(&path)
                .with_context(|| format!("remove stale socket {}", path.display()))?;
        }
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("bind {}", path.display()))?;
        listener.set_nonblocking(true)?;
        while !self.serve_stop.load(Ordering::SeqCst) && !self.stop.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    if let Err(e) = stream.set_nonblocking(false) {
                        eprintln!("veloc daemon: accepted connection unusable: {e}");
                        continue;
                    }
                    let daemon = Arc::clone(self);
                    // Handlers detach: they exit when their peer hangs up
                    // (read_frame errors) or after answering post-shutdown.
                    std::thread::spawn(move || daemon.handle_conn(stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => {
                    // Transient accept errors (EMFILE under load, a peer
                    // resetting mid-handshake) must not take the backend
                    // away from every connected job: log and keep serving.
                    eprintln!("veloc daemon: accept on {}: {e}", path.display());
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        // Graceful exits drain; a crashed daemon (stop already set) must
        // not wait on work that can no longer settle.
        if !self.stop.load(Ordering::SeqCst) {
            self.shutdown(Duration::from_secs(60));
        }
        let _ = std::fs::remove_file(&path);
        Ok(())
    }

    fn handle_conn(self: Arc<Self>, mut stream: std::os::unix::net::UnixStream) {
        use crate::backend::wire;
        use crate::util::json::Json;
        let limits = wire::FrameLimits {
            max_body: self.cfg.max_frame_body,
            ..Default::default()
        };
        loop {
            let (hdr, body) = match wire::read_frame_limited(&mut stream, limits) {
                Ok(f) => f,
                // Peer disconnected, or sent a frame the limits reject —
                // either way the connection is unusable; drop it.
                Err(_) => return,
            };
            let (resp, rbody) = match self.handle_op(&hdr, body) {
                Ok(r) => r,
                Err(e) => (
                    Json::obj().set("ok", false).set("err", format!("{e:#}")),
                    Vec::new(),
                ),
            };
            if wire::write_frame(&mut stream, &resp, &rbody).is_err() {
                return;
            }
            if self.serve_stop.load(Ordering::SeqCst) {
                return;
            }
        }
    }

    fn handle_op(
        &self,
        hdr: &crate::util::json::Json,
        body: Vec<u8>,
    ) -> Result<(crate::util::json::Json, Vec<u8>)> {
        use crate::backend::wire;
        use crate::util::json::Json;
        // Required fields bail instead of defaulting: a malformed frame
        // must never silently act on rank 0 / version 0 / job "".
        let job = || -> Result<&str> {
            match hdr.get("job").and_then(Json::as_str) {
                Some(j) if !j.is_empty() => Ok(j),
                _ => Err(anyhow!("frame missing \"job\"")),
            }
        };
        let rank = || {
            hdr.get("rank")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("frame missing numeric \"rank\""))
        };
        let version = || {
            hdr.get("version")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("frame missing numeric \"version\""))
        };
        let name = || {
            match hdr.get("name").and_then(Json::as_str) {
                Some(n) if !n.is_empty() => Ok(n),
                _ => Err(anyhow!("frame missing \"name\"")),
            }
        };
        match hdr.str_or("op", "") {
            "register" => {
                let node = self.register(job()?, rank()?)?;
                Ok((
                    Json::obj()
                        .set("ok", true)
                        .set("node", node)
                        .set("staging", self.staging.to_string_lossy().as_ref())
                        .set("inline_max", self.cfg.inline_max),
                    Vec::new(),
                ))
            }
            "submit" => {
                // Admission probe: no payload, no reservation — answers
                // "would a submit be admitted right now?" so clients can
                // skip staging a large payload that would be rejected.
                if hdr.bool_or("probe", false) {
                    return Ok(if self.admission_room(job()?)? {
                        (Json::obj().set("ok", true).set("admit", true), Vec::new())
                    } else {
                        (
                            Json::obj()
                                .set("ok", true)
                                .set("busy", true)
                                .set("depth", self.queue.unsettled_of(job()?)),
                            Vec::new(),
                        )
                    });
                }
                // Resolve the staged handoff first: the daemon owns that
                // file from the moment the frame names it, so *every*
                // early exit below must discard it (submit itself
                // discards on its own rejections).
                let staged: Option<PathBuf> = match hdr.get("staged").and_then(Json::as_str)
                {
                    Some(file) => {
                        // A bare file name inside the staging dir — never
                        // a path. With separators rejected, only the
                        // exact dot components could still escape (a name
                        // merely *containing* ".." is legal: job ids may
                        // contain dots).
                        if file.is_empty()
                            || file.contains('/')
                            || file.contains('\\')
                            || file == "."
                            || file == ".."
                        {
                            bail!("invalid staged file name {file:?}");
                        }
                        Some(self.staging.join(file))
                    }
                    None => None,
                };
                let fields = job()
                    .and_then(|j| rank().map(|r| (j, r)))
                    .and_then(|(j, r)| name().map(|n| (j, r, n)))
                    .and_then(|(j, r, n)| version().map(|v| (j, r, n, v)));
                let (job, rank, name, version) = match fields {
                    Ok(f) => f,
                    Err(e) => {
                        if let Some(p) = &staged {
                            let _ = std::fs::remove_file(p);
                        }
                        return Err(e);
                    }
                };
                let payload = match &staged {
                    Some(p) => Payload::Staged(p.clone()),
                    // The handler owns the frame body: hand the existing
                    // allocation straight through, no copy.
                    None => Payload::Inline(Arc::new(body)),
                };
                match self.submit(job, rank, name, version, payload)? {
                    SubmitAck::Acked => {
                        Ok((Json::obj().set("ok", true).set("acked", true), Vec::new()))
                    }
                    SubmitAck::Busy { unsettled } => Ok((
                        Json::obj()
                            .set("ok", true)
                            .set("busy", true)
                            .set("depth", unsettled),
                        Vec::new(),
                    )),
                }
            }
            "wait" => {
                let ms = hdr.get("timeout_ms").and_then(Json::as_u64).unwrap_or(0);
                // Cap per-request waits so a client cannot pin a handler
                // thread forever; `SocketTransport::wait` re-issues
                // chunked waits to spend a longer budget.
                let timeout = Duration::from_millis(ms.min(600_000));
                let st = self.wait(job()?, rank()?, name()?, version()?, timeout)?;
                Ok((wire::status_to_json(&st).set("ok", true), Vec::new()))
            }
            "restart" => {
                // The version is genuinely optional here: absent means
                // "freshest restorable".
                let version = hdr.get("version").and_then(Json::as_u64);
                match self.restore(job()?, rank()?, name()?, version)? {
                    Some(r) => {
                        let header = Json::obj()
                            .set("ok", true)
                            .set("found", true)
                            .set("version", r.version)
                            .set("level", r.level as u64);
                        let bytes = r.ckpt.encode();
                        // Containers too large for one response frame
                        // travel back through the staging dir (mirror of
                        // the submit-side handoff); the client reads and
                        // deletes the file.
                        if bytes.len() > wire::MAX_BODY {
                            let file = format!(
                                "restore.{}.vckp",
                                self.restore_seq
                                    .fetch_add(1, Ordering::SeqCst)
                            );
                            std::fs::write(self.staging.join(&file), &bytes)?;
                            Ok((header.set("staged", file.as_str()), Vec::new()))
                        } else {
                            Ok((header, bytes))
                        }
                    }
                    None => Ok((
                        Json::obj().set("ok", true).set("found", false),
                        Vec::new(),
                    )),
                }
            }
            "stats" => Ok((
                Json::obj()
                    .set("ok", true)
                    .set("metrics", self.runtime.metrics().to_json()),
                Vec::new(),
            )),
            "shutdown" => {
                self.serve_stop.store(true, Ordering::SeqCst);
                Ok((Json::obj().set("ok", true), Vec::new()))
            }
            other => bail!("unknown op {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    static DIRS: AtomicU64 = AtomicU64::new(0);

    fn daemon_config(tag: &str) -> VelocConfig {
        let mut cfg = VelocConfig::default().with_nodes(2, 1);
        cfg.stack.erasure_group = 0;
        cfg.backend.dir = std::env::temp_dir().join(format!(
            "veloc-daemon-test-{tag}-{}-{}",
            std::process::id(),
            DIRS.fetch_add(1, Ordering::SeqCst)
        ));
        cfg.backend.queue_depth = 8;
        cfg
    }

    fn cleanup(cfg: &VelocConfig) {
        let _ = std::fs::remove_dir_all(&cfg.backend.dir);
    }

    #[test]
    fn daemon_roundtrip_checkpoint_and_restore() {
        let cfg = daemon_config("rt");
        let daemon = BackendDaemon::start(cfg.clone()).unwrap();
        let client = daemon.client("jobA", 0, Duration::from_secs(30)).unwrap();
        let h = client.mem_protect(0, vec![42u8; 8 << 10]);
        client.checkpoint("app", 1).unwrap();
        let st = client.checkpoint_wait("app", 1).unwrap();
        assert!(matches!(st, CkptStatus::Done(_)), "{st:?}");
        *h.lock().unwrap() = vec![0u8; 8 << 10];
        let info = client.restart("app").unwrap().expect("restore");
        assert_eq!(info.version, 1);
        assert_eq!(*h.lock().unwrap(), vec![42u8; 8 << 10]);
        assert!(daemon.drain(Duration::from_secs(10)));
        cleanup(&cfg);
    }

    #[test]
    fn unregistered_job_rejected() {
        let cfg = daemon_config("reg");
        let daemon = BackendDaemon::start(cfg.clone()).unwrap();
        let err = daemon
            .submit("ghost", 0, "app", 1, Payload::Inline(Arc::new(b"VCKP".to_vec())))
            .unwrap_err()
            .to_string();
        assert!(err.contains("not registered"), "{err}");
        assert!(daemon.register("bad job", 0).is_err());
        assert!(daemon.register("ok-job", 99).is_err());
        cleanup(&cfg);
    }

    #[test]
    fn two_jobs_never_collide_on_versions() {
        let cfg = daemon_config("collide");
        let daemon = BackendDaemon::start(cfg.clone()).unwrap();
        let a = daemon.client("jobA", 0, Duration::from_secs(30)).unwrap();
        let b = daemon.client("jobB", 0, Duration::from_secs(30)).unwrap();
        let ha = a.mem_protect(0, vec![0xAA; 4 << 10]);
        let hb = b.mem_protect(0, vec![0xBB; 4 << 10]);
        // Same rank, same name, same version — different jobs.
        a.checkpoint("app", 1).unwrap();
        b.checkpoint("app", 1).unwrap();
        assert!(matches!(a.checkpoint_wait("app", 1).unwrap(), CkptStatus::Done(_)));
        assert!(matches!(b.checkpoint_wait("app", 1).unwrap(), CkptStatus::Done(_)));
        *ha.lock().unwrap() = Vec::new();
        *hb.lock().unwrap() = Vec::new();
        a.restart_version("app", 1).unwrap().expect("job A restore");
        b.restart_version("app", 1).unwrap().expect("job B restore");
        assert_eq!(*ha.lock().unwrap(), vec![0xAA; 4 << 10]);
        assert_eq!(*hb.lock().unwrap(), vec![0xBB; 4 << 10]);
        cleanup(&cfg);
    }

    #[test]
    fn backpressure_is_typed_and_releases_on_settle() {
        let mut cfg = daemon_config("bp");
        cfg.backend.queue_depth = 2;
        let daemon = BackendDaemon::start(cfg.clone()).unwrap();
        let client = daemon.client("jobA", 0, Duration::from_secs(30)).unwrap();
        client.mem_protect(0, vec![1u8; 4 << 10]);
        // Stall the drain so nothing settles while we fill the window.
        daemon.runtime().backend().pause_background(true);
        client.checkpoint("app", 1).unwrap();
        client.checkpoint("app", 2).unwrap();
        let err = client.checkpoint("app", 3).unwrap_err();
        let bp = err
            .downcast_ref::<Backpressure>()
            .expect("typed backpressure");
        assert_eq!(bp.job, "jobA");
        assert!(daemon.runtime().metrics().counter("backend.rejected") >= 1);
        daemon.runtime().backend().pause_background(false);
        assert!(daemon.drain(Duration::from_secs(30)), "window drains");
        client.checkpoint("app", 3).unwrap();
        assert!(matches!(
            client.checkpoint_wait("app", 3).unwrap(),
            CkptStatus::Done(_)
        ));
        cleanup(&cfg);
    }

    #[test]
    fn crash_and_replay_settles_acked_checkpoints() {
        let cfg = daemon_config("crash");
        let fabric = Arc::new(
            crate::storage::StorageFabric::build(&cfg.fabric).unwrap(),
        );
        {
            let hooks = SimHooks {
                fabric: Some(Arc::clone(&fabric)),
                ..SimHooks::default()
            };
            let daemon = BackendDaemon::start_with_hooks(cfg.clone(), hooks).unwrap();
            let client = daemon.client("jobA", 0, Duration::from_secs(30)).unwrap();
            client.mem_protect(0, vec![7u8; 8 << 10]);
            // Hold the async tails: the submit is acked + journaled but
            // never settles before the crash.
            daemon.runtime().backend().pause_background(true);
            client.checkpoint("app", 1).unwrap();
            // Let the dispatcher pick it up (deterministic enough: poll).
            let deadline = std::time::Instant::now() + Duration::from_secs(10);
            while daemon.queue.queued_total() > 0 && std::time::Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(5));
            }
            daemon.crash();
        }
        // A fresh daemon over the same journal + storage replays and
        // settles the acked checkpoint.
        let hooks = SimHooks {
            fabric: Some(fabric),
            ..SimHooks::default()
        };
        let daemon = BackendDaemon::start_with_hooks(cfg.clone(), hooks).unwrap();
        assert!(
            daemon.runtime().metrics().counter("backend.journal.replayed") >= 1,
            "the acked checkpoint must replay"
        );
        assert!(daemon.drain(Duration::from_secs(30)));
        let client = daemon.client("jobA", 0, Duration::from_secs(30)).unwrap();
        let h = client.mem_protect(0, Vec::new());
        let info = client.restart_version("app", 1).unwrap().expect("restore");
        assert_eq!(info.version, 1);
        assert_eq!(*h.lock().unwrap(), vec![7u8; 8 << 10]);
        cleanup(&cfg);
    }
}
