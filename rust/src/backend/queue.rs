//! Per-job fair queuing and admission control for the backend daemon.
//!
//! Every job gets a FIFO of journaled-but-not-yet-dispatched submissions;
//! the dispatcher drains them round-robin, one submission per turn, so
//! concurrent jobs share the backend's drain bandwidth predictably (a
//! chatty job cannot starve a quiet one — it only lengthens its own
//! queue). Admission is bounded per job by the *unsettled* count (acked
//! but not yet settled across all levels): beyond `queue_depth` a submit
//! is rejected with [`Backpressure`](crate::backend::Backpressure)
//! instead of buffering without bound.
//!
//! Metrics (`backend.*`): `queue_depth{job=..}` gauge (unsettled count),
//! `rejected` counter, `fair.rr_picks` counter (dispatches made while at
//! least one *other* job also had work queued — the observable fair-share
//! signal), and the `backend.queue_wait` histogram (push-to-pop latency
//! per job — the drain-pacing distribution).

use crate::metrics::Metrics;
use crate::obs::signals::{SignalsBus, SIG_QUEUE_DEPTH, SIG_QUEUE_REJECTED};
use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// One journaled checkpoint waiting for dispatch into the pipeline.
#[derive(Clone, Debug)]
pub struct Submission {
    /// Journal id (settles the WAL entry once the pipeline finishes).
    pub id: u64,
    /// Owning job.
    pub job: String,
    /// Submitting rank.
    pub rank: usize,
    /// Daemon-scoped checkpoint name (`job@name`).
    pub name: String,
    /// Checkpoint version.
    pub version: u64,
    /// Durable payload container in the journal's payload store.
    pub payload: PathBuf,
    /// In-memory copy of the container, when the submit path still holds
    /// one (inline submits): spares the dispatcher a read-back of bytes
    /// that were just written. Journal replay and staged handoffs carry
    /// `None` and read the durable file.
    pub bytes: Option<Arc<Vec<u8>>>,
    /// When the submission entered the queue (queue-wait histogram).
    pub queued_at: Instant,
}

#[derive(Default)]
struct JobState {
    queued: VecDeque<Submission>,
    /// Acked-but-unsettled count (queued + dispatched-in-flight).
    unsettled: usize,
}

struct QState {
    jobs: BTreeMap<String, JobState>,
    /// Round-robin order (insertion order of first appearance).
    rr: Vec<String>,
    next: usize,
}

/// The bounded, fair multi-job submission queue.
pub struct FairQueue {
    depth: usize,
    state: Mutex<QState>,
    cv: Condvar,
    metrics: Option<Arc<Metrics>>,
    signals: OnceLock<Arc<SignalsBus>>,
    rejected: AtomicU64,
}

impl FairQueue {
    /// Build a queue with the given per-job admission depth.
    pub fn new(depth: usize, metrics: Option<Arc<Metrics>>) -> Arc<FairQueue> {
        Arc::new(FairQueue {
            depth,
            state: Mutex::new(QState {
                jobs: BTreeMap::new(),
                rr: Vec::new(),
                next: 0,
            }),
            cv: Condvar::new(),
            metrics,
            signals: OnceLock::new(),
            rejected: AtomicU64::new(0),
        })
    }

    /// Attach a signals bus: depth changes then also sample `queue.depth`
    /// (aggregate unsettled across jobs) and rejections `queue.rejected`
    /// (cumulative count). One-shot — later calls are ignored.
    pub fn set_signals(&self, bus: Arc<SignalsBus>) {
        let _ = self.signals.set(bus);
    }

    fn gauge(&self, job: &str, unsettled: usize) {
        if let Some(m) = &self.metrics {
            m.set_with("backend.queue_depth", &[("job", job)], unsettled as u64);
        }
    }

    /// Sample the aggregate unsettled depth into the signals bus. Called
    /// with the state lock held so a concurrent settle cannot interleave
    /// and record a stale depth as the latest point.
    fn sample_depth(&self, st: &QState) {
        if let Some(bus) = self.signals.get() {
            let depth: usize = st.jobs.values().map(|j| j.unsettled).sum();
            bus.sample(SIG_QUEUE_DEPTH, depth as f64);
        }
    }

    /// Reserve an admission slot for `job`. `Err(unsettled)` means the job
    /// is at its depth bound and the submit must be rejected (the caller
    /// has not journaled anything yet).
    pub fn try_admit(&self, job: &str) -> Result<(), usize> {
        let mut st = self.state.lock().unwrap();
        let js = st.jobs.entry(job.to_string()).or_default();
        if js.unsettled >= self.depth {
            let depth = js.unsettled;
            drop(st);
            if let Some(m) = &self.metrics {
                m.incr("backend.rejected", 1);
            }
            let total = self.rejected.fetch_add(1, Ordering::Relaxed) + 1;
            if let Some(bus) = self.signals.get() {
                bus.sample(SIG_QUEUE_REJECTED, total as f64);
            }
            return Err(depth);
        }
        js.unsettled += 1;
        let unsettled = js.unsettled;
        // Gauge published under the lock: a concurrent settle must not be
        // able to interleave and leave a stale value as the last write.
        self.gauge(job, unsettled);
        self.sample_depth(&st);
        drop(st);
        Ok(())
    }

    /// Reserve a slot unconditionally — journal replay re-admits work that
    /// was already acked before the crash, depth bound or not.
    pub fn admit_replay(&self, job: &str) {
        let mut st = self.state.lock().unwrap();
        let js = st.jobs.entry(job.to_string()).or_default();
        js.unsettled += 1;
        let unsettled = js.unsettled;
        self.gauge(job, unsettled);
        self.sample_depth(&st);
        drop(st);
    }

    /// Queue a journaled submission (its admission slot must be reserved).
    pub fn push(&self, sub: Submission) {
        let mut st = self.state.lock().unwrap();
        if !st.rr.iter().any(|j| j == &sub.job) {
            st.rr.push(sub.job.clone());
        }
        st.jobs
            .entry(sub.job.clone())
            .or_default()
            .queued
            .push_back(sub);
        drop(st);
        self.cv.notify_all();
    }

    /// Round-robin pop: the next job in rotation with queued work yields
    /// one submission. Blocks up to `timeout`; `None` = nothing arrived.
    ///
    /// A popped submission the dispatcher cannot run yet (the duplicate
    /// of a still-settling command) is re-`push`ed to the back of its
    /// job's FIFO; the dispatcher sleeps briefly between such requeues.
    /// That corner accepts within-job version reordering and a few ms of
    /// added rotation latency — duplicate resubmission of an in-flight
    /// version is rare enough that a held-set is not worth its weight.
    pub fn pop(&self, timeout: Duration) -> Option<Submission> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.rr.is_empty() {
                let len = st.rr.len();
                let busy = st
                    .rr
                    .iter()
                    .filter(|j| {
                        st.jobs.get(*j).map(|s| !s.queued.is_empty()).unwrap_or(false)
                    })
                    .count();
                for i in 0..len {
                    let idx = (st.next + i) % len;
                    let job = st.rr[idx].clone();
                    let popped = st
                        .jobs
                        .get_mut(&job)
                        .and_then(|s| s.queued.pop_front());
                    if let Some(sub) = popped {
                        st.next = (idx + 1) % len;
                        drop(st);
                        if let Some(m) = &self.metrics {
                            if busy >= 2 {
                                m.incr("backend.fair.rr_picks", 1);
                            }
                            m.observe_hist_duration(
                                "backend.queue_wait",
                                &[("job", &sub.job)],
                                sub.queued_at.elapsed(),
                            );
                        }
                        return Some(sub);
                    }
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _t) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Release one admission slot of `job` (its submission settled or
    /// failed terminally). A job whose last slot releases with nothing
    /// queued is evicted from the queue state entirely, so a long-lived
    /// daemon churning through short-lived job ids does not grow its
    /// round-robin scan or its job map without bound (the next submit
    /// recreates the state).
    pub fn settled(&self, job: &str) {
        let mut st = self.state.lock().unwrap();
        let unsettled = {
            let js = st.jobs.entry(job.to_string()).or_default();
            js.unsettled = js.unsettled.saturating_sub(1);
            js.unsettled
        };
        self.gauge(job, unsettled);
        self.sample_depth(&st);
        let idle = unsettled == 0
            && st
                .jobs
                .get(job)
                .map(|j| j.queued.is_empty())
                .unwrap_or(true);
        if idle {
            st.jobs.remove(job);
            if let Some(idx) = st.rr.iter().position(|j| j == job) {
                st.rr.remove(idx);
                if st.next > idx {
                    st.next -= 1;
                }
                if !st.rr.is_empty() {
                    st.next %= st.rr.len();
                } else {
                    st.next = 0;
                }
            }
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Drop everything still queued (the crash model: undispatched work
    /// dies with the daemon; the journal brings it back).
    pub fn clear_queued(&self) {
        let mut st = self.state.lock().unwrap();
        for js in st.jobs.values_mut() {
            js.queued.clear();
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Total submissions still waiting for dispatch.
    pub fn queued_total(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.jobs.values().map(|j| j.queued.len()).sum()
    }

    /// Acked-but-unsettled count of one job.
    pub fn unsettled_of(&self, job: &str) -> usize {
        let st = self.state.lock().unwrap();
        st.jobs.get(job).map(|j| j.unsettled).unwrap_or(0)
    }

    /// Block until every queue is empty and every admission slot released,
    /// or the timeout passes. Returns whether the idle state was reached.
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            let busy = st
                .jobs
                .values()
                .any(|j| !j.queued.is_empty() || j.unsettled > 0);
            if !busy {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _t) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sub(job: &str, version: u64) -> Submission {
        Submission {
            id: version,
            job: job.to_string(),
            rank: 0,
            name: format!("{job}@app"),
            version,
            payload: PathBuf::from("/nonexistent"),
            bytes: None,
            queued_at: Instant::now(),
        }
    }

    #[test]
    fn round_robin_interleaves_two_busy_jobs() {
        let m = Metrics::new();
        let q = FairQueue::new(64, Some(Arc::clone(&m)));
        for v in 1..=3 {
            q.try_admit("a").unwrap();
            q.push(sub("a", v));
            q.try_admit("b").unwrap();
            q.push(sub("b", v));
        }
        let order: Vec<String> = (0..6)
            .map(|_| q.pop(Duration::from_millis(100)).unwrap().job)
            .collect();
        // Strict alternation: each turn serves the next job in rotation.
        assert_eq!(order, vec!["a", "b", "a", "b", "a", "b"]);
        assert!(m.counter("backend.fair.rr_picks") >= 4);
        assert!(q.pop(Duration::from_millis(10)).is_none());
    }

    #[test]
    fn admission_bounds_unsettled_not_just_queued() {
        let m = Metrics::new();
        let q = FairQueue::new(2, Some(Arc::clone(&m)));
        q.try_admit("j").unwrap();
        q.push(sub("j", 1));
        q.try_admit("j").unwrap();
        q.push(sub("j", 2));
        // Depth reached: rejected even though the queue could be drained.
        assert!(q.try_admit("j").is_err());
        assert_eq!(m.counter("backend.rejected"), 1);
        // Dispatching alone does not release the slot...
        let _ = q.pop(Duration::from_millis(10)).unwrap();
        assert!(q.try_admit("j").is_err());
        // ...settlement does.
        q.settled("j");
        q.try_admit("j").unwrap();
        assert_eq!(m.gauge_with("backend.queue_depth", &[("job", "j")]), 2);
        // The drain-pacing histogram saw the pop above.
        let h = m.histogram("backend.queue_wait", &[("job", "j")]).unwrap();
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn replay_admission_ignores_the_bound() {
        let q = FairQueue::new(1, None);
        q.try_admit("j").unwrap();
        assert!(q.try_admit("j").is_err());
        q.admit_replay("j"); // acked before the crash: always re-admitted
        assert_eq!(q.unsettled_of("j"), 2);
    }

    #[test]
    fn wait_idle_sees_settlement() {
        let q = FairQueue::new(4, None);
        q.try_admit("j").unwrap();
        q.push(sub("j", 1));
        assert!(!q.wait_idle(Duration::from_millis(20)));
        let s = q.pop(Duration::from_millis(20)).unwrap();
        assert_eq!(s.version, 1);
        q.settled("j");
        assert!(q.wait_idle(Duration::from_millis(100)));
    }

    #[test]
    fn idle_jobs_are_evicted_and_recreated() {
        let q = FairQueue::new(4, None);
        q.try_admit("j").unwrap();
        q.push(sub("j", 1));
        let _ = q.pop(Duration::from_millis(20)).unwrap();
        q.settled("j");
        {
            let st = q.state.lock().unwrap();
            assert!(st.jobs.is_empty(), "idle job state must be evicted");
            assert!(st.rr.is_empty(), "idle job must leave the rotation");
        }
        // Re-admission recreates the state transparently.
        q.try_admit("j").unwrap();
        assert_eq!(q.unsettled_of("j"), 1);
    }

    #[test]
    fn signals_bus_sees_depth_and_rejections() {
        let q = FairQueue::new(2, None);
        let bus = SignalsBus::new(16);
        q.set_signals(Arc::clone(&bus));
        q.try_admit("j").unwrap();
        q.try_admit("j").unwrap();
        assert!(q.try_admit("j").is_err());
        assert!(q.try_admit("j").is_err());
        q.settled("j");
        let view = bus.view();
        let depth = view.queue_depth().expect("depth sampled");
        let values: Vec<f64> = depth.points.iter().map(|p| p.value).collect();
        assert_eq!(values, vec![1.0, 2.0, 1.0]);
        let rejected = view.queue_rejected().expect("rejections sampled");
        assert_eq!(rejected.latest(), Some(2.0), "cumulative rejection count");
    }

    #[test]
    fn clear_queued_drops_work_but_keeps_slots() {
        let q = FairQueue::new(4, None);
        q.try_admit("j").unwrap();
        q.push(sub("j", 1));
        q.clear_queued();
        assert_eq!(q.queued_total(), 0);
        assert_eq!(q.unsettled_of("j"), 1, "the ack is still outstanding");
    }
}
