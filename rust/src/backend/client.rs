//! Client side of the daemon socket: [`BackendClient`] (connection
//! factory + admin one-shots) and [`SocketTransport`] (the
//! [`Transport`](crate::api::Transport) implementation that makes daemon
//! clients ordinary [`VelocClient`](crate::api::VelocClient)s).
//!
//! Payload handoff: containers at most `inline_max` bytes (announced by
//! the daemon at registration) travel inside the submit frame; larger
//! ones are written — and fsynced — as files in the daemon's staging
//! directory on the local tier, and the frame carries only the file name.
//! The daemon adopts the staged file by rename, so large checkpoints
//! cross the process boundary without a second copy.

#![cfg(unix)]

use crate::api::{Transport, VelocClient};
use crate::backend::{wire, Backpressure};
use crate::pipeline::CkptStatus;
use crate::recovery::Restored;
use crate::util::bytes::Checkpoint;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::Write;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Entry point for applications talking to a `veloc daemon`: remembers
/// the socket path and builds per-rank clients (each with its own
/// connection).
pub struct BackendClient {
    socket: PathBuf,
    wait_timeout: Duration,
}

impl BackendClient {
    /// Point at a daemon socket (no connection is made yet).
    pub fn connect(socket: impl Into<PathBuf>) -> BackendClient {
        BackendClient {
            socket: socket.into(),
            wait_timeout: Duration::from_secs(60),
        }
    }

    /// Override the `checkpoint_wait` budget (default 60 s).
    pub fn with_wait_timeout(mut self, d: Duration) -> BackendClient {
        self.wait_timeout = d;
        self
    }

    /// Open a connection, register `(job, rank)` and wrap the transport
    /// in a [`VelocClient`] — the same API the in-process path serves.
    pub fn client(&self, job: &str, rank: usize) -> Result<VelocClient> {
        let transport =
            SocketTransport::open(&self.socket, job, rank, self.wait_timeout)?;
        Ok(VelocClient::with_transport(Arc::new(transport), rank))
    }

    fn one_shot(&self, header: &Json) -> Result<Json> {
        let mut stream = UnixStream::connect(&self.socket)
            .with_context(|| format!("connect {}", self.socket.display()))?;
        wire::write_frame(&mut stream, header, &[])?;
        let (resp, _body) = wire::read_frame(&mut stream)?;
        check_ok(&resp)?;
        Ok(resp)
    }

    /// Fetch the daemon's metrics dump (the `backend.*` gauges live here).
    pub fn stats(&self) -> Result<Json> {
        let resp = self.one_shot(&Json::obj().set("op", "stats"))?;
        resp.get("metrics")
            .cloned()
            .ok_or_else(|| anyhow!("stats response missing metrics"))
    }

    /// Ask the daemon to drain and exit its serve loop.
    pub fn shutdown(&self) -> Result<()> {
        self.one_shot(&Json::obj().set("op", "shutdown"))?;
        Ok(())
    }
}

fn check_ok(resp: &Json) -> Result<()> {
    if resp.bool_or("ok", false) {
        return Ok(());
    }
    bail!("daemon error: {}", resp.str_or("err", "unknown"));
}

/// The socket [`Transport`]: one registered connection per client,
/// requests serialized under a lock (the application may share a client
/// handle across threads).
pub struct SocketTransport {
    stream: Mutex<UnixStream>,
    job: String,
    /// Daemon staging directory for large-payload handoff.
    staging: PathBuf,
    /// Largest payload the daemon accepts inline.
    inline_max: usize,
    wait_timeout: Duration,
}

/// Process-global uniquifier for staged file names: combined with the
/// process id, no two submissions — across transports, reconnects and
/// processes — can ever name the same staged file, so a resubmit can
/// never truncate a file the daemon is still adopting.
static STAGE_NONCE: AtomicU64 = AtomicU64::new(0);

impl SocketTransport {
    /// Connect and register; the daemon answers with the staging
    /// directory and the inline-payload bound.
    pub fn open(
        socket: &std::path::Path,
        job: &str,
        rank: usize,
        wait_timeout: Duration,
    ) -> Result<SocketTransport> {
        let mut stream = UnixStream::connect(socket)
            .with_context(|| format!("connect {}", socket.display()))?;
        wire::write_frame(
            &mut stream,
            &Json::obj()
                .set("op", "register")
                .set("job", job)
                .set("rank", rank),
            &[],
        )?;
        let (resp, _body) = wire::read_frame(&mut stream)?;
        check_ok(&resp)?;
        let staging = PathBuf::from(
            resp.get("staging")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("register response missing staging dir"))?,
        );
        let inline_max = resp.usize_or("inline_max", 64 << 10);
        Ok(SocketTransport {
            stream: Mutex::new(stream),
            job: job.to_string(),
            staging,
            inline_max,
            wait_timeout,
        })
    }

    fn request(&self, header: &Json, body: &[u8]) -> Result<(Json, Vec<u8>)> {
        let mut stream = self.stream.lock().unwrap();
        wire::write_frame(&mut *stream, header, body)?;
        let frame = wire::read_frame(&mut *stream)?;
        check_ok(&frame.0)?;
        Ok(frame)
    }

    /// Stage a large payload as a durable file the daemon can adopt.
    fn stage(&self, rank: usize, version: u64, payload: &[u8]) -> Result<String> {
        let name = format!(
            "{}.{rank}.{version}.{}-{}.vckp",
            self.job,
            std::process::id(),
            STAGE_NONCE.fetch_add(1, Ordering::SeqCst)
        );
        let path = self.staging.join(&name);
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("stage payload {}", path.display()))?;
        f.write_all(payload)?;
        // The handoff contract: bytes are durable before the daemon acks
        // a journal record that points at them.
        f.sync_data()?;
        Ok(name)
    }
}

impl Transport for SocketTransport {
    fn submit(
        &self,
        rank: usize,
        name: &str,
        version: u64,
        ckpt: Checkpoint,
        _started: std::time::Instant,
    ) -> Result<()> {
        let bytes = ckpt.encode();
        let header = Json::obj()
            .set("op", "submit")
            .set("job", self.job.as_str())
            .set("rank", rank)
            .set("name", name)
            .set("version", version);
        let (resp, _body) = if bytes.len() <= self.inline_max {
            self.request(&header, &bytes)?
        } else {
            // Probe admission before paying the staging write: under
            // sustained backpressure every rejected retry would otherwise
            // write (and fsync) the full payload just for the daemon to
            // delete it. The probe is advisory — a slot filling between
            // probe and submit degrades to an ordinary rejection.
            let (probe, _b) = self.request(&header.clone().set("probe", true), &[])?;
            if probe.bool_or("busy", false) {
                return Err(anyhow::Error::new(Backpressure {
                    job: self.job.clone(),
                    depth: probe.usize_or("depth", 0),
                }));
            }
            let staged = self.stage(rank, version, &bytes)?;
            self.request(&header.set("staged", staged.as_str()), &[])?
        };
        if resp.bool_or("busy", false) {
            return Err(anyhow::Error::new(Backpressure {
                job: self.job.clone(),
                depth: resp.usize_or("depth", 0),
            }));
        }
        if !resp.bool_or("acked", false) {
            bail!("daemon did not ack submit of {name} v{version}");
        }
        Ok(())
    }

    fn wait(&self, rank: usize, name: &str, version: u64) -> Result<CkptStatus> {
        // Chunked waits, for two reasons: the daemon caps each wait
        // request (a client must not pin a handler thread forever), and
        // each slice releases this transport's stream mutex so other
        // threads sharing the client can interleave submits/restores
        // instead of stalling behind a long wait.
        const SLICE: Duration = Duration::from_millis(500);
        let deadline = std::time::Instant::now() + self.wait_timeout;
        loop {
            let now = std::time::Instant::now();
            let remaining = deadline.saturating_duration_since(now);
            let slice = remaining.min(SLICE).max(Duration::from_millis(1));
            let (resp, _body) = self.request(
                &Json::obj()
                    .set("op", "wait")
                    .set("job", self.job.as_str())
                    .set("rank", rank)
                    .set("name", name)
                    .set("version", version)
                    .set("timeout_ms", slice.as_millis() as u64),
                &[],
            )?;
            let st = wire::status_from_json(&resp)?;
            if st != CkptStatus::TimedOut || remaining <= slice {
                return Ok(st);
            }
        }
    }

    fn restore(
        &self,
        rank: usize,
        name: &str,
        version: Option<u64>,
    ) -> Result<Option<Restored>> {
        let mut header = Json::obj()
            .set("op", "restart")
            .set("job", self.job.as_str())
            .set("rank", rank)
            .set("name", name);
        if let Some(v) = version {
            header = header.set("version", v);
        }
        let (resp, body) = self.request(&header, &[])?;
        if !resp.bool_or("found", false) {
            return Ok(None);
        }
        // Oversized containers come back as staged files (mirror of the
        // submit-side handoff); this side owns the cleanup.
        let bytes = match resp.get("staged").and_then(Json::as_str) {
            Some(file) => {
                let path = self.staging.join(file);
                let b = std::fs::read(&path)
                    .with_context(|| format!("staged restore {}", path.display()))?;
                let _ = std::fs::remove_file(&path);
                b
            }
            None => body,
        };
        let ckpt = Checkpoint::decode(&bytes)?;
        Ok(Some(Restored {
            version: resp
                .get("version")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("restart response missing version"))?,
            level: resp
                .get("level")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("restart response missing level"))? as u8,
            ckpt,
        }))
    }
}
