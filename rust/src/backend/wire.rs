//! Length-prefixed frame protocol spoken over the daemon's Unix domain
//! socket.
//!
//! Every message — request or response — is one frame:
//!
//! ```text
//! hlen   u32 LE    header JSON length
//! blen   u64 LE    binary body length
//! header JSON      {"op": ..., ...} / {"ok": ..., ...}
//! body   bytes     payload (submit) or restored container (restart)
//! ```
//!
//! The header carries the operation and its small fields; checkpoint
//! payloads ride in the body (inline submits, restart responses) or are
//! handed off out of band as staged files on the daemon's local tier
//! (large submits — the header then names the staged file instead of
//! carrying bytes).
//!
//! Operations: `register` (job + rank), `submit`, `wait` (a `timeout_ms`
//! of 0 is a poll), `restart`, `stats`, `shutdown`. Responses always
//! carry `"ok"`; failures carry `"err"`.

use crate::pipeline::CkptStatus;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};

/// Largest accepted header (requests are small; this bounds a corrupt or
/// hostile peer).
pub const MAX_HEADER: usize = 1 << 20;
/// Largest accepted body — one checkpoint payload.
pub const MAX_BODY: usize = 1 << 30;

/// Write one frame (header JSON + binary body).
pub fn write_frame<W: Write>(w: &mut W, header: &Json, body: &[u8]) -> Result<()> {
    let h = header.to_string().into_bytes();
    if h.len() > MAX_HEADER {
        bail!("frame header too large ({} bytes)", h.len());
    }
    if body.len() > MAX_BODY {
        bail!("frame body too large ({} bytes)", body.len());
    }
    w.write_all(&(h.len() as u32).to_le_bytes())?;
    w.write_all(&(body.len() as u64).to_le_bytes())?;
    w.write_all(&h)?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. An immediate clean EOF (peer closed between frames)
/// surfaces as an error carrying "closed".
pub fn read_frame<R: Read>(r: &mut R) -> Result<(Json, Vec<u8>)> {
    let mut lens = [0u8; 12];
    r.read_exact(&mut lens)
        .map_err(|e| anyhow!("connection closed: {e}"))?;
    let hlen = u32::from_le_bytes(lens[0..4].try_into().unwrap()) as usize;
    // Bound-check the body length as u64 *before* narrowing: on 32-bit
    // targets an oversized length would wrap through `as usize` and pass.
    let blen64 = u64::from_le_bytes(lens[4..12].try_into().unwrap());
    if hlen > MAX_HEADER {
        bail!("frame header too large ({hlen} bytes)");
    }
    if blen64 > MAX_BODY as u64 {
        bail!("frame body too large ({blen64} bytes)");
    }
    let blen = blen64 as usize;
    let mut h = vec![0u8; hlen];
    r.read_exact(&mut h)?;
    let header = std::str::from_utf8(&h).map_err(|_| anyhow!("frame header not utf-8"))?;
    let header = Json::parse(header).map_err(|e| anyhow!("frame header: {e}"))?;
    let mut body = vec![0u8; blen];
    r.read_exact(&mut body)?;
    Ok((header, body))
}

/// Serialize a checkpoint status into response-header fields.
pub fn status_to_json(st: &CkptStatus) -> Json {
    match st {
        CkptStatus::Done(level) => Json::obj()
            .set("status", "done")
            .set("level", *level as u64),
        CkptStatus::Failed(msg) => Json::obj()
            .set("status", "failed")
            .set("msg", msg.as_str()),
        CkptStatus::InFlight => Json::obj().set("status", "in-flight"),
        CkptStatus::TimedOut => Json::obj().set("status", "timeout"),
    }
}

/// Parse a checkpoint status out of a response header.
pub fn status_from_json(j: &Json) -> Result<CkptStatus> {
    match j.str_or("status", "") {
        "done" => Ok(CkptStatus::Done(
            j.get("level")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("done status missing level"))? as u8,
        )),
        "failed" => Ok(CkptStatus::Failed(
            j.str_or("msg", "unknown failure").to_string(),
        )),
        "in-flight" => Ok(CkptStatus::InFlight),
        "timeout" => Ok(CkptStatus::TimedOut),
        other => bail!("unknown status {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_over_a_buffer() {
        let header = Json::obj().set("op", "submit").set("version", 7u64);
        let body = vec![1u8, 2, 3, 4, 5];
        let mut buf = Vec::new();
        write_frame(&mut buf, &header, &body).unwrap();
        // A second frame with an empty body directly behind it.
        write_frame(&mut buf, &Json::obj().set("op", "stats"), &[]).unwrap();

        let mut r = std::io::Cursor::new(buf);
        let (h1, b1) = read_frame(&mut r).unwrap();
        assert_eq!(h1.str_or("op", ""), "submit");
        assert_eq!(h1.get("version").and_then(Json::as_u64), Some(7));
        assert_eq!(b1, body);
        let (h2, b2) = read_frame(&mut r).unwrap();
        assert_eq!(h2.str_or("op", ""), "stats");
        assert!(b2.is_empty());
        // Stream exhausted: the next read reports the close.
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("closed"), "{err}");
    }

    #[test]
    fn oversized_lengths_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(8u32).to_le_bytes());
        buf.extend_from_slice(&((MAX_BODY as u64) + 1).to_le_bytes());
        buf.extend_from_slice(b"{\"a\":1}x");
        let err = read_frame(&mut std::io::Cursor::new(buf))
            .unwrap_err()
            .to_string();
        assert!(err.contains("too large"), "{err}");
    }

    #[test]
    fn statuses_roundtrip() {
        for st in [
            CkptStatus::Done(4),
            CkptStatus::Failed("boom".to_string()),
            CkptStatus::InFlight,
            CkptStatus::TimedOut,
        ] {
            assert_eq!(status_from_json(&status_to_json(&st)).unwrap(), st);
        }
        assert!(status_from_json(&Json::obj().set("status", "??")).is_err());
    }
}
