//! Length-prefixed frame protocol spoken over the daemon's Unix domain
//! socket.
//!
//! Every message — request or response — is one frame:
//!
//! ```text
//! hlen   u32 LE    header JSON length
//! blen   u64 LE    binary body length
//! header JSON      {"op": ..., ...} / {"ok": ..., ...}
//! body   bytes     payload (submit) or restored container (restart)
//! ```
//!
//! The header carries the operation and its small fields; checkpoint
//! payloads ride in the body (inline submits, restart responses) or are
//! handed off out of band as staged files on the daemon's local tier
//! (large submits — the header then names the staged file instead of
//! carrying bytes).
//!
//! Operations: `register` (job + rank), `submit`, `wait` (a `timeout_ms`
//! of 0 is a poll), `restart`, `stats`, `shutdown`. Responses always
//! carry `"ok"`; failures carry `"err"`.

use crate::pipeline::CkptStatus;
use crate::util::json::{Json, ParseError};
use anyhow::{anyhow, bail, Result};
use std::fmt;
use std::io::{Read, Write};

/// Largest accepted header (requests are small; this bounds a corrupt or
/// hostile peer).
pub const MAX_HEADER: usize = 1 << 20;
/// Largest accepted body — one checkpoint payload.
pub const MAX_BODY: usize = 1 << 30;

/// Incremental read granularity: a peer that *declares* a huge body but
/// never sends it costs at most one step of allocation, not the declared
/// length.
const READ_STEP: usize = 256 << 10;

/// Typed failure taxonomy for frame I/O. Every way a hostile or crashed
/// peer can garble a frame maps to one variant — callers can branch on
/// shape (the daemon drops the connection on any of them) and tests can
/// assert the exact rejection instead of matching message substrings.
#[derive(Debug)]
pub enum WireError {
    /// Clean EOF between frames: the peer hung up.
    Closed(std::io::Error),
    /// Declared header length exceeds the configured cap.
    HeaderTooLarge {
        /// Length the frame declared.
        len: u64,
        /// Cap it was checked against.
        max: usize,
    },
    /// Declared body length exceeds the configured cap.
    BodyTooLarge {
        /// Length the frame declared.
        len: u64,
        /// Cap it was checked against.
        max: usize,
    },
    /// Header bytes are not UTF-8.
    HeaderNotUtf8,
    /// Header text is not valid JSON.
    HeaderJson(ParseError),
    /// Truncated mid-frame or any other transport failure.
    Io(std::io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Closed(e) => write!(f, "connection closed: {e}"),
            WireError::HeaderTooLarge { len, max } => {
                write!(f, "frame header too large ({len} bytes, max {max})")
            }
            WireError::BodyTooLarge { len, max } => {
                write!(f, "frame body too large ({len} bytes, max {max})")
            }
            WireError::HeaderNotUtf8 => write!(f, "frame header not utf-8"),
            WireError::HeaderJson(e) => write!(f, "frame header: {e}"),
            WireError::Io(e) => write!(f, "frame i/o: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Closed(e) | WireError::Io(e) => Some(e),
            WireError::HeaderJson(e) => Some(e),
            _ => None,
        }
    }
}

/// Configurable per-connection frame caps. [`Default`] is the protocol
/// maximum ([`MAX_HEADER`] / [`MAX_BODY`]); deployments that never submit
/// inline payloads can run with a far smaller `max_body`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameLimits {
    /// Largest accepted header JSON, bytes.
    pub max_header: usize,
    /// Largest accepted binary body, bytes.
    pub max_body: usize,
}

impl Default for FrameLimits {
    fn default() -> Self {
        FrameLimits {
            max_header: MAX_HEADER,
            max_body: MAX_BODY,
        }
    }
}

/// Write one frame (header JSON + binary body).
pub fn write_frame<W: Write>(w: &mut W, header: &Json, body: &[u8]) -> Result<()> {
    let h = header.to_string().into_bytes();
    if h.len() > MAX_HEADER {
        bail!("frame header too large ({} bytes)", h.len());
    }
    if body.len() > MAX_BODY {
        bail!("frame body too large ({} bytes)", body.len());
    }
    w.write_all(&(h.len() as u32).to_le_bytes())?;
    w.write_all(&(body.len() as u64).to_le_bytes())?;
    w.write_all(&h)?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Read one frame under the default [`FrameLimits`]. An immediate clean
/// EOF (peer closed between frames) surfaces as [`WireError::Closed`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<(Json, Vec<u8>), WireError> {
    read_frame_limited(r, FrameLimits::default())
}

/// Read one frame, validating both declared lengths against `limits`
/// *before* any allocation, then reading incrementally so memory grows
/// only as bytes actually arrive — a hostile 4 GiB length prefix costs a
/// typed error, and a truncated 1 GiB claim costs one [`READ_STEP`].
pub fn read_frame_limited<R: Read>(
    r: &mut R,
    limits: FrameLimits,
) -> Result<(Json, Vec<u8>), WireError> {
    let mut lens = [0u8; 12];
    r.read_exact(&mut lens).map_err(WireError::Closed)?;
    // Bound-check both lengths as u64 *before* narrowing: on 32-bit
    // targets an oversized length would wrap through `as usize` and pass.
    let hlen64 = u32::from_le_bytes(lens[0..4].try_into().unwrap()) as u64;
    let blen64 = u64::from_le_bytes(lens[4..12].try_into().unwrap());
    if hlen64 > limits.max_header as u64 {
        return Err(WireError::HeaderTooLarge {
            len: hlen64,
            max: limits.max_header,
        });
    }
    if blen64 > limits.max_body as u64 {
        return Err(WireError::BodyTooLarge {
            len: blen64,
            max: limits.max_body,
        });
    }
    let h = read_exact_bounded(r, hlen64 as usize)?;
    let header = std::str::from_utf8(&h).map_err(|_| WireError::HeaderNotUtf8)?;
    let header = Json::parse(header).map_err(WireError::HeaderJson)?;
    let body = read_exact_bounded(r, blen64 as usize)?;
    Ok((header, body))
}

/// Read exactly `len` bytes, growing the buffer in [`READ_STEP`] chunks
/// so a declared-but-never-sent length cannot reserve memory up front.
fn read_exact_bounded<R: Read>(r: &mut R, len: usize) -> Result<Vec<u8>, WireError> {
    let mut buf = Vec::with_capacity(len.min(READ_STEP));
    while buf.len() < len {
        let take = (len - buf.len()).min(READ_STEP);
        let start = buf.len();
        buf.resize(start + take, 0);
        r.read_exact(&mut buf[start..]).map_err(WireError::Io)?;
    }
    Ok(buf)
}

/// Serialize a checkpoint status into response-header fields.
pub fn status_to_json(st: &CkptStatus) -> Json {
    match st {
        CkptStatus::Done(level) => Json::obj()
            .set("status", "done")
            .set("level", *level as u64),
        CkptStatus::Failed(msg) => Json::obj()
            .set("status", "failed")
            .set("msg", msg.as_str()),
        CkptStatus::InFlight => Json::obj().set("status", "in-flight"),
        CkptStatus::TimedOut => Json::obj().set("status", "timeout"),
    }
}

/// Parse a checkpoint status out of a response header.
pub fn status_from_json(j: &Json) -> Result<CkptStatus> {
    match j.str_or("status", "") {
        "done" => Ok(CkptStatus::Done(
            j.get("level")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("done status missing level"))? as u8,
        )),
        "failed" => Ok(CkptStatus::Failed(
            j.str_or("msg", "unknown failure").to_string(),
        )),
        "in-flight" => Ok(CkptStatus::InFlight),
        "timeout" => Ok(CkptStatus::TimedOut),
        other => bail!("unknown status {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_over_a_buffer() {
        let header = Json::obj().set("op", "submit").set("version", 7u64);
        let body = vec![1u8, 2, 3, 4, 5];
        let mut buf = Vec::new();
        write_frame(&mut buf, &header, &body).unwrap();
        // A second frame with an empty body directly behind it.
        write_frame(&mut buf, &Json::obj().set("op", "stats"), &[]).unwrap();

        let mut r = std::io::Cursor::new(buf);
        let (h1, b1) = read_frame(&mut r).unwrap();
        assert_eq!(h1.str_or("op", ""), "submit");
        assert_eq!(h1.get("version").and_then(Json::as_u64), Some(7));
        assert_eq!(b1, body);
        let (h2, b2) = read_frame(&mut r).unwrap();
        assert_eq!(h2.str_or("op", ""), "stats");
        assert!(b2.is_empty());
        // Stream exhausted: the next read reports the close.
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("closed"), "{err}");
    }

    #[test]
    fn oversized_lengths_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(8u32).to_le_bytes());
        buf.extend_from_slice(&((MAX_BODY as u64) + 1).to_le_bytes());
        buf.extend_from_slice(b"{\"a\":1}x");
        let err = read_frame(&mut std::io::Cursor::new(buf))
            .unwrap_err()
            .to_string();
        assert!(err.contains("too large"), "{err}");
    }

    #[test]
    fn hostile_4gib_length_prefix_rejected_before_allocation() {
        // A frame claiming a 4 GiB body must come back as a typed
        // rejection without ever allocating the claimed length.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(2u32).to_le_bytes());
        buf.extend_from_slice(&(4u64 << 30).to_le_bytes());
        buf.extend_from_slice(b"{}");
        match read_frame(&mut std::io::Cursor::new(buf)).unwrap_err() {
            WireError::BodyTooLarge { len, max } => {
                assert_eq!(len, 4 << 30);
                assert_eq!(max, MAX_BODY);
            }
            other => panic!("expected BodyTooLarge, got {other}"),
        }
        // Same for a header length beyond the cap.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(&0u64.to_le_bytes());
        match read_frame(&mut std::io::Cursor::new(buf)).unwrap_err() {
            WireError::HeaderTooLarge { len, .. } => assert_eq!(len, u32::MAX as u64),
            other => panic!("expected HeaderTooLarge, got {other}"),
        }
    }

    #[test]
    fn truncated_body_is_a_typed_io_error_with_bounded_allocation() {
        // Declares a large (in-cap) body but sends only a few bytes: the
        // incremental reader must fail with Io after at most one step.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(2u32).to_le_bytes());
        buf.extend_from_slice(&(512u64 << 20).to_le_bytes());
        buf.extend_from_slice(b"{}");
        buf.extend_from_slice(&[0u8; 64]);
        match read_frame(&mut std::io::Cursor::new(buf)).unwrap_err() {
            WireError::Io(_) => {}
            other => panic!("expected Io, got {other}"),
        }
    }

    #[test]
    fn limits_are_configurable() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Json::obj().set("op", "stats"), &[0u8; 128]).unwrap();
        let tight = FrameLimits {
            max_header: MAX_HEADER,
            max_body: 64,
        };
        match read_frame_limited(&mut std::io::Cursor::new(&buf), tight).unwrap_err() {
            WireError::BodyTooLarge { len, max } => {
                assert_eq!((len, max), (128, 64));
            }
            other => panic!("expected BodyTooLarge, got {other}"),
        }
        // The same bytes pass under the default limits.
        read_frame(&mut std::io::Cursor::new(&buf)).unwrap();
    }

    #[test]
    fn garbled_headers_are_typed_errors() {
        let mut non_utf8 = Vec::new();
        non_utf8.extend_from_slice(&(2u32).to_le_bytes());
        non_utf8.extend_from_slice(&0u64.to_le_bytes());
        non_utf8.extend_from_slice(&[0xff, 0xfe]);
        assert!(matches!(
            read_frame(&mut std::io::Cursor::new(non_utf8)).unwrap_err(),
            WireError::HeaderNotUtf8
        ));

        let mut bad_json = Vec::new();
        bad_json.extend_from_slice(&(2u32).to_le_bytes());
        bad_json.extend_from_slice(&0u64.to_le_bytes());
        bad_json.extend_from_slice(b"{x");
        assert!(matches!(
            read_frame(&mut std::io::Cursor::new(bad_json)).unwrap_err(),
            WireError::HeaderJson(_)
        ));
    }

    #[test]
    fn statuses_roundtrip() {
        for st in [
            CkptStatus::Done(4),
            CkptStatus::Failed("boom".to_string()),
            CkptStatus::InFlight,
            CkptStatus::TimedOut,
        ] {
            assert_eq!(status_from_json(&status_to_json(&st)).unwrap(), st);
        }
        assert!(status_from_json(&Json::obj().set("status", "??")).is_err());
    }
}
