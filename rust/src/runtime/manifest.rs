//! Parsed `artifacts/manifest.json` — the contract between the Python AOT
//! compile path and the Rust runtime (shapes, dtypes, parameter blobs).

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// One AOT-compiled HLO module.
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    pub name: String,
    pub file: PathBuf,
    /// Argument (shape, dtype) list in call order.
    pub args: Vec<(Vec<usize>, DType)>,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
}

/// One tensor inside a parameter blob.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// Byte offset in the blob.
    pub offset: usize,
    /// Element count.
    pub len: usize,
}

/// One exported parameter blob (raw little-endian f32).
#[derive(Clone, Debug)]
pub struct ParamsSpec {
    pub file: PathBuf,
    pub tensors: Vec<TensorSpec>,
}

/// The whole artifacts manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub modules: BTreeMap<String, ModuleSpec>,
    pub params: BTreeMap<String, ParamsSpec>,
    pub constants: BTreeMap<String, f64>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = json::load(&dir.join("manifest.json"))?;
        let mut modules = BTreeMap::new();
        for (name, m) in j
            .get("modules")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing modules"))?
        {
            let args = m
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing args"))?
                .iter()
                .map(|a| {
                    let shape = shape_of(
                        a.get("shape").ok_or_else(|| anyhow!("missing shape"))?,
                    )?;
                    let dt = DType::parse(
                        a.get("dtype")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("missing dtype"))?,
                    )?;
                    Ok((shape, dt))
                })
                .collect::<Result<Vec<_>>>()?;
            modules.insert(
                name.clone(),
                ModuleSpec {
                    name: name.clone(),
                    file: dir.join(m.str_or("file", "")),
                    args,
                    outputs: m.usize_or("outputs", 1),
                },
            );
        }
        let mut params = BTreeMap::new();
        if let Some(ps) = j.get("params").and_then(Json::as_obj) {
            for (name, p) in ps {
                let tensors = p
                    .get("tensors")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{name}: missing tensors"))?
                    .iter()
                    .map(|t| {
                        Ok(TensorSpec {
                            name: t.str_or("name", "").to_string(),
                            shape: shape_of(
                                t.get("shape")
                                    .ok_or_else(|| anyhow!("missing shape"))?,
                            )?,
                            offset: t.usize_or("offset", 0),
                            len: t.usize_or("len", 0),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                params.insert(
                    name.clone(),
                    ParamsSpec {
                        file: dir.join(p.str_or("file", "")),
                        tensors,
                    },
                );
            }
        }
        let mut constants = BTreeMap::new();
        if let Some(cs) = j.get("constants").and_then(Json::as_obj) {
            for (k, v) in cs {
                if let Some(x) = v.as_f64() {
                    constants.insert(k.clone(), x);
                }
            }
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            modules,
            params,
            constants,
        })
    }

    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no module '{name}'"))
    }

    pub fn constant(&self, name: &str) -> Result<usize> {
        self.constants
            .get(name)
            .map(|&v| v as usize)
            .ok_or_else(|| anyhow!("manifest has no constant '{name}'"))
    }

    /// Load a parameter blob as named f32 tensors.
    pub fn load_params(&self, name: &str) -> Result<Vec<NamedTensor>> {
        let spec = self
            .params
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no params '{name}'"))?;
        let raw = std::fs::read(&spec.file)
            .map_err(|e| anyhow!("reading {}: {e}", spec.file.display()))?;
        spec.tensors
            .iter()
            .map(|t| {
                let end = t.offset + t.len * 4;
                if end > raw.len() {
                    bail!("{name}/{}: blob truncated", t.name);
                }
                let data = raw[t.offset..end]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Ok(NamedTensor {
                    name: t.name.clone(),
                    shape: t.shape.clone(),
                    data,
                })
            })
            .collect()
    }
}

/// A named f32 tensor loaded from a parameter blob.
#[derive(Clone, Debug)]
pub struct NamedTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let xp = m.module("xor_parity").unwrap();
        assert_eq!(xp.args.len(), 1);
        assert_eq!(xp.args[0].1, DType::I32);
        let train = m.module("dnn_train_step").unwrap();
        assert_eq!(train.args.len(), 9);
        assert_eq!(train.outputs, 7);
        assert!(m.constant("dnn_in").unwrap() > 0);
    }

    #[test]
    fn loads_param_blobs() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let dnn = m.load_params("dnn_init").unwrap();
        assert_eq!(dnn.len(), 6);
        let w1 = &dnn[0];
        assert_eq!(w1.name, "w1");
        assert_eq!(w1.data.len(), w1.shape.iter().product::<usize>());
        assert!(w1.data.iter().all(|x| x.is_finite()));
        // He-init spread sanity
        let mean: f32 =
            w1.data.iter().sum::<f32>() / w1.data.len() as f32;
        assert!(mean.abs() < 0.01);
    }

    #[test]
    fn missing_module_errors() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(m.module("nope").is_err());
        assert!(m.constant("nope").is_err());
        assert!(m.load_params("nope").is_err());
    }
}
