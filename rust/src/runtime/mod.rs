//! PJRT runtime: manifest parsing and AOT-module execution (the bridge to
//! the L2/L1 artifacts produced by `python/compile/aot.py`).

pub mod exec;
pub mod manifest;

pub use exec::{PjrtEngine, Tensor};
pub use manifest::{DType, Manifest, ModuleSpec, NamedTensor};

use std::path::PathBuf;

/// Default artifacts directory: `$VELOC_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("VELOC_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
