//! PJRT execution engine: load `artifacts/*.hlo.txt`, compile once per
//! module on the CPU PJRT client, execute from the L3 hot path.
//!
//! Pattern from /opt/xla-example/load_hlo: HLO *text* -> `HloModuleProto::
//! from_text_file` -> `XlaComputation::from_proto` -> `client.compile` ->
//! `execute`. Executables are compiled lazily and cached, so the first
//! caller pays the compile and everyone else hits the cache.

use crate::runtime::manifest::{DType, Manifest, ModuleSpec, NamedTensor};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// `xla::PjRtLoadedExecutable` holds raw C pointers and is not marked Send/
/// Sync by the binding crate, but the underlying PJRT CPU client is thread-
/// safe (it owns its own thread pool and the C API guarantees concurrent
/// `Execute` is legal). We wrap it to share across rank threads; execution
/// itself takes no Rust-side lock.
struct SendExecutable(xla::PjRtLoadedExecutable);
unsafe impl Send for SendExecutable {}
unsafe impl Sync for SendExecutable {}

struct SendClient(xla::PjRtClient);
unsafe impl Send for SendClient {}
unsafe impl Sync for SendClient {}

/// Typed host-side tensor passed to / returned from executions.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32 {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => {
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Tensor::I32 { data, .. } => {
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(Tensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

impl From<&NamedTensor> for Tensor {
    fn from(t: &NamedTensor) -> Tensor {
        Tensor::F32 {
            shape: t.shape.clone(),
            data: t.data.clone(),
        }
    }
}

/// The engine: one PJRT CPU client + compiled executable cache + manifest.
pub struct PjrtEngine {
    client: SendClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<SendExecutable>>>,
}

impl PjrtEngine {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Arc<Self>> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        Ok(Arc::new(PjrtEngine {
            client: SendClient(client),
            manifest,
            cache: Mutex::new(HashMap::new()),
        }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.0.platform_name()
    }

    fn executable(&self, name: &str) -> Result<Arc<SendExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(exe));
        }
        let spec = self.manifest.module(name)?;
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow!("parsing {}: {e}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        let exe = Arc::new(SendExecutable(exe));
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Eagerly compile a set of modules (start-up warm path).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    fn check_args(spec: &ModuleSpec, args: &[Tensor]) -> Result<()> {
        if args.len() != spec.args.len() {
            bail!(
                "{}: expected {} args, got {}",
                spec.name,
                spec.args.len(),
                args.len()
            );
        }
        for (i, ((shape, dt), t)) in spec.args.iter().zip(args).enumerate() {
            if t.shape() != shape.as_slice() || t.dtype() != *dt {
                bail!(
                    "{} arg {i}: expected {:?}/{:?}, got {:?}/{:?}",
                    spec.name,
                    shape,
                    dt,
                    t.shape(),
                    t.dtype()
                );
            }
        }
        Ok(())
    }

    /// Execute a module; returns the output tuple as host tensors.
    pub fn run(&self, name: &str, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.manifest.module(name)?.clone();
        Self::check_args(&spec, args)?;
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe
            .0
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e}"))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e}"))?;
        if parts.len() != spec.outputs {
            bail!(
                "{name}: expected {} outputs, got {}",
                spec.outputs,
                parts.len()
            );
        }
        parts
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<Vec<_>>>()
            .context("converting outputs")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn engine() -> Option<Arc<PjrtEngine>> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some(PjrtEngine::load(&dir).unwrap())
    }

    #[test]
    fn xor_parity_executes_and_matches_host() {
        let Some(eng) = engine() else { return };
        let k = eng.manifest().constant("xor_shards").unwrap();
        let n = eng.manifest().constant("xor_chunk").unwrap();
        let mut rng = crate::util::rng::Rng::new(5);
        let data: Vec<i32> =
            (0..k * n).map(|_| rng.next_u64() as i32).collect();
        let out = eng
            .run("xor_parity", &[Tensor::i32(&[k, n], data.clone())])
            .unwrap();
        let got = out[0].as_i32().unwrap();
        for j in 0..n {
            let mut want = 0i32;
            for i in 0..k {
                want ^= data[i * n + j];
            }
            assert_eq!(got[j], want, "lane {j}");
        }
    }

    #[test]
    fn checksum_executes() {
        let Some(eng) = engine() else { return };
        let rows = eng.manifest().constant("csum_rows").unwrap();
        let blk = eng.manifest().constant("csum_block").unwrap();
        let data: Vec<i32> = (0..rows * blk).map(|i| i as i32).collect();
        let out = eng
            .run("checksum", &[Tensor::i32(&[rows, blk], data.clone())])
            .unwrap();
        let got = out[0].as_i32().unwrap();
        assert_eq!(got.len(), rows);
        // Host oracle for row 0.
        let mut want: i32 = 0;
        for j in 0..blk {
            want = want
                .wrapping_add((data[j]).wrapping_mul(2 * j as i32 + 1));
        }
        assert_eq!(got[0], want);
    }

    #[test]
    fn shape_mismatch_is_error() {
        let Some(eng) = engine() else { return };
        let err = eng
            .run("xor_parity", &[Tensor::i32(&[2, 2], vec![0; 4])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("expected"), "{err}");
    }

    #[test]
    fn dnn_train_step_decreases_loss() {
        let Some(eng) = engine() else { return };
        let man = eng.manifest();
        let b = man.constant("dnn_batch").unwrap();
        let d = man.constant("dnn_in").unwrap();
        let c = man.constant("dnn_classes").unwrap();
        let params = man.load_params("dnn_init").unwrap();
        let mut args: Vec<Tensor> = params.iter().map(Tensor::from).collect();
        let mut rng = crate::util::rng::Rng::new(11);
        let x: Vec<f32> =
            (0..b * d).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> =
            (0..b).map(|_| rng.below(c as u64) as i32).collect();
        args.push(Tensor::f32(&[b, d], x.clone()));
        args.push(Tensor::i32(&[b], y.clone()));
        args.push(Tensor::scalar_f32(0.05));
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 0..5 {
            let out = eng.run("dnn_train_step", &args).unwrap();
            let loss = out[6].as_f32().unwrap()[0];
            if step == 0 {
                first = loss;
            }
            last = loss;
            // Feed updated params back in.
            for i in 0..6 {
                args[i] = out[i].clone();
            }
        }
        assert!(last < first, "loss {first} -> {last}");
    }

    #[test]
    fn interval_mlp_fwd_shape() {
        let Some(eng) = engine() else { return };
        let man = eng.manifest();
        let f = man.constant("interval_features").unwrap();
        let bsz = man.constant("interval_batch").unwrap();
        let params = man.load_params("interval_init").unwrap();
        let mut args: Vec<Tensor> = params.iter().map(Tensor::from).collect();
        args.push(Tensor::f32(&[bsz, f], vec![0.5; bsz * f]));
        let out = eng.run("interval_mlp_fwd", &args).unwrap();
        assert_eq!(out[0].shape(), &[bsz]);
        assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn seq2seq_fwd_in_unit_range() {
        let Some(eng) = engine() else { return };
        let man = eng.manifest();
        let w = man.constant("seq_window").unwrap();
        let h = man.constant("seq_horizon").unwrap();
        let params = man.load_params("seq2seq").unwrap();
        let mut args: Vec<Tensor> = params.iter().map(Tensor::from).collect();
        args.push(Tensor::f32(&[1, w], vec![0.8; w]));
        let out = eng.run("seq2seq_fwd", &args).unwrap();
        assert_eq!(out[0].shape(), &[1, h]);
        for &p in out[0].as_f32().unwrap() {
            assert!((0.0..=1.0).contains(&p));
        }
    }

    #[test]
    fn concurrent_execution_is_safe() {
        let Some(eng) = engine() else { return };
        let k = eng.manifest().constant("xor_shards").unwrap();
        let n = eng.manifest().constant("xor_chunk").unwrap();
        eng.warm(&["xor_parity"]).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let eng = Arc::clone(&eng);
                std::thread::spawn(move || {
                    let data: Vec<i32> = vec![t as i32; k * n];
                    let out = eng
                        .run("xor_parity", &[Tensor::i32(&[k, n], data)])
                        .unwrap();
                    // xor of 4 identical values = 0 for even k
                    assert!(out[0]
                        .as_i32()
                        .unwrap()
                        .iter()
                        .all(|&v| v == 0));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
