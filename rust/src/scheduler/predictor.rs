//! Application-behaviour prediction (paper ref [6]): a sliding window of
//! observed resource utilization feeds a seq2seq GRU (AOT-compiled at
//! build time, weights trained in `python/compile/aot.py` on synthetic
//! phase traces) that forecasts the next phase. A heuristic fallback
//! (persistence forecast) covers kernel-less configurations.

use crate::runtime::{PjrtEngine, Tensor};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// Sliding window of recent utilization samples in [0, 1], fed by the
/// application harness after every iteration.
pub struct UtilizationMonitor {
    window: Mutex<VecDeque<f32>>,
    capacity: usize,
    last_update: Mutex<Option<std::time::Instant>>,
}

impl UtilizationMonitor {
    pub fn new(capacity: usize) -> Arc<Self> {
        Arc::new(UtilizationMonitor {
            window: Mutex::new(VecDeque::with_capacity(capacity)),
            capacity,
            last_update: Mutex::new(None),
        })
    }

    pub fn record(&self, util: f32) {
        let mut w = self.window.lock().unwrap();
        if w.len() == self.capacity {
            w.pop_front();
        }
        w.push_back(util.clamp(0.0, 1.0));
        *self.last_update.lock().unwrap() = Some(std::time::Instant::now());
    }

    /// Time since the last sample (None = never reported). A stale monitor
    /// means the application stopped reporting — i.e. it is quiescent and
    /// background work cannot interfere with it.
    pub fn staleness(&self) -> Option<std::time::Duration> {
        self.last_update.lock().unwrap().map(|t| t.elapsed())
    }

    /// Current window, front-padded with the oldest sample (or 0.5) to
    /// always return `capacity` values.
    pub fn window(&self) -> Vec<f32> {
        let w = self.window.lock().unwrap();
        let pad = w.front().copied().unwrap_or(0.5);
        let mut out = vec![pad; self.capacity - w.len()];
        out.extend(w.iter().copied());
        out
    }

    pub fn len(&self) -> usize {
        self.window.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

enum Backend {
    /// seq2seq GRU through PJRT (window/horizon from the manifest).
    Kernel {
        engine: Arc<PjrtEngine>,
        params: Vec<Tensor>,
        window: usize,
        horizon: usize,
    },
    /// Persistence forecast: tomorrow looks like the recent average.
    Heuristic,
}

/// Utilization forecaster.
pub struct UtilizationPredictor {
    backend: Backend,
}

impl UtilizationPredictor {
    /// Kernel-backed predictor with the build-time-trained weights.
    pub fn from_engine(engine: Arc<PjrtEngine>) -> Result<Self> {
        let params: Vec<Tensor> = engine
            .manifest()
            .load_params("seq2seq")?
            .iter()
            .map(Tensor::from)
            .collect();
        let window = engine.manifest().constant("seq_window")?;
        let horizon = engine.manifest().constant("seq_horizon")?;
        Ok(UtilizationPredictor {
            backend: Backend::Kernel {
                engine,
                params,
                window,
                horizon,
            },
        })
    }

    pub fn heuristic() -> Self {
        UtilizationPredictor {
            backend: Backend::Heuristic,
        }
    }

    pub fn is_kernel_backed(&self) -> bool {
        matches!(self.backend, Backend::Kernel { .. })
    }

    /// Forecast the next phase's utilization from a window of samples
    /// (values in [0,1]; the window is resampled to the model's length).
    pub fn predict(&self, window: &[f32]) -> Vec<f32> {
        match &self.backend {
            Backend::Heuristic => {
                let n = window.len().min(8).max(1);
                let recent = &window[window.len() - n..];
                let mean = recent.iter().sum::<f32>() / n as f32;
                vec![mean; 8]
            }
            Backend::Kernel {
                engine,
                params,
                window: wlen,
                horizon,
            } => {
                let w = resample(window, *wlen);
                let mut args = params.clone();
                args.push(Tensor::f32(&[1, *wlen], w));
                match engine.run("seq2seq_fwd", &args) {
                    Ok(out) => out[0].as_f32().map(|s| s.to_vec()).unwrap_or_else(|_| vec![0.5; *horizon]),
                    Err(_) => vec![0.5; *horizon],
                }
            }
        }
    }
}

/// Linear resample of `xs` to length `n` (pad with edge value if short).
fn resample(xs: &[f32], n: usize) -> Vec<f32> {
    if xs.is_empty() {
        return vec![0.5; n];
    }
    if xs.len() == n {
        return xs.to_vec();
    }
    if xs.len() < n {
        let mut out = vec![xs[0]; n - xs.len()];
        out.extend_from_slice(xs);
        return out;
    }
    // downsample by averaging buckets
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let lo = i * xs.len() / n;
        let hi = ((i + 1) * xs.len() / n).max(lo + 1);
        let mean = xs[lo..hi].iter().sum::<f32>() / (hi - lo) as f32;
        out.push(mean);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monitor_ring_semantics() {
        let m = UtilizationMonitor::new(4);
        assert!(m.is_empty());
        for i in 0..6 {
            m.record(i as f32 / 10.0);
        }
        assert_eq!(m.len(), 4);
        assert_eq!(m.window(), vec![0.2, 0.3, 0.4, 0.5]);
    }

    #[test]
    fn monitor_pads_short_windows() {
        let m = UtilizationMonitor::new(4);
        m.record(0.8);
        assert_eq!(m.window(), vec![0.8, 0.8, 0.8, 0.8]);
    }

    #[test]
    fn monitor_clamps() {
        let m = UtilizationMonitor::new(2);
        m.record(7.0);
        m.record(-3.0);
        assert_eq!(m.window(), vec![1.0, 0.0]);
    }

    #[test]
    fn heuristic_tracks_recent_mean() {
        let p = UtilizationPredictor::heuristic();
        let f = p.predict(&[0.0, 0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        assert!(f[0] > 0.7);
        let f2 = p.predict(&[0.1; 16]);
        assert!((f2[0] - 0.1).abs() < 1e-5);
    }

    #[test]
    fn resample_shapes() {
        assert_eq!(resample(&[1.0, 2.0], 4), vec![1.0, 1.0, 1.0, 2.0]);
        assert_eq!(resample(&[1.0; 8], 8).len(), 8);
        let down = resample(&(0..16).map(|i| i as f32).collect::<Vec<_>>(), 4);
        assert_eq!(down.len(), 4);
        assert!(down[0] < down[3]);
        assert_eq!(resample(&[], 3), vec![0.5; 3]);
    }

    #[test]
    fn kernel_predictor_distinguishes_phases() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let eng = PjrtEngine::load(&dir).unwrap();
        let p = UtilizationPredictor::from_engine(eng).unwrap();
        assert!(p.is_kernel_backed());
        let busy = p.predict(&[0.85; 32]);
        let idle = p.predict(&[0.15; 32]);
        assert_eq!(busy.len(), 8);
        // The GRU was trained on phase traces; a solidly busy history must
        // forecast higher utilization than a solidly idle one.
        let mb = busy.iter().sum::<f32>() / busy.len() as f32;
        let mi = idle.iter().sum::<f32>() / idle.len() as f32;
        assert!(mb > mi, "busy {mb} vs idle {mi}");
    }
}
