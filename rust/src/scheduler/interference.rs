//! Interference micro-benchmark model (paper §2: "performance modeling
//! using micro-benchmarks focused on interference patterns can be used to
//! control the priority").
//!
//! Calibration runs a fixed compute kernel alone, then again while a
//! competitor thread hammers memory — the measured slowdown is the
//! machine's sensitivity to background I/O-ish work, and feeds the
//! `PriorityGate` pacing.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Result of interference calibration.
#[derive(Clone, Copy, Debug)]
pub struct InterferenceModel {
    /// Compute time alone (seconds) for the probe kernel.
    pub baseline: f64,
    /// Compute time under one background competitor.
    pub contended: f64,
}

impl InterferenceModel {
    /// slowdown >= 1: how much one background stream inflates foreground
    /// compute on this host.
    pub fn slowdown_factor(&self) -> f64 {
        (self.contended / self.baseline).max(1.0)
    }

    /// A neutral model (no calibration run): mild assumed interference.
    pub fn assumed() -> Self {
        InterferenceModel {
            baseline: 1.0,
            contended: 1.15,
        }
    }

    /// Run the calibration micro-benchmark (~tens of milliseconds).
    pub fn calibrate() -> Self {
        let probe = || {
            // Memory-walking probe: sensitive to bandwidth competition.
            let mut v = vec![1u64; 1 << 18];
            let t0 = Instant::now();
            for round in 0..20u64 {
                for i in 0..v.len() {
                    v[i] = v[i].wrapping_mul(6364136223846793005).wrapping_add(round);
                }
            }
            std::hint::black_box(&v);
            t0.elapsed().as_secs_f64()
        };
        let baseline = probe();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let competitor = std::thread::spawn(move || {
            let mut buf = vec![0u8; 1 << 22];
            let mut x = 0u8;
            while !stop2.load(Ordering::Relaxed) {
                for b in buf.iter_mut() {
                    *b = b.wrapping_add(x);
                }
                x = x.wrapping_add(1);
            }
            std::hint::black_box(&buf);
        });
        let contended = probe();
        stop.store(true, Ordering::Relaxed);
        let _ = competitor.join();
        InterferenceModel {
            baseline,
            contended,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slowdown_at_least_one() {
        let m = InterferenceModel {
            baseline: 2.0,
            contended: 1.5, // noise can make this < baseline
        };
        assert_eq!(m.slowdown_factor(), 1.0);
        let m2 = InterferenceModel {
            baseline: 1.0,
            contended: 1.3,
        };
        assert!((m2.slowdown_factor() - 1.3).abs() < 1e-12);
    }

    #[test]
    fn calibration_runs_and_is_sane() {
        let m = InterferenceModel::calibrate();
        assert!(m.baseline > 0.0);
        assert!(m.contended > 0.0);
        let s = m.slowdown_factor();
        assert!((1.0..10.0).contains(&s), "slowdown {s}");
    }

    #[test]
    fn assumed_model_mild() {
        let m = InterferenceModel::assumed();
        assert!(m.slowdown_factor() < 1.5);
    }
}
