//! Background-operation scheduling — the paper's two complementary
//! interference-mitigation strategies (§2, "Optimized Asynchronous
//! Multi-Level Strategies"):
//!
//! 1. **Priority throttling** ([`PriorityGate`]): background flushes run at
//!    low priority and self-throttle between chunks, giving the
//!    application the large time slice. The throttle factor comes from the
//!    interference micro-benchmark model ([`interference`]).
//! 2. **Predictive scheduling** ([`PredictiveGate`]): for applications
//!    with repetitive phase behaviour, a seq2seq model (paper ref [6],
//!    AOT-compiled, executed via PJRT) forecasts near-future utilization
//!    from a sliding window; flushes proceed only through predicted-idle
//!    phases.

pub mod interference;
pub mod predictor;

pub use interference::InterferenceModel;
pub use predictor::{UtilizationMonitor, UtilizationPredictor};

use crate::modules::FlushGate;
use std::sync::Arc;
use std::time::Duration;

/// Scheduling policy for background flushes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// Flush at full speed (the interference baseline).
    Greedy,
    /// Low-priority throttled flush.
    LowPriority,
    /// Seq2seq-predicted idle-phase flush.
    Predictive,
}

/// Greedy gate: no pacing.
pub struct GreedyGate;

impl FlushGate for GreedyGate {
    fn before_chunk(&self, _bytes: usize) {}
}

/// Priority-throttled gate: sleep `throttle * service_time(chunk)` between
/// chunks — the "nice" model where the OS hands the application the bulk
/// of each time slice.
pub struct PriorityGate {
    /// Seconds of pause per byte flushed (derived from the interference
    /// model and the flush bandwidth).
    pause_per_byte: f64,
}

impl PriorityGate {
    pub fn new(pause_per_byte: f64) -> Arc<Self> {
        Arc::new(PriorityGate { pause_per_byte })
    }

    /// Derive pacing from the interference model: pause long enough that
    /// the background stream consumes at most `budget` fraction of the
    /// contended resource.
    pub fn from_model(model: &InterferenceModel, flush_bw: f64, budget: f64) -> Arc<Self> {
        let budget = budget.clamp(0.01, 1.0);
        // service time per byte at full speed:
        let service = 1.0 / flush_bw;
        // slow the stream down to `budget` utilization:
        let pause = service * (1.0 - budget) / budget * model.slowdown_factor();
        Arc::new(PriorityGate {
            pause_per_byte: pause,
        })
    }
}

impl FlushGate for PriorityGate {
    fn before_chunk(&self, bytes: usize) {
        let pause = self.pause_per_byte * bytes as f64;
        if pause > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(pause.min(0.1)));
        }
    }
}

/// Predictive gate: consult the utilization forecast; while the
/// application is predicted busy, wait (bounded) for the next idle phase.
pub struct PredictiveGate {
    predictor: Arc<UtilizationPredictor>,
    monitor: Arc<UtilizationMonitor>,
    /// Utilization above this counts as "busy".
    busy_threshold: f32,
    /// Poll interval while waiting for an idle phase.
    poll: Duration,
    /// Give up waiting after this long (flush must eventually proceed).
    max_wait: Duration,
}

impl PredictiveGate {
    pub fn new(
        predictor: Arc<UtilizationPredictor>,
        monitor: Arc<UtilizationMonitor>,
        busy_threshold: f32,
    ) -> Arc<Self> {
        Arc::new(PredictiveGate {
            predictor,
            monitor,
            busy_threshold,
            poll: Duration::from_millis(2),
            max_wait: Duration::from_millis(250),
        })
    }
}

impl FlushGate for PredictiveGate {
    fn before_chunk(&self, _bytes: usize) {
        let deadline = std::time::Instant::now() + self.max_wait;
        loop {
            // A quiescent application (no fresh samples) cannot be
            // interfered with: flush freely.
            match self.monitor.staleness() {
                None => return,
                Some(s) if s > Duration::from_millis(50) => return,
                _ => {}
            }
            let window = self.monitor.window();
            let forecast = self.predictor.predict(&window);
            // Proceed when the immediate future looks idle.
            let next = forecast.first().copied().unwrap_or(0.0);
            if next <= self.busy_threshold || std::time::Instant::now() >= deadline {
                return;
            }
            std::thread::sleep(self.poll);
        }
    }
}

/// Build the configured gate.
pub fn build_gate(
    policy: SchedulerPolicy,
    model: &InterferenceModel,
    predictor: Option<Arc<UtilizationPredictor>>,
    monitor: Arc<UtilizationMonitor>,
    flush_bw: f64,
) -> Arc<dyn FlushGate> {
    match policy {
        SchedulerPolicy::Greedy => Arc::new(GreedyGate),
        SchedulerPolicy::LowPriority => {
            PriorityGate::from_model(model, flush_bw, 0.3)
        }
        SchedulerPolicy::Predictive => {
            let p = predictor
                .unwrap_or_else(|| Arc::new(UtilizationPredictor::heuristic()));
            PredictiveGate::new(p, monitor, 0.5)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_gate_is_instant() {
        let g = GreedyGate;
        let t0 = std::time::Instant::now();
        g.before_chunk(1 << 20);
        assert!(t0.elapsed() < Duration::from_millis(2));
    }

    #[test]
    fn priority_gate_paces() {
        let g = PriorityGate::new(10e-9); // 10 ns per byte
        let t0 = std::time::Instant::now();
        g.before_chunk(1 << 20); // ~10.5 ms pause
        let e = t0.elapsed();
        assert!(e >= Duration::from_millis(8), "{e:?}");
    }

    #[test]
    fn priority_gate_pause_capped() {
        let g = PriorityGate::new(1.0); // absurd: 1 s/byte
        let t0 = std::time::Instant::now();
        g.before_chunk(1 << 20);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn predictive_gate_passes_when_idle() {
        let monitor = UtilizationMonitor::new(32);
        for _ in 0..32 {
            monitor.record(0.1); // idle history
        }
        let g = PredictiveGate::new(
            Arc::new(UtilizationPredictor::heuristic()),
            monitor,
            0.5,
        );
        let t0 = std::time::Instant::now();
        g.before_chunk(1024);
        assert!(t0.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn predictive_gate_waits_when_busy_then_gives_up() {
        let monitor = UtilizationMonitor::new(32);
        for _ in 0..32 {
            monitor.record(0.95); // solid busy history
        }
        // Keep the monitor fresh (a live busy application) while the gate
        // deliberates.
        let m2 = Arc::clone(&monitor);
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let s2 = Arc::clone(&stop);
        let feeder = std::thread::spawn(move || {
            while !s2.load(std::sync::atomic::Ordering::Relaxed) {
                m2.record(0.95);
                std::thread::sleep(Duration::from_millis(5));
            }
        });
        let g = PredictiveGate::new(
            Arc::new(UtilizationPredictor::heuristic()),
            monitor,
            0.5,
        );
        let t0 = std::time::Instant::now();
        g.before_chunk(1024);
        let e = t0.elapsed();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        feeder.join().unwrap();
        // waited up to max_wait, then proceeded
        assert!(e >= Duration::from_millis(200), "{e:?}");
        assert!(e < Duration::from_secs(1));
    }

    #[test]
    fn predictive_gate_ignores_stale_busy_history() {
        let monitor = UtilizationMonitor::new(32);
        for _ in 0..32 {
            monitor.record(0.95);
        }
        std::thread::sleep(Duration::from_millis(60)); // app went quiet
        let g = PredictiveGate::new(
            Arc::new(UtilizationPredictor::heuristic()),
            monitor,
            0.5,
        );
        let t0 = std::time::Instant::now();
        g.before_chunk(1024);
        assert!(t0.elapsed() < Duration::from_millis(20));
    }
}
