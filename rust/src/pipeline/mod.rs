//! Module pipeline (paper Figure 1): command contexts, the `Module` trait,
//! and the sync/async engine with its active backend.

pub mod context;
pub mod engine;
pub mod module;

pub use context::{
    level_name, storage_key, CkptContext, LevelResult, Outcome, RestoreContext,
    LEVEL_ERASURE, LEVEL_KV, LEVEL_LOCAL, LEVEL_PARTNER, LEVEL_PFS,
};
pub use engine::{BoundaryHook, CkptStatus, Engine, EngineMode, TRACKER_KEEP};
pub use module::{Module, ModuleSwitch};
