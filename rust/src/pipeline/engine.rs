//! The pipeline engine (paper Figure 1): triggers each module in priority
//! order, either synchronously (engine linked into the application) or
//! asynchronously (engine runs in the *active backend* — here a priority
//! thread pool, matching VeloC's separate backend process).

use crate::pipeline::context::{level_name, CkptContext, Outcome, RestoreContext};
use crate::pipeline::module::Module;
use crate::util::bytes::Checkpoint;
use crate::util::pool::{Priority, ThreadPool};
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Engine execution mode (Figure 1: linked-in library vs active backend).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// All modules run inline in `checkpoint()`.
    Sync,
    /// Only `blocking()` modules run inline; the rest run in the backend.
    Async,
}

/// Instrumentation hook consulted at every module boundary of a checkpoint
/// command (between pipeline stages). The fault-injection scenario engine
/// ([`crate::sim`]) uses it to land a failure *mid-pipeline*: returning
/// `false` means the rank died at this boundary — the engine abandons the
/// remaining stages, exactly as a crashed process would.
pub trait BoundaryHook: Send + Sync {
    /// Called before each module runs; `next` is the module about to run.
    /// Return `false` to abort the rest of the pipeline for this command.
    fn before_module(&self, ctx: &CkptContext, next: &'static str) -> bool;
}

/// Completion state of one (rank, name, version) checkpoint command.
#[derive(Clone, Debug, PartialEq)]
pub enum CkptStatus {
    /// Still travelling the pipeline (async tail not settled).
    InFlight,
    /// Highest resilience level achieved.
    Done(u8),
    /// The pipeline failed (or its rank died) with this message.
    Failed(String),
    /// `wait` gave up before the command settled: the typed timeout
    /// outcome (callers used to have to string-match an error). The
    /// command itself may still settle later — only the wait expired.
    TimedOut,
}

/// Settled statuses retained per (rank, name) series: waiters only ever
/// ask about recent versions, and a long-lived daemon would otherwise
/// grow one tracker entry per submitted checkpoint forever. Callers that
/// bound their in-flight window (the backend daemon's admission control)
/// must keep it at or below this, so a watched command's terminal status
/// can never be pruned before its watcher reads it.
pub const TRACKER_KEEP: usize = 4096;

/// Status series retained across distinct (rank, name) keys: bounds the
/// tracker for a daemon churning through many short-lived job-scoped
/// names. Only fully settled series are ever evicted.
const SERIES_MAX: usize = 4096;

/// Status store nested by (rank, name) → version, so the bounded-
/// retention prune touches only the affected series, never the full map.
#[derive(Default)]
struct Tracker {
    states: Mutex<HashMap<(usize, String), BTreeMap<u64, CkptStatus>>>,
    cv: Condvar,
}

impl Tracker {
    fn set(&self, rank: usize, name: &str, version: u64, st: CkptStatus) {
        let key = (rank, name.to_string());
        let mut states = self.states.lock().unwrap();
        let series = states.entry(key.clone()).or_default();
        series.insert(version, st);
        // Bounded retention of *terminal* statuses only, oldest first.
        // In-flight entries are bounded by the caller's admission control
        // and must never be evicted: pruning one would make its eventual
        // completion unobservable (the terminal set() would re-prune it
        // as the oldest key, wedging any watcher forever). The scan only
        // runs once the series actually exceeds the window.
        if series.len() > TRACKER_KEEP {
            let terminal: Vec<u64> = series
                .iter()
                .filter(|(_, s)| !matches!(s, CkptStatus::InFlight))
                .map(|(&v, _)| v)
                .collect();
            if terminal.len() > TRACKER_KEEP {
                for v in &terminal[..terminal.len() - TRACKER_KEEP] {
                    series.remove(v);
                }
            }
        }
        // Series-count bound: job-scoped names make one series per job a
        // daemon ever served; fully settled series of retired jobs are
        // evicted once the map grows past the cap (active series — any
        // in-flight entry — are never touched).
        if states.len() > SERIES_MAX {
            let excess = states.len() - SERIES_MAX;
            let victims: Vec<(usize, String)> = states
                .iter()
                .filter(|(k, s)| {
                    **k != key
                        && s.values().all(|st| !matches!(st, CkptStatus::InFlight))
                })
                .map(|(k, _)| k.clone())
                .take(excess)
                .collect();
            for k in victims {
                states.remove(&k);
            }
        }
        drop(states);
        self.cv.notify_all();
    }

    fn get(&self, rank: usize, name: &str, version: u64) -> Option<CkptStatus> {
        self.states
            .lock()
            .unwrap()
            .get(&(rank, name.to_string()))
            .and_then(|series| series.get(&version))
            .cloned()
    }

    fn wait(
        &self,
        rank: usize,
        name: &str,
        version: u64,
        timeout: Duration,
    ) -> Result<CkptStatus> {
        let key = (rank, name.to_string());
        let deadline = Instant::now() + timeout;
        let mut states = self.states.lock().unwrap();
        loop {
            match states.get(&key).and_then(|series| series.get(&version)) {
                Some(CkptStatus::InFlight) | None => {}
                Some(done) => return Ok(done.clone()),
            }
            let now = Instant::now();
            if now >= deadline {
                // Typed, not an error: an expired wait is an expected
                // outcome the caller decides how to handle (poll again,
                // surface backpressure, fail the run).
                return Ok(CkptStatus::TimedOut);
            }
            let (g, _t) = self.cv.wait_timeout(states, deadline - now).unwrap();
            states = g;
        }
    }
}

/// The per-rank pipeline engine.
pub struct Engine {
    /// Modules sorted by ascending priority.
    modules: Vec<Arc<dyn Module>>,
    mode: EngineMode,
    /// Active backend (shared across ranks); required for Async mode.
    backend: Option<Arc<ThreadPool>>,
    /// Backend priority for the async tail (Background enables the
    /// interference-mitigation path).
    background_priority: Priority,
    /// Optional module-boundary instrumentation (fault injection).
    boundary_hook: Option<Arc<dyn BoundaryHook>>,
    tracker: Arc<Tracker>,
}

impl Engine {
    /// Build an engine over a module stack (sorted by priority). Async
    /// mode requires the shared active-backend pool.
    pub fn new(
        mut modules: Vec<Arc<dyn Module>>,
        mode: EngineMode,
        backend: Option<Arc<ThreadPool>>,
    ) -> Result<Self> {
        if mode == EngineMode::Async && backend.is_none() {
            bail!("async engine mode requires an active backend pool");
        }
        modules.sort_by_key(|m| m.priority());
        Ok(Engine {
            modules,
            mode,
            backend,
            background_priority: Priority::Normal,
            boundary_hook: None,
            tracker: Arc::new(Tracker::default()),
        })
    }

    /// Set the backend priority async tails run at.
    pub fn with_background_priority(mut self, p: Priority) -> Self {
        self.background_priority = p;
        self
    }

    /// Install a module-boundary hook (fault-injection instrumentation).
    pub fn with_boundary_hook(mut self, hook: Arc<dyn BoundaryHook>) -> Self {
        self.boundary_hook = Some(hook);
        self
    }

    /// Sync or async execution.
    pub fn mode(&self) -> EngineMode {
        self.mode
    }

    /// The stack, in pipeline order.
    pub fn modules(&self) -> &[Arc<dyn Module>] {
        &self.modules
    }

    /// Find a module by its stable name.
    pub fn module_named(&self, name: &str) -> Option<&Arc<dyn Module>> {
        self.modules.iter().find(|m| m.name() == name)
    }

    /// Pipeline description for diagnostics (quickstart prints this).
    pub fn describe(&self) -> String {
        let mut s = format!("pipeline ({:?} engine):\n", self.mode);
        for m in &self.modules {
            s.push_str(&format!(
                "  [{:>3}] {:<12} level={} blocking={} enabled={}\n",
                m.priority(),
                m.name(),
                m.level(),
                m.blocking(),
                m.is_enabled()
            ));
        }
        s
    }

    fn run_stage(m: &Arc<dyn Module>, ctx: &mut CkptContext) -> Result<Outcome> {
        if !m.is_enabled() {
            return Ok(Outcome::Skipped);
        }
        m.process(ctx)
    }

    /// Run modules [from..] over the context; returns first error after
    /// attempting every stage (one failed level must not block the rest —
    /// that is the point of multi-level redundancy). `Ok(Some(name))` means
    /// the boundary hook aborted the pipeline before module `name` (the
    /// rank died there); `Ok(None)` means every stage was attempted.
    fn run_range(
        modules: &[Arc<dyn Module>],
        ctx: &mut CkptContext,
        hook: Option<&Arc<dyn BoundaryHook>>,
    ) -> Result<Option<&'static str>> {
        let mut first_err: Option<anyhow::Error> = None;
        for m in modules {
            if let Some(h) = hook {
                if !h.before_module(ctx, m.name()) {
                    return Ok(Some(m.name()));
                }
            }
            // Per-stage observability: a child span under the command span
            // plus one labeled latency observation. Both are no-ops (no
            // allocation, no lock) when the command's obs handle is inert.
            let level = level_name(m.level());
            let span = ctx.obs.open(m.name(), &[("level", level)], ctx.rank as u64);
            let t0 = Instant::now();
            ctx.route_tier = None;
            let res = Self::run_stage(m, ctx);
            ctx.obs.stage_latency(m.name(), level, t0.elapsed());
            if let Some(tier) = ctx.route_tier.take() {
                ctx.obs.label(span, "tier", &tier);
            }
            ctx.obs.close(span);
            if let Err(e) = res {
                if first_err.is_none() {
                    first_err = Some(anyhow!("{}: {e}", m.name()));
                }
            }
        }
        match first_err {
            Some(e) if ctx.max_level() == 0 => Err(e.context("all levels failed")),
            _ => Ok(None),
        }
    }

    /// Submit a checkpoint command. In `Sync` mode the call returns when
    /// every module ran; in `Async` mode it returns after the blocking
    /// prefix, with the rest scheduled on the backend.
    pub fn submit(&self, mut ctx: CkptContext) -> Result<()> {
        let rank = ctx.rank;
        let name = ctx.name.clone();
        let version = ctx.version;
        self.tracker.set(rank, &name, version, CkptStatus::InFlight);

        let split = match self.mode {
            EngineMode::Sync => self.modules.len(),
            EngineMode::Async => self
                .modules
                .iter()
                .position(|m| !m.blocking())
                .unwrap_or(self.modules.len()),
        };
        // Blocking prefix, inline.
        match Self::run_range(&self.modules[..split], &mut ctx, self.boundary_hook.as_ref()) {
            Err(e) => {
                // Terminal: the command span ends with the failed prefix.
                ctx.obs.close(ctx.obs.parent);
                self.tracker
                    .set(rank, &name, version, CkptStatus::Failed(e.to_string()));
                return Err(e);
            }
            Ok(Some(module)) => {
                // The rank died mid-pipeline (injected failure): the command
                // never completes, but the submit itself was accepted.
                ctx.obs.close(ctx.obs.parent);
                self.tracker.set(
                    rank,
                    &name,
                    version,
                    CkptStatus::Failed(format!("rank {rank} died at {module} boundary")),
                );
                return Ok(());
            }
            Ok(None) => {}
        }
        if split == self.modules.len() {
            ctx.obs.close(ctx.obs.parent);
            self.tracker
                .set(rank, &name, version, CkptStatus::Done(ctx.max_level()));
            return Ok(());
        }
        // Async tail on the active backend.
        let tail: Vec<Arc<dyn Module>> = self.modules[split..].to_vec();
        let tracker = Arc::clone(&self.tracker);
        let hook = self.boundary_hook.clone();
        let pool = self.backend.as_ref().expect("checked in new").clone();
        pool.submit(self.background_priority, move || {
            let st = match Engine::run_range(&tail, &mut ctx, hook.as_ref()) {
                Ok(None) => CkptStatus::Done(ctx.max_level()),
                Ok(Some(module)) => CkptStatus::Failed(format!(
                    "rank {} died at {module} boundary",
                    ctx.rank
                )),
                Err(e) => CkptStatus::Failed(e.to_string()),
            };
            // Terminal: the async tail settled; the command span ends here.
            ctx.obs.close(ctx.obs.parent);
            tracker.set(ctx.rank, &ctx.name, ctx.version, st);
        });
        Ok(())
    }

    /// Wait for an async checkpoint to settle; returns its final status,
    /// or [`CkptStatus::TimedOut`] when it does not settle in time.
    pub fn wait(
        &self,
        rank: usize,
        name: &str,
        version: u64,
        timeout: Duration,
    ) -> Result<CkptStatus> {
        self.tracker.wait(rank, name, version, timeout)
    }

    /// Non-blocking status peek: the command's current tracker state, or
    /// `None` when this engine never saw the command (e.g. it is still
    /// queued ahead of the engine — the daemon's poll path maps that to
    /// in-flight).
    pub fn status(&self, rank: usize, name: &str, version: u64) -> Option<CkptStatus> {
        self.tracker.get(rank, name, version)
    }

    /// Record a terminal failure for a command this engine never ran.
    /// The backend daemon uses it when a journaled payload turns out to
    /// be undecodable at dispatch: waiters then observe `Failed` instead
    /// of burning their whole budget into a timeout.
    pub fn reject(&self, rank: usize, name: &str, version: u64, msg: String) {
        self.tracker.set(rank, name, version, CkptStatus::Failed(msg));
    }

    /// Probe modules in priority order (fastest level first) for a copy of
    /// the requested version.
    pub fn restore(&self, ctx: &RestoreContext) -> Result<Option<(u8, Checkpoint)>> {
        for m in &self.modules {
            if !m.is_enabled() || m.level() == 0 {
                continue;
            }
            match m.restore(ctx) {
                Ok(Some(ckpt)) => return Ok(Some((m.level(), ckpt))),
                Ok(None) => continue,
                Err(_e) => continue, // corrupt copy at this level: fall through
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::context::LEVEL_LOCAL;
    use crate::pipeline::module::ModuleSwitch;
    use std::sync::atomic::{AtomicUsize, Ordering};

    struct TestModule {
        name: &'static str,
        prio: i32,
        blocking: bool,
        fail: bool,
        ran: Arc<AtomicUsize>,
        switch: ModuleSwitch,
    }

    impl TestModule {
        fn new(
            name: &'static str,
            prio: i32,
            blocking: bool,
            fail: bool,
            ran: Arc<AtomicUsize>,
        ) -> Arc<dyn Module> {
            Arc::new(TestModule {
                name,
                prio,
                blocking,
                fail,
                ran,
                switch: ModuleSwitch::new(true),
            })
        }
    }

    impl Module for TestModule {
        fn name(&self) -> &'static str {
            self.name
        }
        fn priority(&self) -> i32 {
            self.prio
        }
        fn level(&self) -> u8 {
            LEVEL_LOCAL
        }
        fn blocking(&self) -> bool {
            self.blocking
        }
        fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
            self.ran.fetch_add(1, Ordering::SeqCst);
            if self.fail {
                bail!("boom");
            }
            ctx.record(self.name, LEVEL_LOCAL, Duration::ZERO, 1);
            Ok(Outcome::Done)
        }
        fn switch(&self) -> &ModuleSwitch {
            &self.switch
        }
    }

    fn ctx() -> CkptContext {
        let mut c = Checkpoint::new("t", 0, 1);
        c.push_region(0, vec![0; 8]);
        CkptContext::new("t", 0, 0, 1, c)
    }

    #[test]
    fn sync_runs_all_in_priority_order() {
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![
                TestModule::new("b", 20, false, false, ran.clone()),
                TestModule::new("a", 10, true, false, ran.clone()),
            ],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        assert_eq!(eng.modules()[0].name(), "a");
        eng.submit(ctx()).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 2);
        let st = eng.wait(0, "t", 1, Duration::from_secs(1)).unwrap();
        assert_eq!(st, CkptStatus::Done(LEVEL_LOCAL));
    }

    #[test]
    fn async_defers_non_blocking_tail() {
        let ran = Arc::new(AtomicUsize::new(0));
        let pool = Arc::new(ThreadPool::new(1));
        let eng = Engine::new(
            vec![
                TestModule::new("fast", 10, true, false, ran.clone()),
                TestModule::new("slow", 20, false, false, ran.clone()),
            ],
            EngineMode::Async,
            Some(pool),
        )
        .unwrap();
        eng.submit(ctx()).unwrap();
        let st = eng.wait(0, "t", 1, Duration::from_secs(5)).unwrap();
        assert!(matches!(st, CkptStatus::Done(_)));
        assert_eq!(ran.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn async_mode_requires_pool() {
        let ran = Arc::new(AtomicUsize::new(0));
        assert!(Engine::new(
            vec![TestModule::new("x", 1, true, false, ran)],
            EngineMode::Async,
            None
        )
        .is_err());
    }

    #[test]
    fn one_failed_level_does_not_abort_pipeline() {
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![
                TestModule::new("bad", 10, false, true, ran.clone()),
                TestModule::new("good", 20, false, false, ran.clone()),
            ],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        eng.submit(ctx()).unwrap(); // good level succeeded => Ok
        assert_eq!(ran.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn all_levels_failing_is_an_error() {
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![TestModule::new("bad", 10, false, true, ran)],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        assert!(eng.submit(ctx()).is_err());
    }

    #[test]
    fn disabled_module_skipped() {
        let ran = Arc::new(AtomicUsize::new(0));
        let good = TestModule::new("good", 20, false, false, ran.clone());
        let eng = Engine::new(
            vec![
                TestModule::new("off", 10, false, false, ran.clone()),
                good,
            ],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        eng.module_named("off").unwrap().switch().set(false);
        eng.submit(ctx()).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        eng.module_named("off").unwrap().switch().set(true);
        let mut c2 = ctx();
        c2.version = 2;
        eng.submit(c2).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn boundary_hook_aborts_remaining_stages() {
        struct DieBefore(&'static str);
        impl BoundaryHook for DieBefore {
            fn before_module(&self, _ctx: &CkptContext, next: &'static str) -> bool {
                next != self.0
            }
        }
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![
                TestModule::new("a", 10, false, false, ran.clone()),
                TestModule::new("b", 20, false, false, ran.clone()),
            ],
            EngineMode::Sync,
            None,
        )
        .unwrap()
        .with_boundary_hook(Arc::new(DieBefore("b")));
        eng.submit(ctx()).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 1, "b must never run");
        let st = eng.wait(0, "t", 1, Duration::from_secs(1)).unwrap();
        match st {
            CkptStatus::Failed(msg) => {
                assert!(msg.contains("died at b boundary"), "{msg}")
            }
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    /// Satellite regression: an engine that never settles must produce the
    /// typed timeout status within the timeout — not hang, not a stringly
    /// error. The tail is held behind the backend's background pause, so
    /// the command stays in-flight for the whole wait.
    #[test]
    fn wait_on_unsettled_command_returns_typed_timeout() {
        let ran = Arc::new(AtomicUsize::new(0));
        let pool = Arc::new(ThreadPool::new(1));
        pool.pause_background(true);
        let eng = Engine::new(
            vec![
                TestModule::new("fast", 10, true, false, ran.clone()),
                TestModule::new("slow", 20, false, false, ran.clone()),
            ],
            EngineMode::Async,
            Some(Arc::clone(&pool)),
        )
        .unwrap()
        .with_background_priority(Priority::Background);
        eng.submit(ctx()).unwrap();
        let t0 = Instant::now();
        let st = eng.wait(0, "t", 1, Duration::from_millis(100)).unwrap();
        assert_eq!(st, CkptStatus::TimedOut);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "timeout must not hang: {:?}",
            t0.elapsed()
        );
        assert_eq!(eng.status(0, "t", 1), Some(CkptStatus::InFlight));
        // The command itself was only delayed: releasing the backend
        // settles it and a fresh wait observes the terminal status.
        pool.pause_background(false);
        let st = eng.wait(0, "t", 1, Duration::from_secs(5)).unwrap();
        assert!(matches!(st, CkptStatus::Done(_)), "{st:?}");
    }

    #[test]
    fn status_peek_is_none_for_unknown_commands() {
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![TestModule::new("m", 10, true, false, ran)],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        assert_eq!(eng.status(0, "nope", 1), None);
        let st = eng.wait(0, "nope", 1, Duration::from_millis(20)).unwrap();
        assert_eq!(st, CkptStatus::TimedOut);
    }

    #[test]
    fn describe_lists_modules() {
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![TestModule::new("local", 10, true, false, ran)],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        let d = eng.describe();
        assert!(d.contains("local"));
        assert!(d.contains("blocking=true"));
    }
}
