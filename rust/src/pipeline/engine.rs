//! The pipeline engine (paper Figure 1): triggers each module in priority
//! order, either synchronously (engine linked into the application) or
//! asynchronously (engine runs in the *active backend* — here a priority
//! thread pool, matching VeloC's separate backend process).

use crate::pipeline::context::{CkptContext, Outcome, RestoreContext};
use crate::pipeline::module::Module;
use crate::util::bytes::Checkpoint;
use crate::util::pool::{Priority, ThreadPool};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Engine execution mode (Figure 1: linked-in library vs active backend).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// All modules run inline in `checkpoint()`.
    Sync,
    /// Only `blocking()` modules run inline; the rest run in the backend.
    Async,
}

/// Instrumentation hook consulted at every module boundary of a checkpoint
/// command (between pipeline stages). The fault-injection scenario engine
/// ([`crate::sim`]) uses it to land a failure *mid-pipeline*: returning
/// `false` means the rank died at this boundary — the engine abandons the
/// remaining stages, exactly as a crashed process would.
pub trait BoundaryHook: Send + Sync {
    /// Called before each module runs; `next` is the module about to run.
    /// Return `false` to abort the rest of the pipeline for this command.
    fn before_module(&self, ctx: &CkptContext, next: &'static str) -> bool;
}

/// Completion state of one (rank, name, version) checkpoint command.
#[derive(Clone, Debug, PartialEq)]
pub enum CkptStatus {
    /// Still travelling the pipeline (async tail not settled).
    InFlight,
    /// Highest resilience level achieved.
    Done(u8),
    /// The pipeline failed (or its rank died) with this message.
    Failed(String),
}

#[derive(Default)]
struct Tracker {
    states: Mutex<HashMap<(usize, String, u64), CkptStatus>>,
    cv: Condvar,
}

impl Tracker {
    fn set(&self, rank: usize, name: &str, version: u64, st: CkptStatus) {
        self.states
            .lock()
            .unwrap()
            .insert((rank, name.to_string(), version), st);
        self.cv.notify_all();
    }

    fn wait(
        &self,
        rank: usize,
        name: &str,
        version: u64,
        timeout: Duration,
    ) -> Result<CkptStatus> {
        let key = (rank, name.to_string(), version);
        let deadline = Instant::now() + timeout;
        let mut states = self.states.lock().unwrap();
        loop {
            match states.get(&key) {
                Some(CkptStatus::InFlight) | None => {}
                Some(done) => return Ok(done.clone()),
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("checkpoint_wait timeout: {name} v{version} rank {rank}");
            }
            let (g, _t) = self.cv.wait_timeout(states, deadline - now).unwrap();
            states = g;
        }
    }
}

/// The per-rank pipeline engine.
pub struct Engine {
    /// Modules sorted by ascending priority.
    modules: Vec<Arc<dyn Module>>,
    mode: EngineMode,
    /// Active backend (shared across ranks); required for Async mode.
    backend: Option<Arc<ThreadPool>>,
    /// Backend priority for the async tail (Background enables the
    /// interference-mitigation path).
    background_priority: Priority,
    /// Optional module-boundary instrumentation (fault injection).
    boundary_hook: Option<Arc<dyn BoundaryHook>>,
    tracker: Arc<Tracker>,
}

impl Engine {
    /// Build an engine over a module stack (sorted by priority). Async
    /// mode requires the shared active-backend pool.
    pub fn new(
        mut modules: Vec<Arc<dyn Module>>,
        mode: EngineMode,
        backend: Option<Arc<ThreadPool>>,
    ) -> Result<Self> {
        if mode == EngineMode::Async && backend.is_none() {
            bail!("async engine mode requires an active backend pool");
        }
        modules.sort_by_key(|m| m.priority());
        Ok(Engine {
            modules,
            mode,
            backend,
            background_priority: Priority::Normal,
            boundary_hook: None,
            tracker: Arc::new(Tracker::default()),
        })
    }

    /// Set the backend priority async tails run at.
    pub fn with_background_priority(mut self, p: Priority) -> Self {
        self.background_priority = p;
        self
    }

    /// Install a module-boundary hook (fault-injection instrumentation).
    pub fn with_boundary_hook(mut self, hook: Arc<dyn BoundaryHook>) -> Self {
        self.boundary_hook = Some(hook);
        self
    }

    /// Sync or async execution.
    pub fn mode(&self) -> EngineMode {
        self.mode
    }

    /// The stack, in pipeline order.
    pub fn modules(&self) -> &[Arc<dyn Module>] {
        &self.modules
    }

    /// Find a module by its stable name.
    pub fn module_named(&self, name: &str) -> Option<&Arc<dyn Module>> {
        self.modules.iter().find(|m| m.name() == name)
    }

    /// Pipeline description for diagnostics (quickstart prints this).
    pub fn describe(&self) -> String {
        let mut s = format!("pipeline ({:?} engine):\n", self.mode);
        for m in &self.modules {
            s.push_str(&format!(
                "  [{:>3}] {:<12} level={} blocking={} enabled={}\n",
                m.priority(),
                m.name(),
                m.level(),
                m.blocking(),
                m.is_enabled()
            ));
        }
        s
    }

    fn run_stage(m: &Arc<dyn Module>, ctx: &mut CkptContext) -> Result<Outcome> {
        if !m.is_enabled() {
            return Ok(Outcome::Skipped);
        }
        m.process(ctx)
    }

    /// Run modules [from..] over the context; returns first error after
    /// attempting every stage (one failed level must not block the rest —
    /// that is the point of multi-level redundancy). `Ok(Some(name))` means
    /// the boundary hook aborted the pipeline before module `name` (the
    /// rank died there); `Ok(None)` means every stage was attempted.
    fn run_range(
        modules: &[Arc<dyn Module>],
        ctx: &mut CkptContext,
        hook: Option<&Arc<dyn BoundaryHook>>,
    ) -> Result<Option<&'static str>> {
        let mut first_err: Option<anyhow::Error> = None;
        for m in modules {
            if let Some(h) = hook {
                if !h.before_module(ctx, m.name()) {
                    return Ok(Some(m.name()));
                }
            }
            if let Err(e) = Self::run_stage(m, ctx) {
                if first_err.is_none() {
                    first_err = Some(anyhow!("{}: {e}", m.name()));
                }
            }
        }
        match first_err {
            Some(e) if ctx.max_level() == 0 => Err(e.context("all levels failed")),
            _ => Ok(None),
        }
    }

    /// Submit a checkpoint command. In `Sync` mode the call returns when
    /// every module ran; in `Async` mode it returns after the blocking
    /// prefix, with the rest scheduled on the backend.
    pub fn submit(&self, mut ctx: CkptContext) -> Result<()> {
        let rank = ctx.rank;
        let name = ctx.name.clone();
        let version = ctx.version;
        self.tracker.set(rank, &name, version, CkptStatus::InFlight);

        let split = match self.mode {
            EngineMode::Sync => self.modules.len(),
            EngineMode::Async => self
                .modules
                .iter()
                .position(|m| !m.blocking())
                .unwrap_or(self.modules.len()),
        };
        // Blocking prefix, inline.
        match Self::run_range(&self.modules[..split], &mut ctx, self.boundary_hook.as_ref()) {
            Err(e) => {
                self.tracker
                    .set(rank, &name, version, CkptStatus::Failed(e.to_string()));
                return Err(e);
            }
            Ok(Some(module)) => {
                // The rank died mid-pipeline (injected failure): the command
                // never completes, but the submit itself was accepted.
                self.tracker.set(
                    rank,
                    &name,
                    version,
                    CkptStatus::Failed(format!("rank {rank} died at {module} boundary")),
                );
                return Ok(());
            }
            Ok(None) => {}
        }
        if split == self.modules.len() {
            self.tracker
                .set(rank, &name, version, CkptStatus::Done(ctx.max_level()));
            return Ok(());
        }
        // Async tail on the active backend.
        let tail: Vec<Arc<dyn Module>> = self.modules[split..].to_vec();
        let tracker = Arc::clone(&self.tracker);
        let hook = self.boundary_hook.clone();
        let pool = self.backend.as_ref().expect("checked in new").clone();
        pool.submit(self.background_priority, move || {
            let st = match Engine::run_range(&tail, &mut ctx, hook.as_ref()) {
                Ok(None) => CkptStatus::Done(ctx.max_level()),
                Ok(Some(module)) => CkptStatus::Failed(format!(
                    "rank {} died at {module} boundary",
                    ctx.rank
                )),
                Err(e) => CkptStatus::Failed(e.to_string()),
            };
            tracker.set(ctx.rank, &ctx.name, ctx.version, st);
        });
        Ok(())
    }

    /// Wait for an async checkpoint to settle; returns its final status.
    pub fn wait(
        &self,
        rank: usize,
        name: &str,
        version: u64,
        timeout: Duration,
    ) -> Result<CkptStatus> {
        self.tracker.wait(rank, name, version, timeout)
    }

    /// Probe modules in priority order (fastest level first) for a copy of
    /// the requested version.
    pub fn restore(&self, ctx: &RestoreContext) -> Result<Option<(u8, Checkpoint)>> {
        for m in &self.modules {
            if !m.is_enabled() || m.level() == 0 {
                continue;
            }
            match m.restore(ctx) {
                Ok(Some(ckpt)) => return Ok(Some((m.level(), ckpt))),
                Ok(None) => continue,
                Err(_e) => continue, // corrupt copy at this level: fall through
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::context::LEVEL_LOCAL;
    use crate::pipeline::module::ModuleSwitch;
    use std::sync::atomic::{AtomicUsize, Ordering};

    struct TestModule {
        name: &'static str,
        prio: i32,
        blocking: bool,
        fail: bool,
        ran: Arc<AtomicUsize>,
        switch: ModuleSwitch,
    }

    impl TestModule {
        fn new(
            name: &'static str,
            prio: i32,
            blocking: bool,
            fail: bool,
            ran: Arc<AtomicUsize>,
        ) -> Arc<dyn Module> {
            Arc::new(TestModule {
                name,
                prio,
                blocking,
                fail,
                ran,
                switch: ModuleSwitch::new(true),
            })
        }
    }

    impl Module for TestModule {
        fn name(&self) -> &'static str {
            self.name
        }
        fn priority(&self) -> i32 {
            self.prio
        }
        fn level(&self) -> u8 {
            LEVEL_LOCAL
        }
        fn blocking(&self) -> bool {
            self.blocking
        }
        fn process(&self, ctx: &mut CkptContext) -> Result<Outcome> {
            self.ran.fetch_add(1, Ordering::SeqCst);
            if self.fail {
                bail!("boom");
            }
            ctx.record(self.name, LEVEL_LOCAL, Duration::ZERO, 1);
            Ok(Outcome::Done)
        }
        fn switch(&self) -> &ModuleSwitch {
            &self.switch
        }
    }

    fn ctx() -> CkptContext {
        let mut c = Checkpoint::new("t", 0, 1);
        c.push_region(0, vec![0; 8]);
        CkptContext::new("t", 0, 0, 1, c)
    }

    #[test]
    fn sync_runs_all_in_priority_order() {
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![
                TestModule::new("b", 20, false, false, ran.clone()),
                TestModule::new("a", 10, true, false, ran.clone()),
            ],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        assert_eq!(eng.modules()[0].name(), "a");
        eng.submit(ctx()).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 2);
        let st = eng.wait(0, "t", 1, Duration::from_secs(1)).unwrap();
        assert_eq!(st, CkptStatus::Done(LEVEL_LOCAL));
    }

    #[test]
    fn async_defers_non_blocking_tail() {
        let ran = Arc::new(AtomicUsize::new(0));
        let pool = Arc::new(ThreadPool::new(1));
        let eng = Engine::new(
            vec![
                TestModule::new("fast", 10, true, false, ran.clone()),
                TestModule::new("slow", 20, false, false, ran.clone()),
            ],
            EngineMode::Async,
            Some(pool),
        )
        .unwrap();
        eng.submit(ctx()).unwrap();
        let st = eng.wait(0, "t", 1, Duration::from_secs(5)).unwrap();
        assert!(matches!(st, CkptStatus::Done(_)));
        assert_eq!(ran.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn async_mode_requires_pool() {
        let ran = Arc::new(AtomicUsize::new(0));
        assert!(Engine::new(
            vec![TestModule::new("x", 1, true, false, ran)],
            EngineMode::Async,
            None
        )
        .is_err());
    }

    #[test]
    fn one_failed_level_does_not_abort_pipeline() {
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![
                TestModule::new("bad", 10, false, true, ran.clone()),
                TestModule::new("good", 20, false, false, ran.clone()),
            ],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        eng.submit(ctx()).unwrap(); // good level succeeded => Ok
        assert_eq!(ran.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn all_levels_failing_is_an_error() {
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![TestModule::new("bad", 10, false, true, ran)],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        assert!(eng.submit(ctx()).is_err());
    }

    #[test]
    fn disabled_module_skipped() {
        let ran = Arc::new(AtomicUsize::new(0));
        let good = TestModule::new("good", 20, false, false, ran.clone());
        let eng = Engine::new(
            vec![
                TestModule::new("off", 10, false, false, ran.clone()),
                good,
            ],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        eng.module_named("off").unwrap().switch().set(false);
        eng.submit(ctx()).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        eng.module_named("off").unwrap().switch().set(true);
        let mut c2 = ctx();
        c2.version = 2;
        eng.submit(c2).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn boundary_hook_aborts_remaining_stages() {
        struct DieBefore(&'static str);
        impl BoundaryHook for DieBefore {
            fn before_module(&self, _ctx: &CkptContext, next: &'static str) -> bool {
                next != self.0
            }
        }
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![
                TestModule::new("a", 10, false, false, ran.clone()),
                TestModule::new("b", 20, false, false, ran.clone()),
            ],
            EngineMode::Sync,
            None,
        )
        .unwrap()
        .with_boundary_hook(Arc::new(DieBefore("b")));
        eng.submit(ctx()).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 1, "b must never run");
        let st = eng.wait(0, "t", 1, Duration::from_secs(1)).unwrap();
        match st {
            CkptStatus::Failed(msg) => {
                assert!(msg.contains("died at b boundary"), "{msg}")
            }
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn describe_lists_modules() {
        let ran = Arc::new(AtomicUsize::new(0));
        let eng = Engine::new(
            vec![TestModule::new("local", 10, true, false, ran)],
            EngineMode::Sync,
            None,
        )
        .unwrap();
        let d = eng.describe();
        assert!(d.contains("local"));
        assert!(d.contains("blocking=true"));
    }
}
