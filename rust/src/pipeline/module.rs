//! The `Module` trait — every I/O and resilience strategy is an independent
//! pipeline stage with a priority and a runtime enable/disable switch
//! (paper §2, "Flexibility through Modular Design").

use crate::pipeline::context::{CkptContext, Outcome, RestoreContext};
use crate::util::bytes::Checkpoint;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};

/// Pipeline stage. Implementations live in `crate::modules`.
pub trait Module: Send + Sync {
    /// Stable module name (used in configs, metrics, reports).
    fn name(&self) -> &'static str;

    /// Pipeline position: lower runs earlier. The default stack is
    /// checksum(5) < delta(8) < local(10) < partner(20) < erasure(30) <
    /// compression(35) < transfer(40) < kv(41) < version(50).
    fn priority(&self) -> i32;

    /// Resilience level this module contributes (0 = none, e.g. checksum).
    fn level(&self) -> u8 {
        0
    }

    /// Whether the module blocks the application. Blocking modules run
    /// inline in `checkpoint()` even in async mode (the paper's "block the
    /// application only while writing to the fastest level").
    fn blocking(&self) -> bool {
        false
    }

    /// Handle a checkpoint command.
    fn process(&self, ctx: &mut CkptContext) -> Result<Outcome>;

    /// Try to produce the requested checkpoint during restart. Returns
    /// `Ok(None)` when this level has no usable copy.
    fn restore(&self, _ctx: &RestoreContext) -> Result<Option<Checkpoint>> {
        Ok(None)
    }

    /// Runtime switch (paper: "activated or deactivated at runtime as
    /// needed using a simple switch").
    fn switch(&self) -> &ModuleSwitch;

    /// Convenience: is the module's switch on?
    fn is_enabled(&self) -> bool {
        self.switch().enabled()
    }
}

/// The enable/disable switch shared by all modules.
#[derive(Debug, Default)]
pub struct ModuleSwitch {
    disabled: AtomicBool,
}

impl ModuleSwitch {
    /// A switch in the given initial state.
    pub fn new(enabled: bool) -> Self {
        ModuleSwitch {
            disabled: AtomicBool::new(!enabled),
        }
    }

    /// Is the module currently enabled?
    pub fn enabled(&self) -> bool {
        !self.disabled.load(Ordering::SeqCst)
    }

    /// Enable or disable the module at runtime.
    pub fn set(&self, enabled: bool) {
        self.disabled.store(!enabled, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn switch_toggles() {
        let s = ModuleSwitch::new(true);
        assert!(s.enabled());
        s.set(false);
        assert!(!s.enabled());
        s.set(true);
        assert!(s.enabled());
    }

    #[test]
    fn switch_default_enabled() {
        assert!(ModuleSwitch::default().enabled());
    }
}
