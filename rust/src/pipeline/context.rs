//! Command contexts flowing through the module pipeline.

use crate::obs::ObsHandle;
use crate::util::bufpool::{self, Bytes};
use crate::util::bytes::Checkpoint;
use std::sync::Arc;
use std::time::Duration;

/// Level 1: node-local capture (paper §2's multi-level hierarchy).
pub const LEVEL_LOCAL: u8 = 1;
/// Level 2: partner replica on another node.
pub const LEVEL_PARTNER: u8 = 2;
/// Level 3: XOR erasure parity across a group.
pub const LEVEL_ERASURE: u8 = 3;
/// Level 4: shared-tier flush (PFS, or wherever placement routed it).
pub const LEVEL_PFS: u8 = 4;
/// Level 5: key-value repository copy.
pub const LEVEL_KV: u8 = 5;

/// Canonical storage key for one rank's copy of one version at a level
/// prefix. Shared by the pipeline ([`CkptContext::key`]), every restore
/// fetcher and the delta base-durability probe, so the formats can never
/// drift apart.
pub fn storage_key(prefix: &str, name: &str, rank: usize, version: u64) -> String {
    format!("{prefix}.{name}.r{rank}.v{version}")
}

/// Human-readable name of a resilience level.
pub fn level_name(level: u8) -> &'static str {
    match level {
        LEVEL_LOCAL => "local",
        LEVEL_PARTNER => "partner",
        LEVEL_ERASURE => "erasure",
        LEVEL_PFS => "pfs",
        LEVEL_KV => "kv",
        _ => "unknown",
    }
}

/// What one module did with a command.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    /// Module completed its resilience level.
    Done,
    /// Module chose not to act (disabled levels pass through, paper §2:
    /// "can do so or simply pass based on its own internal state").
    Skipped,
}

/// Record of one completed pipeline stage.
#[derive(Clone, Debug)]
pub struct LevelResult {
    /// Module that ran.
    pub module: String,
    /// Resilience level it completed (0 = none).
    pub level: u8,
    /// Wall/modeled duration charged to the stage.
    pub duration: Duration,
    /// Bytes the stage moved.
    pub bytes: u64,
}

/// A checkpoint command travelling down the pipeline.
pub struct CkptContext {
    /// Application-chosen checkpoint name.
    pub name: String,
    /// Originating rank.
    pub rank: usize,
    /// Node hosting that rank.
    pub node: usize,
    /// Monotonic version.
    pub version: u64,
    /// Decoded checkpoint (regions + meta).
    pub ckpt: Arc<Checkpoint>,
    /// VCKP-encoded container (what modules move around): a refcounted
    /// slice captured once into a pooled buffer, shared zero-copy by every
    /// level. Modules that transform the payload (compression, delta)
    /// swap this and set `encoding`.
    pub encoded: Bytes,
    /// Payload encoding tag stored in the version registry ("raw"/"zlib").
    pub encoding: &'static str,
    /// Completed stages, in pipeline order.
    pub results: Vec<LevelResult>,
    /// Observability handle: span recorder + metrics + the per-command
    /// parent span every stage span nests under. Defaults to fully inert;
    /// the transport (or daemon dispatch) arms it.
    pub obs: ObsHandle,
    /// Storage tier the most recent transfer stage routed to (set by the
    /// transfer module, consumed by the engine as a `tier` span label for
    /// critical-path attribution).
    pub route_tier: Option<String>,
}

impl CkptContext {
    /// Wrap a freshly captured checkpoint into a pipeline command. The
    /// VCKP container is encoded directly into a pooled buffer — this is
    /// the single capture copy; everything downstream shares it.
    pub fn new(
        name: &str,
        rank: usize,
        node: usize,
        version: u64,
        ckpt: Checkpoint,
    ) -> Self {
        let mut buf = bufpool::global().take(ckpt.encoded_size_hint());
        ckpt.encode_into(&mut buf);
        let encoded = buf.freeze();
        CkptContext {
            name: name.to_string(),
            rank,
            node,
            version,
            ckpt: Arc::new(ckpt),
            encoded,
            encoding: "raw",
            results: Vec::new(),
            obs: ObsHandle::default(),
            route_tier: None,
        }
    }

    /// Wrap an already-encoded container without re-encoding it — the
    /// daemon IPC boundary hands over the exact bytes the client encoded
    /// (CRC-validated by the `Checkpoint::decode` that produced `ckpt`,
    /// and VCKP encoding is deterministic, so the two always agree).
    pub fn from_encoded(
        name: &str,
        rank: usize,
        node: usize,
        version: u64,
        ckpt: Checkpoint,
        encoded: Bytes,
    ) -> Self {
        CkptContext {
            name: name.to_string(),
            rank,
            node,
            version,
            ckpt: Arc::new(ckpt),
            encoded,
            encoding: "raw",
            results: Vec::new(),
            obs: ObsHandle::default(),
            route_tier: None,
        }
    }

    /// Storage key for this rank's copy at a given level prefix.
    pub fn key(&self, prefix: &str) -> String {
        storage_key(prefix, &self.name, self.rank, self.version)
    }

    /// Record one completed stage.
    pub fn record(&mut self, module: &str, level: u8, duration: Duration, bytes: u64) {
        self.results.push(LevelResult {
            module: module.to_string(),
            level,
            duration,
            bytes,
        });
    }

    /// Highest resilience level achieved so far.
    pub fn max_level(&self) -> u8 {
        self.results.iter().map(|r| r.level).max().unwrap_or(0)
    }
}

/// A restart command: probe levels for the freshest recoverable version.
pub struct RestoreContext {
    /// Checkpoint name to restore.
    pub name: String,
    /// Requesting rank.
    pub rank: usize,
    /// Node hosting that rank.
    pub node: usize,
    /// Specific version to restore, or None = latest available.
    pub version: Option<u64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> CkptContext {
        let mut c = Checkpoint::new("app", 2, 9);
        c.push_region(0, vec![1, 2, 3]);
        CkptContext::new("app", 2, 1, 9, c)
    }

    #[test]
    fn key_namespacing() {
        let c = ctx();
        assert_eq!(c.key("local"), "local.app.r2.v9");
        assert_eq!(c.key("partner"), "partner.app.r2.v9");
    }

    #[test]
    fn encoded_is_valid_vckp() {
        let c = ctx();
        let d = Checkpoint::decode(&c.encoded).unwrap();
        assert_eq!(d.meta.iteration, 9);
    }

    #[test]
    fn max_level_tracks_records() {
        let mut c = ctx();
        assert_eq!(c.max_level(), 0);
        c.record("local", LEVEL_LOCAL, Duration::ZERO, 10);
        c.record("pfs", LEVEL_PFS, Duration::ZERO, 10);
        assert_eq!(c.max_level(), LEVEL_PFS);
        assert_eq!(c.results.len(), 2);
    }

    #[test]
    fn level_names() {
        assert_eq!(level_name(LEVEL_LOCAL), "local");
        assert_eq!(level_name(LEVEL_KV), "kv");
        assert_eq!(level_name(99), "unknown");
    }
}
