//! Aggregated asynchronous flush (paper follow-on: *Towards Aggregated
//! Asynchronous Checkpointing*, Gossman & Nicolae et al.).
//!
//! At exascale rank counts, a file-per-rank flush is exactly the PFS
//! metadata/small-write pattern the paper's abstract warns about. This
//! subsystem coalesces many per-rank checkpoints into a few large
//! sequential container writes before they hit the shared tier:
//!
//! - [`Aggregator`] — per-group write-combining buffers absorbing level-4
//!   flushes, drained under configurable policies (size threshold, age
//!   threshold, version-complete barrier) in scheduler-gated chunks.
//! - [`container`] — the self-describing VAGG container format.
//! - [`index`] — the `(name, version, rank) → (container, offset, len)`
//!   segment index, persisted next to the containers and rebuildable from
//!   container headers when lost.
//!
//! `modules::transfer` routes through the aggregator when
//! `VelocConfig::aggregation.enabled` is set; restore falls back to the
//! aggregated containers transparently.
//!
//! ```
//! use std::sync::Arc;
//! use veloc::aggregation::{AggregationConfig, Aggregator};
//! use veloc::cluster::Topology;
//! use veloc::storage::{FabricConfig, StorageFabric};
//!
//! let fabric = Arc::new(StorageFabric::build(&FabricConfig::default()).unwrap());
//! // One rank per node: the version-complete barrier drains immediately.
//! let agg = Aggregator::new(
//!     Topology::new(2, 1),
//!     fabric,
//!     AggregationConfig::default(),
//!     None,
//!     None,
//! );
//! agg.submit("app", 1, 0, "raw", veloc::util::bufpool::Bytes::from(vec![7u8; 4096])).unwrap();
//! let restored = agg.restore("app", 1, 0).unwrap().unwrap();
//! assert_eq!(restored, vec![7u8; 4096]);
//! ```

pub mod aggregator;
pub mod container;
pub mod index;

pub use aggregator::{
    AggFaultHook, AggregationReport, Aggregator, DrainStat, SubmitStat, FAULT_PRE_INDEX,
};
pub use container::{ContainerError, ContainerHeader, SegmentMeta};
pub use index::{SegmentIndex, SegmentLoc, INDEX_KEY};

use std::time::Duration;

/// Shared tier the aggregated containers drain to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggTarget {
    /// Parallel file system (persistent).
    Pfs,
    /// Burst buffer (survives node failures, not full-system ones).
    BurstBuffer,
}

impl AggTarget {
    /// Stable config/CLI spelling.
    pub fn name(&self) -> &'static str {
        match self {
            AggTarget::Pfs => "pfs",
            AggTarget::BurstBuffer => "burst-buffer",
        }
    }

    /// Parse the JSON/CLI spelling (single source of truth for both).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "pfs" => Ok(AggTarget::Pfs),
            "burst-buffer" | "bb" => Ok(AggTarget::BurstBuffer),
            other => anyhow::bail!("aggregation target must be pfs|burst-buffer, got {other}"),
        }
    }
}

/// Aggregation knobs (see `VelocConfig::aggregation` and the JSON
/// `"aggregation"` section).
#[derive(Clone, Debug)]
pub struct AggregationConfig {
    /// Route level-4 flushes through the aggregator.
    pub enabled: bool,
    /// Ranks per write-combining group; 0 groups by node (the common
    /// burst-buffer topology: one writer per node).
    pub group_ranks: usize,
    /// Size-threshold drain: flush a group once it buffers this much.
    pub flush_bytes: u64,
    /// Age-threshold drain: flush a group once its oldest segment has
    /// waited this long.
    pub max_delay: Duration,
    /// Version-complete barrier: drain as soon as every rank of the group
    /// submitted the same (name, version) — one container per checkpoint
    /// wave per group.
    pub version_barrier: bool,
    /// Chunk size for scheduler-gated drain pacing (>= 4 KiB).
    pub drain_chunk: usize,
    /// Shared tier the containers drain to (placement may override).
    pub target: AggTarget,
}

impl Default for AggregationConfig {
    fn default() -> Self {
        AggregationConfig {
            enabled: false,
            group_ranks: 0,
            flush_bytes: 32 << 20,
            max_delay: Duration::from_millis(500),
            version_barrier: true,
            drain_chunk: 4 << 20,
            target: AggTarget::Pfs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = AggregationConfig::default();
        assert!(!c.enabled);
        assert_eq!(c.group_ranks, 0, "group by node by default");
        assert!(c.version_barrier);
        assert!(c.drain_chunk >= 4096);
        assert_eq!(c.target, AggTarget::Pfs);
    }

    #[test]
    fn target_names_roundtrip_parse() {
        assert_eq!(AggTarget::Pfs.name(), "pfs");
        assert_eq!(AggTarget::BurstBuffer.name(), "burst-buffer");
        for t in [AggTarget::Pfs, AggTarget::BurstBuffer] {
            assert_eq!(AggTarget::parse(t.name()).unwrap(), t);
        }
        assert_eq!(AggTarget::parse("bb").unwrap(), AggTarget::BurstBuffer);
        assert!(AggTarget::parse("floppy").is_err());
    }
}
