//! VAGG — aggregated checkpoint container format.
//!
//! One container coalesces many per-rank checkpoint payloads (VCKP or zlib
//! blobs) into a single large sequential object, the write pattern the PFS
//! is good at. Layout (little-endian):
//!
//! ```text
//! magic   "VAGG"            4 bytes
//! version u32               format version (1)
//! hlen    u32               header JSON length
//! header  JSON              {"container","group","segments":[
//!                             {"name","version","rank","len","encoding","crc"}]}
//! body    segment payloads  concatenated in header order
//! crc     u32               CRC32 of everything above
//! ```
//!
//! The header is *self-describing*: segment offsets are the cumulative sums
//! of the declared lengths, so the segment index can always be rebuilt from
//! container headers alone (the missing-index recovery path). Each segment
//! additionally carries its own CRC32 so a single-rank extraction validates
//! without touching the rest of the body.

use crate::util::json::{Json, ParseError};
use std::fmt;

/// Container magic bytes.
pub const AGG_MAGIC: &[u8; 4] = b"VAGG";
/// Container format version.
pub const AGG_VERSION: u32 = 1;

/// Typed VAGG parse/extract failures. Index rebuild skips containers
/// rejected with any of these; a fetch degrades the affected rank to a
/// miss (resolved from a deeper level). None may panic on hostile bytes.
#[derive(Debug)]
pub enum ContainerError {
    /// Container shorter than the fixed framing.
    TooShort(usize),
    /// Missing `"VAGG"` magic.
    BadMagic,
    /// Unsupported format version.
    BadVersion(u32),
    /// Declared header length overruns the container.
    HeaderTruncated,
    /// Header bytes are not UTF-8.
    HeaderNotUtf8,
    /// Header text is not valid JSON.
    HeaderJson(ParseError),
    /// Header JSON parsed but a field is missing or has the wrong shape.
    Malformed(String),
    /// Declared segment lengths sum past what any container could hold.
    OversizedBody,
    /// Segment index out of range for this header.
    NoSuchSegment(usize),
    /// A segment's declared span falls outside the container bytes.
    SegmentOverrun(String),
    /// A segment's payload does not match its stored CRC32.
    SegmentCrc(String),
}

impl fmt::Display for ContainerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ContainerError::TooShort(n) => write!(f, "VAGG too short ({n} bytes)"),
            ContainerError::BadMagic => write!(f, "bad VAGG magic"),
            ContainerError::BadVersion(v) => write!(f, "unsupported VAGG version {v}"),
            ContainerError::HeaderTruncated => write!(f, "VAGG header truncated"),
            ContainerError::HeaderNotUtf8 => write!(f, "VAGG header not utf-8"),
            ContainerError::HeaderJson(e) => write!(f, "VAGG header: {e}"),
            ContainerError::Malformed(msg) => write!(f, "VAGG header: {msg}"),
            ContainerError::OversizedBody => {
                write!(f, "VAGG header declares oversized body")
            }
            ContainerError::NoSuchSegment(i) => {
                write!(f, "segment index {i} out of range")
            }
            ContainerError::SegmentOverrun(which) => {
                write!(f, "segment {which} overruns container")
            }
            ContainerError::SegmentCrc(which) => {
                write!(f, "segment {which} CRC mismatch")
            }
        }
    }
}

impl std::error::Error for ContainerError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ContainerError::HeaderJson(e) => Some(e),
            _ => None,
        }
    }
}

/// Metadata of one packed segment (one rank's checkpoint payload).
#[derive(Clone, Debug, PartialEq)]
pub struct SegmentMeta {
    /// Checkpoint name.
    pub name: String,
    /// Checkpoint version.
    pub version: u64,
    /// Originating rank.
    pub rank: usize,
    /// Payload length in bytes.
    pub len: usize,
    /// Payload encoding tag ("raw" VCKP or "zlib").
    pub encoding: String,
    /// CRC32 of the segment payload bytes.
    pub crc: u32,
}

/// Decoded container header.
#[derive(Clone, Debug)]
pub struct ContainerHeader {
    /// Container id (also its storage key suffix).
    pub id: String,
    /// Aggregation group that produced it.
    pub group: usize,
    /// Packed segments, in body order.
    pub segments: Vec<SegmentMeta>,
    /// Byte offset of the body (first segment payload) in the container.
    pub body_offset: usize,
}

impl ContainerHeader {
    /// Offset of segment `i`'s payload relative to the container start.
    pub fn segment_offset(&self, i: usize) -> usize {
        let before: usize = self.segments[..i].iter().map(|s| s.len).sum();
        self.body_offset + before
    }

    /// Find a segment by its (name, version, rank) identity.
    pub fn find(&self, name: &str, version: u64, rank: usize) -> Option<usize> {
        self.segments
            .iter()
            .position(|s| s.rank == rank && s.version == version && s.name == name)
    }
}

/// Serialize just the container prefix — magic, format version, header —
/// for the given segment metadata. The scatter-gather drain path emits
/// `[prefix, seg0, seg1, ..., crc_le]` as a vectored write without ever
/// concatenating the segment payloads; the trailing CRC32 covers prefix +
/// payloads in that order (identical to what [`encode`] produces).
pub fn encode_prefix(id: &str, group: usize, segments: &[SegmentMeta]) -> Vec<u8> {
    let seg_json: Vec<Json> = segments
        .iter()
        .map(|m| {
            Json::obj()
                .set("name", m.name.as_str())
                .set("version", m.version)
                .set("rank", m.rank)
                .set("len", m.len as u64)
                .set("encoding", m.encoding.as_str())
                .set("crc", m.crc as u64)
        })
        .collect();
    let header = Json::obj()
        .set("container", id)
        .set("group", group)
        .set("segments", Json::Arr(seg_json))
        .to_string();
    let hbytes = header.as_bytes();
    let mut out = Vec::with_capacity(4 + 4 + 4 + hbytes.len());
    out.extend_from_slice(AGG_MAGIC);
    out.extend_from_slice(&AGG_VERSION.to_le_bytes());
    out.extend_from_slice(&(hbytes.len() as u32).to_le_bytes());
    out.extend_from_slice(hbytes);
    out
}

/// Serialize segments into one VAGG container.
pub fn encode(id: &str, group: usize, segments: &[(SegmentMeta, &[u8])]) -> Vec<u8> {
    let metas: Vec<SegmentMeta> = segments.iter().map(|(m, _)| m.clone()).collect();
    let mut out = encode_prefix(id, group, &metas);
    let body_len: usize = segments.iter().map(|(m, _)| m.len).sum();
    out.reserve(body_len + 4);
    for (_, data) in segments {
        out.extend_from_slice(data);
    }
    let crc = crc32fast::hash(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse a container header (without validating the body — extraction
/// validates per-segment CRCs, so index rebuilds stay cheap even when only
/// the header region is intact).
pub fn decode_header(buf: &[u8]) -> Result<ContainerHeader, ContainerError> {
    let field = |msg: &str| ContainerError::Malformed(msg.to_string());
    if buf.len() < 12 {
        return Err(ContainerError::TooShort(buf.len()));
    }
    if &buf[0..4] != AGG_MAGIC {
        return Err(ContainerError::BadMagic);
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != AGG_VERSION {
        return Err(ContainerError::BadVersion(version));
    }
    let hlen = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    let hend = 12usize
        .checked_add(hlen)
        .filter(|&hend| hend <= buf.len())
        .ok_or(ContainerError::HeaderTruncated)?;
    let header =
        std::str::from_utf8(&buf[12..hend]).map_err(|_| ContainerError::HeaderNotUtf8)?;
    let j = Json::parse(header).map_err(ContainerError::HeaderJson)?;
    let id = j
        .get("container")
        .and_then(Json::as_str)
        .ok_or_else(|| field("header missing container id"))?
        .to_string();
    let group = j
        .get("group")
        .and_then(Json::as_usize)
        .ok_or_else(|| field("header missing group"))?;
    let mut segments = Vec::new();
    for s in j
        .get("segments")
        .and_then(Json::as_arr)
        .ok_or_else(|| field("header missing segments"))?
    {
        segments.push(SegmentMeta {
            name: s
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| field("segment missing name"))?
                .to_string(),
            version: s
                .get("version")
                .and_then(Json::as_u64)
                .ok_or_else(|| field("segment missing version"))?,
            rank: s
                .get("rank")
                .and_then(Json::as_usize)
                .ok_or_else(|| field("segment missing rank"))?,
            len: s
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| field("segment missing len"))?,
            encoding: s.str_or("encoding", "raw").to_string(),
            crc: s.get("crc").and_then(Json::as_u64).unwrap_or(0) as u32,
        });
    }
    // Reject headers whose declared lengths overflow: segment_offset adds
    // `body_offset` to cumulative sums of them, and a hostile/corrupt
    // header must not be able to panic it. Starting the fold at `hend`
    // bounds `body_offset + sum`, not just the sum.
    segments
        .iter()
        .try_fold(hend, |acc, s| acc.checked_add(s.len))
        .ok_or(ContainerError::OversizedBody)?;
    Ok(ContainerHeader {
        id,
        group,
        segments,
        body_offset: hend,
    })
}

/// Extract one segment's payload, validating bounds and the per-segment
/// CRC (catches truncated or corrupted containers without relying on the
/// trailing whole-container checksum).
pub fn extract(
    buf: &[u8],
    header: &ContainerHeader,
    i: usize,
) -> Result<Vec<u8>, ContainerError> {
    let meta = header
        .segments
        .get(i)
        .ok_or(ContainerError::NoSuchSegment(i))?;
    let which = || format!("{} r{} v{}", meta.name, meta.rank, meta.version);
    let off = header.segment_offset(i);
    // The last 4 container bytes are the trailing CRC, never payload.
    let end = off
        .checked_add(meta.len)
        .and_then(|e| e.checked_add(4))
        .ok_or_else(|| ContainerError::SegmentOverrun(which()))?;
    if end > buf.len() {
        return Err(ContainerError::SegmentOverrun(which()));
    }
    let data = &buf[off..off + meta.len];
    let actual = crc32fast::hash(data);
    if actual != meta.crc {
        return Err(ContainerError::SegmentCrc(which()));
    }
    Ok(data.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(name: &str, version: u64, rank: usize, data: &[u8]) -> SegmentMeta {
        SegmentMeta {
            name: name.to_string(),
            version,
            rank,
            len: data.len(),
            encoding: "raw".to_string(),
            crc: crc32fast::hash(data),
        }
    }

    fn sample() -> (Vec<u8>, Vec<Vec<u8>>) {
        let payloads = vec![vec![1u8; 100], vec![2u8; 250], vec![3u8; 7]];
        let metas: Vec<(SegmentMeta, &[u8])> = payloads
            .iter()
            .enumerate()
            .map(|(r, p)| (seg("app", 3, r, p), p.as_slice()))
            .collect();
        (encode("g0.c1", 0, &metas), payloads)
    }

    #[test]
    fn roundtrip_all_segments() {
        let (buf, payloads) = sample();
        let h = decode_header(&buf).unwrap();
        assert_eq!(h.id, "g0.c1");
        assert_eq!(h.group, 0);
        assert_eq!(h.segments.len(), 3);
        for (i, p) in payloads.iter().enumerate() {
            assert_eq!(&extract(&buf, &h, i).unwrap(), p);
        }
    }

    #[test]
    fn prefix_plus_parts_plus_crc_equals_encode() {
        // The scatter-gather drain path must produce a byte-identical
        // container: prefix, payloads in header order, trailing CRC.
        let (buf, payloads) = sample();
        let metas: Vec<SegmentMeta> = payloads
            .iter()
            .enumerate()
            .map(|(r, p)| seg("app", 3, r, p))
            .collect();
        let mut out = encode_prefix("g0.c1", 0, &metas);
        for p in &payloads {
            out.extend_from_slice(p);
        }
        let crc = crc32fast::hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        assert_eq!(out, buf);
    }

    #[test]
    fn find_by_identity() {
        let (buf, _) = sample();
        let h = decode_header(&buf).unwrap();
        assert_eq!(h.find("app", 3, 1), Some(1));
        assert_eq!(h.find("app", 2, 1), None);
        assert_eq!(h.find("other", 3, 1), None);
    }

    #[test]
    fn truncation_detected_on_extract() {
        let (buf, _) = sample();
        let h = decode_header(&buf).unwrap();
        // Cut into the last segment's payload.
        let cut = &buf[..buf.len() - 8];
        assert!(extract(cut, &h, 2).is_err());
        // Earlier segments still extract (partial-container salvage).
        assert!(extract(cut, &h, 0).is_ok());
    }

    #[test]
    fn corruption_detected_by_segment_crc() {
        let (mut buf, _) = sample();
        let h = decode_header(&buf).unwrap();
        let off = h.segment_offset(1);
        buf[off + 3] ^= 0xFF;
        assert!(extract(&buf, &h, 1).is_err());
        assert!(extract(&buf, &h, 0).is_ok());
    }

    #[test]
    fn bad_magic_rejected() {
        let (mut buf, _) = sample();
        buf[0] = b'X';
        assert!(decode_header(&buf).is_err());
    }

    #[test]
    fn header_truncation_rejected() {
        let (buf, _) = sample();
        assert!(decode_header(&buf[..10]).is_err());
        assert!(decode_header(&buf[..20]).is_err());
    }

    #[test]
    fn hostile_declared_lengths_are_typed_errors() {
        // Segment lengths that together overflow `body_offset + sum` must
        // be rejected at header-decode time, not panic in segment_offset.
        let forge = |lens: &[u64]| -> Vec<u8> {
            let segs: Vec<String> = lens
                .iter()
                .map(|l| {
                    format!(
                        "{{\"name\":\"a\",\"version\":1,\"rank\":0,\"len\":{l},\
                         \"encoding\":\"raw\",\"crc\":0}}"
                    )
                })
                .collect();
            let header = format!(
                "{{\"container\":\"c\",\"group\":0,\"segments\":[{}]}}",
                segs.join(",")
            );
            let hb = header.as_bytes();
            let mut out = Vec::new();
            out.extend_from_slice(AGG_MAGIC);
            out.extend_from_slice(&AGG_VERSION.to_le_bytes());
            out.extend_from_slice(&(hb.len() as u32).to_le_bytes());
            out.extend_from_slice(hb);
            out
        };
        match decode_header(&forge(&[u64::MAX, u64::MAX])) {
            Err(ContainerError::OversizedBody) => {}
            other => panic!("expected OversizedBody, got {other:?}"),
        }
        // A single in-range but container-overrunning length decodes (the
        // header is self-consistent) but extraction degrades typed.
        let buf = forge(&[4 << 30]);
        let h = decode_header(&buf).unwrap();
        match extract(&buf, &h, 0) {
            Err(ContainerError::SegmentOverrun(_)) => {}
            other => panic!("expected SegmentOverrun, got {other:?}"),
        }
        match extract(&buf, &h, 9) {
            Err(ContainerError::NoSuchSegment(9)) => {}
            other => panic!("expected NoSuchSegment, got {other:?}"),
        }
    }
}
