//! The write-combining aggregator.
//!
//! Per-group buffers absorb level-4 flushes from every rank of the group
//! (group = node, or N consecutive ranks, see
//! [`AggregationConfig::group_ranks`]), pack them into large [VAGG
//! containers](super::container) and drain the containers to the shared
//! tier in scheduler-gated chunks. Drains trigger on any of three
//! policies: buffered bytes over [`AggregationConfig::flush_bytes`], the
//! oldest buffered segment older than [`AggregationConfig::max_delay`], or
//! — the checkpoint-shaped default — a *version-complete barrier*: every
//! rank of the group submitted the same (name, version), so the container
//! holds one coherent wave of the collective checkpoint.

use crate::aggregation::container::{self, SegmentMeta};
use crate::aggregation::index::{SegmentIndex, SegmentLoc, INDEX_KEY};
use crate::aggregation::{AggTarget, AggregationConfig};
use crate::cluster::Topology;
use crate::metrics::Metrics;
use crate::modules::version::VersionRegistry;
use crate::modules::FlushGate;
use crate::pipeline::context::LEVEL_PFS;
use crate::storage::{PlacementEngine, StorageFabric, StorageTier};
use crate::util::bufpool::Bytes;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Named crash window inside [`Aggregator::drain_locked`]: after the
/// container was durably published but before the segment index was
/// updated/persisted. A failure landing here leaves a durable-but-unindexed
/// container that recovery must find via the header rebuild.
pub const FAULT_PRE_INDEX: &str = "drain.pre_index";

/// Test/sim instrumentation fired at named fault points inside the
/// aggregator. Returning `true` means the simulated failure lands at that
/// point: the drain stops there, exactly as a crashed writer would.
pub type AggFaultHook = Arc<dyn Fn(&str) -> bool + Send + Sync>;

/// One rank's checkpoint payload waiting in a group buffer — a shared
/// view of the capture allocation (or the level-1 read-back), never a
/// private copy.
struct PendingSegment {
    name: String,
    version: u64,
    rank: usize,
    encoding: String,
    data: Bytes,
}

#[derive(Default)]
struct GroupBuffer {
    pending: Vec<PendingSegment>,
    bytes: u64,
    /// When the oldest currently-buffered segment arrived (age policy).
    first_at: Option<Instant>,
}

impl GroupBuffer {
    fn count_version(&self, name: &str, version: u64) -> usize {
        self.pending
            .iter()
            .filter(|p| p.version == version && p.name == name)
            .count()
    }
}

/// Outcome of one [`Aggregator::submit`].
#[derive(Clone, Copy, Debug)]
pub struct SubmitStat {
    /// Payload bytes accepted into the buffer.
    pub bytes: u64,
    /// Modeled duration charged by the drain this submit triggered
    /// (zero when the segment was only buffered).
    pub modeled: Duration,
    /// Whether this submit triggered a container drain.
    pub drained: bool,
}

/// Outcome of one container drain.
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainStat {
    /// Containers written (0 when the buffer was empty).
    pub containers: u64,
    /// Per-rank segments drained.
    pub segments: u64,
    /// Container bytes written to the target tier.
    pub written_bytes: u64,
    /// Modeled tier duration for the container writes.
    pub modeled: Duration,
}

impl DrainStat {
    fn absorb(&mut self, other: DrainStat) {
        self.containers += other.containers;
        self.segments += other.segments;
        self.written_bytes += other.written_bytes;
        self.modeled += other.modeled;
    }
}

/// Cumulative aggregator accounting (drives the metrics the win is
/// measured by: container count, mean write size, write amplification).
#[derive(Clone, Copy, Debug, Default)]
pub struct AggregationReport {
    /// Containers written since construction.
    pub containers: u64,
    /// Per-rank segments drained since construction.
    pub segments: u64,
    /// Checkpoint payload bytes absorbed.
    pub payload_bytes: u64,
    /// Container bytes written to the target tier (payload + headers).
    pub written_bytes: u64,
}

impl AggregationReport {
    /// Mean container size written to the shared tier.
    pub fn mean_write_bytes(&self) -> f64 {
        if self.containers == 0 {
            return 0.0;
        }
        self.written_bytes as f64 / self.containers as f64
    }

    /// Bytes hitting the shared tier per payload byte (>= 1.0; the excess
    /// is container-header overhead).
    pub fn write_amplification(&self) -> f64 {
        if self.payload_bytes == 0 {
            return 1.0;
        }
        self.written_bytes as f64 / self.payload_bytes as f64
    }

    /// Mean per-rank segments coalesced per container.
    pub fn segments_per_container(&self) -> f64 {
        if self.containers == 0 {
            return 0.0;
        }
        self.segments as f64 / self.containers as f64
    }
}

/// The write-combining aggregator (see the [module docs](self)).
pub struct Aggregator {
    topology: Topology,
    fabric: Arc<StorageFabric>,
    cfg: AggregationConfig,
    /// Scheduler gate consulted between drain chunks (same interference
    /// lever the direct flush path uses).
    gate: Option<Arc<dyn FlushGate>>,
    metrics: Option<Arc<Metrics>>,
    /// Optional span recorder: container drains show up in `veloc trace`
    /// exports as `agg.drain` spans.
    tracer: Mutex<Option<Arc<crate::obs::TraceRecorder>>>,
    /// Adaptive tier placement: when set, container drains route to the
    /// best eligible shared tier (with failover) instead of the fixed
    /// [`AggTarget`], and the segment index records where each container
    /// landed.
    placement: Option<Arc<PlacementEngine>>,
    /// When set, level-4 durability is recorded here at *drain* time —
    /// a buffered segment is still volatile node memory and must not
    /// count as flushed.
    registry: Option<Arc<VersionRegistry>>,
    groups: Vec<Mutex<GroupBuffer>>,
    index: Mutex<SegmentIndex>,
    /// One-shot guard for the cold-start fallbacks (persisted-index load,
    /// header rebuild). A mutex, not an atomic: concurrent first restores
    /// must block until the sync completes, then retry their lookup —
    /// otherwise racers would report a miss while the winner is still
    /// scanning. After the sync the in-memory index is authoritative and
    /// repeated misses stay cheap.
    cold_sync: Mutex<bool>,
    /// Optional fault-point hook ([`FAULT_PRE_INDEX`]); installed by the
    /// scenario engine, None in production.
    fault_hook: Mutex<Option<AggFaultHook>>,
    /// Global container sequence (keys stay unique across groups; seeded
    /// past any containers already on a persistent tier so a restarted
    /// runtime never overwrites a prior run's containers).
    seq: AtomicU64,
    containers: AtomicU64,
    segments: AtomicU64,
    payload_bytes: AtomicU64,
    written_bytes: AtomicU64,
}

impl Aggregator {
    /// Minimal constructor: no metrics, no registry, fixed target tier.
    pub fn new(
        topology: Topology,
        fabric: Arc<StorageFabric>,
        cfg: AggregationConfig,
        gate: Option<Arc<dyn FlushGate>>,
        metrics: Option<Arc<Metrics>>,
    ) -> Arc<Self> {
        Self::with_registry(topology, fabric, cfg, gate, metrics, None)
    }

    /// Constructor recording level-4 durability into a version registry
    /// at drain time; fixed target tier.
    pub fn with_registry(
        topology: Topology,
        fabric: Arc<StorageFabric>,
        cfg: AggregationConfig,
        gate: Option<Arc<dyn FlushGate>>,
        metrics: Option<Arc<Metrics>>,
        registry: Option<Arc<VersionRegistry>>,
    ) -> Arc<Self> {
        Self::with_placement(topology, fabric, cfg, gate, metrics, registry, None)
    }

    /// Full constructor: registry recording plus adaptive tier placement
    /// for the container drains (the runtime's entry point).
    pub fn with_placement(
        topology: Topology,
        fabric: Arc<StorageFabric>,
        cfg: AggregationConfig,
        gate: Option<Arc<dyn FlushGate>>,
        metrics: Option<Arc<Metrics>>,
        registry: Option<Arc<VersionRegistry>>,
        placement: Option<Arc<PlacementEngine>>,
    ) -> Arc<Self> {
        let n = Self::group_count(&topology, &cfg);
        let groups = (0..n).map(|_| Mutex::new(GroupBuffer::default())).collect();
        let seq0 = Self::seed_seq(&fabric, &cfg, placement.as_deref());
        Arc::new(Aggregator {
            topology,
            fabric,
            cfg,
            gate,
            metrics,
            tracer: Mutex::new(None),
            placement,
            registry,
            groups,
            index: Mutex::new(SegmentIndex::new()),
            cold_sync: Mutex::new(false),
            fault_hook: Mutex::new(None),
            seq: AtomicU64::new(seq0),
            containers: AtomicU64::new(0),
            segments: AtomicU64::new(0),
            payload_bytes: AtomicU64::new(0),
            written_bytes: AtomicU64::new(0),
        })
    }

    /// The aggregation knobs this instance runs under.
    pub fn config(&self) -> &AggregationConfig {
        &self.cfg
    }

    /// Install (or clear) the fault-point hook — scenario-engine
    /// instrumentation, never set in production.
    pub fn set_fault_hook(&self, hook: Option<AggFaultHook>) {
        *self.fault_hook.lock().unwrap() = hook;
    }

    fn fault_at(&self, point: &str) -> bool {
        let hook = self.fault_hook.lock().unwrap().clone();
        hook.map(|h| h(point)).unwrap_or(false)
    }

    /// First free container sequence number: one past the highest
    /// `agg.g*.c<seq>` already on any candidate tier, so that a restarted
    /// runtime over a persistent backing never overwrites durable
    /// containers from a previous run (placement may have scattered them
    /// across the pool).
    fn seed_seq(
        fabric: &StorageFabric,
        cfg: &AggregationConfig,
        placement: Option<&PlacementEngine>,
    ) -> u64 {
        let tiers: Vec<Arc<StorageTier>> = match placement {
            Some(p) => p.tiers().to_vec(),
            None => match cfg.target {
                AggTarget::Pfs => vec![Arc::clone(fabric.pfs())],
                AggTarget::BurstBuffer => match fabric.burst_buffer() {
                    Some(t) => vec![Arc::clone(t)],
                    None => return 0,
                },
            },
        };
        tiers
            .iter()
            .flat_map(|t| t.list("agg.g"))
            .filter_map(|k| {
                k.rsplit_once(".c").and_then(|(_, s)| s.parse::<u64>().ok())
            })
            .max()
            .map(|m| m + 1)
            .unwrap_or(0)
    }

    fn group_count(topology: &Topology, cfg: &AggregationConfig) -> usize {
        if cfg.group_ranks == 0 {
            topology.nodes
        } else {
            topology.world_size().div_ceil(cfg.group_ranks)
        }
    }

    /// Aggregation group of a rank: its node, or `rank / group_ranks`.
    pub fn group_of(&self, rank: usize) -> usize {
        if self.cfg.group_ranks == 0 {
            self.topology.node_of(rank)
        } else {
            rank / self.cfg.group_ranks
        }
    }

    /// Number of ranks belonging to a group (the version-barrier quorum).
    pub fn group_size(&self, group: usize) -> usize {
        if self.cfg.group_ranks == 0 {
            self.topology.ranks_per_node
        } else {
            let start = group * self.cfg.group_ranks;
            self.topology
                .world_size()
                .saturating_sub(start)
                .min(self.cfg.group_ranks)
        }
    }

    fn target_tier(&self) -> Result<&Arc<StorageTier>> {
        match self.cfg.target {
            AggTarget::Pfs => Ok(self.fabric.pfs()),
            AggTarget::BurstBuffer => self
                .fabric
                .burst_buffer()
                .ok_or_else(|| anyhow!("aggregation targets burst-buffer but the fabric has none")),
        }
    }

    /// Candidate tiers a container (or the persisted index) may live on:
    /// the placement pool, or just the fixed target.
    fn pool_tiers(&self) -> Result<Vec<Arc<StorageTier>>> {
        match &self.placement {
            Some(p) => Ok(p.tiers().to_vec()),
            None => Ok(vec![Arc::clone(self.target_tier()?)]),
        }
    }

    /// Home of shared aggregation metadata (the persisted index): the
    /// placement primary, or the fixed target.
    fn index_tier(&self) -> Result<Arc<StorageTier>> {
        match &self.placement {
            Some(p) => Ok(Arc::clone(p.primary())),
            None => Ok(Arc::clone(self.target_tier()?)),
        }
    }

    /// Buffered-but-undrained payload bytes across all groups.
    pub fn pending_bytes(&self) -> u64 {
        self.groups
            .iter()
            .map(|g| g.lock().unwrap().bytes)
            .sum()
    }

    /// Is any segment of `name` still buffered (not yet drained)?
    pub fn has_pending(&self, name: &str) -> bool {
        self.groups.iter().any(|g| {
            g.lock()
                .unwrap()
                .pending
                .iter()
                .any(|p| p.name == name)
        })
    }

    /// Cumulative accounting snapshot.
    pub fn report(&self) -> AggregationReport {
        AggregationReport {
            containers: self.containers.load(Ordering::Relaxed),
            segments: self.segments.load(Ordering::Relaxed),
            payload_bytes: self.payload_bytes.load(Ordering::Relaxed),
            written_bytes: self.written_bytes.load(Ordering::Relaxed),
        }
    }

    /// Absorb one rank's encoded checkpoint. Buffers it in the rank's group
    /// and drains the group inline when a policy triggers (the caller is
    /// the active-backend flush thread, so inline drains keep the paper's
    /// async property: the application never blocks on the shared tier).
    pub fn submit(
        &self,
        name: &str,
        version: u64,
        rank: usize,
        encoding: &str,
        data: Bytes,
    ) -> Result<SubmitStat> {
        let g = self.group_of(rank);
        let bytes = data.len() as u64;
        let mut guard = self.groups[g].lock().unwrap();
        let buf = &mut *guard;
        // Re-submitted (name, version, rank) replaces its pending copy —
        // duplicate-version overwrite keeps last-writer-wins semantics.
        if let Some(p) = buf
            .pending
            .iter_mut()
            .find(|p| p.rank == rank && p.version == version && p.name == name)
        {
            buf.bytes = buf.bytes - p.data.len() as u64 + bytes;
            p.encoding = encoding.to_string();
            p.data = data;
        } else {
            buf.pending.push(PendingSegment {
                name: name.to_string(),
                version,
                rank,
                encoding: encoding.to_string(),
                data,
            });
            buf.bytes += bytes;
            if buf.first_at.is_none() {
                buf.first_at = Some(Instant::now());
            }
        }
        let over_size = buf.bytes >= self.cfg.flush_bytes;
        let over_age = buf
            .first_at
            .map(|t| t.elapsed() >= self.cfg.max_delay)
            .unwrap_or(false);
        let barrier = self.cfg.version_barrier
            && buf.count_version(name, version) >= self.group_size(g);
        if over_size || over_age || barrier {
            let stat = self.drain_locked(g, buf)?;
            return Ok(SubmitStat {
                bytes,
                modeled: stat.modeled,
                drained: true,
            });
        }
        Ok(SubmitStat {
            bytes,
            modeled: Duration::ZERO,
            drained: false,
        })
    }

    /// Drain every group whose buffer satisfies `should_drain`. One
    /// group's failed drain must not leave later groups buffered: every
    /// matching group is attempted, and the first error is reported after.
    fn drain_matching(
        &self,
        should_drain: impl Fn(&GroupBuffer) -> bool,
    ) -> Result<DrainStat> {
        let mut total = DrainStat::default();
        let mut first_err = None;
        let tracer = self.live_tracer();
        for g in 0..self.groups.len() {
            let mut buf = self.groups[g].lock().unwrap();
            if !should_drain(&*buf) {
                continue;
            }
            let span = match (&tracer, buf.pending.is_empty()) {
                (Some(t), false) => {
                    let gs = g.to_string();
                    let ss = buf.pending.len().to_string();
                    t.open(
                        "agg.drain",
                        crate::obs::SpanId::NONE,
                        &[("group", gs.as_str()), ("segments", ss.as_str())],
                        g as u64,
                    )
                }
                _ => crate::obs::SpanId::NONE,
            };
            let t0 = Instant::now();
            let res = self.drain_locked(g, &mut buf);
            if let Some(t) = &tracer {
                t.close(span);
            }
            match res {
                Ok(stat) => {
                    if stat.containers > 0 {
                        if let Some(m) = &self.metrics {
                            m.observe_hist_duration("agg.drain", &[], t0.elapsed());
                        }
                    }
                    total.absorb(stat);
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(total),
        }
    }

    /// Attach the runtime's span recorder after construction.
    pub fn set_tracer(&self, tracer: Arc<crate::obs::TraceRecorder>) {
        *self.tracer.lock().unwrap() = Some(tracer);
    }

    /// The recorder, only when attached and enabled.
    fn live_tracer(&self) -> Option<Arc<crate::obs::TraceRecorder>> {
        let g = self.tracer.lock().unwrap();
        match &*g {
            Some(t) if t.is_enabled() => Some(Arc::clone(t)),
            _ => None,
        }
    }

    /// Drain every non-empty group buffer (runtime `drain()` / barriers).
    pub fn flush_all(&self) -> Result<DrainStat> {
        self.drain_matching(|_| true)
    }

    /// Drain only groups whose oldest segment exceeded the age threshold
    /// (for callers running a periodic tick).
    pub fn flush_aged(&self) -> Result<DrainStat> {
        self.drain_matching(|buf| {
            buf.first_at
                .map(|t| t.elapsed() >= self.cfg.max_delay)
                .unwrap_or(false)
        })
    }

    /// Pack the buffer into one container, pace it through the scheduler
    /// gate, publish it on the target tier, update + persist the index.
    ///
    /// Runs under the group lock, so concurrent submits to the *same*
    /// group serialize behind the paced write — deliberate: it models one
    /// aggregator writer per group, and only backend flush threads wait
    /// here, never the application (submit is always called from the
    /// async pipeline tail). Releasing the lock mid-drain would open a
    /// window where a segment is neither buffered nor indexed.
    fn drain_locked(&self, group: usize, buf: &mut GroupBuffer) -> Result<DrainStat> {
        if buf.pending.is_empty() {
            return Ok(DrainStat::default());
        }
        let metas: Vec<SegmentMeta> = buf
            .pending
            .iter()
            .map(|p| SegmentMeta {
                name: p.name.clone(),
                version: p.version,
                rank: p.rank,
                len: p.data.len(),
                encoding: p.encoding.clone(),
                crc: crc32fast::hash(&p.data),
            })
            .collect();
        // Claim a container key no *reachable* tier already holds:
        // seed_seq cannot see containers behind a tier that was down at
        // construction, so a blind sequence restart could otherwise
        // overwrite a durable container once that tier recovers. The
        // probe re-checks at drain time, when the tier may be back.
        let (id, key) = loop {
            let seq = self.seq.fetch_add(1, Ordering::SeqCst);
            let id = format!("g{group}.c{seq}");
            let key = format!("agg.{id}");
            if self.pool_tiers()?.iter().all(|t| !t.exists(&key)) {
                break (id, key);
            }
        };
        // Scatter-gather encode: serialize only the container prefix
        // (magic + header) and the trailing CRC, then hand the vectored
        // parts [prefix, seg0, seg1, ..., crc] straight to the tier — the
        // buffered segment payloads are never concatenated into a staging
        // container. The streaming hasher reproduces exactly the CRC
        // `container::encode` would have appended.
        let prefix = container::encode_prefix(&id, group, &metas);
        let mut hasher = crc32fast::Hasher::new();
        hasher.update(&prefix);
        for p in &buf.pending {
            hasher.update(&p.data);
        }
        let crc_le = hasher.finalize().to_le_bytes();
        let body_len: usize = metas.iter().map(|m| m.len).sum();
        let total_len = prefix.len() + body_len + 4;
        // The drain writer is colocated with the group's buffers; use the
        // first buffered segment's rank to ask the gate whether a failure
        // landed on that node mid-drain.
        let writer_rank = buf.pending.first().map(|p| p.rank);
        // Pace the large sequential write chunk by chunk under the gate,
        // then publish atomically (same pattern as the direct flush). A
        // failure mid-drain abandons the container before the publish: the
        // segments stay buffered (and die with the node when it is wiped).
        if let Some(gate) = &self.gate {
            let mut off = 0;
            while off < total_len {
                gate.before_chunk(self.cfg.drain_chunk.min(total_len - off));
                if let Some(r) = writer_rank {
                    if gate.aborted_for(r) {
                        bail!(
                            "aggregated drain aborted: group {group} writer \
                             (rank {r}) failed mid-drain at offset {off}"
                        );
                    }
                }
                off += self.cfg.drain_chunk;
            }
        }
        let mut parts: Vec<&[u8]> = Vec::with_capacity(buf.pending.len() + 2);
        parts.push(&prefix);
        for p in &buf.pending {
            parts.push(&p.data);
        }
        parts.push(&crc_le);
        // Adaptive placement routes the container to the best eligible
        // shared tier (failing over past down/read-only/full ones) and
        // reports where it landed; the fixed target is the legacy path.
        let (dest, stat) = match &self.placement {
            Some(p) => p.put_gather(&key, &parts)?,
            None => {
                let tier = self.target_tier()?;
                (tier.id().to_string(), tier.put_gather(&key, &parts)?)
            }
        };
        drop(parts);
        let n = buf.pending.len() as u64;
        // Crash window: container durable, index not yet updated. A failure
        // landing here kills the writer after the publish — the buffered
        // segments die with the node, the in-memory/persisted index never
        // learns about the container, and recovery must rebuild the index
        // from the self-describing container headers.
        if self.fault_at(FAULT_PRE_INDEX) {
            buf.pending.clear();
            buf.bytes = 0;
            buf.first_at = None;
            return Ok(DrainStat {
                containers: 1,
                segments: n,
                written_bytes: stat.bytes,
                modeled: stat.modeled,
            });
        }
        // Index the freshly-published segments (recording the tier the
        // container landed on) and persist the index on the metadata
        // tier. Offsets are the cumulative meta lengths past the prefix —
        // the same arithmetic `ContainerHeader::segment_offset` performs —
        // so no header decode round-trip is needed. The put happens under
        // the index lock so that concurrent group drains cannot persist a
        // stale snapshot last.
        {
            let mut idx = self.index.lock().unwrap();
            let mut off = prefix.len();
            for m in &metas {
                idx.insert(
                    &m.name,
                    m.version,
                    m.rank,
                    SegmentLoc {
                        container: key.clone(),
                        offset: off,
                        len: m.len,
                        encoding: m.encoding.clone(),
                        crc: m.crc,
                        tier: dest.clone(),
                    },
                );
                off += m.len;
            }
            if let Ok(t) = self.index_tier() {
                let _ = t.put(INDEX_KEY, idx.to_json().to_string().as_bytes());
            }
        }
        // The segments just became durable on the shared tier: only now do
        // they count as level-4 complete (a buffered segment is volatile
        // node memory and must not unlock GC of older versions).
        if let Some(reg) = &self.registry {
            for m in &metas {
                reg.record_level_only(&m.name, m.version, m.rank, LEVEL_PFS, &m.encoding);
            }
        }
        self.containers.fetch_add(1, Ordering::Relaxed);
        self.segments.fetch_add(n, Ordering::Relaxed);
        self.payload_bytes.fetch_add(buf.bytes, Ordering::Relaxed);
        self.written_bytes.fetch_add(stat.bytes, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            m.incr("agg.containers", 1);
            m.incr("agg.segments", n);
            m.incr("agg.bytes.payload", buf.bytes);
            m.incr("agg.bytes.written", stat.bytes);
            m.observe("agg.container_bytes", stat.bytes as f64);
            m.observe_duration("agg.drain.modeled", stat.modeled);
        }
        buf.pending.clear();
        buf.bytes = 0;
        buf.first_at = None;
        Ok(DrainStat {
            containers: 1,
            segments: n,
            written_bytes: stat.bytes,
            modeled: stat.modeled,
        })
    }

    /// Fetch a segment payload via an index entry; None when the container
    /// is missing, truncated or fails the segment CRC. The recorded tier
    /// is tried first; a miss (failover re-drain, stale tier id, tier
    /// down) falls back to probing the whole pool.
    fn fetch(&self, loc: &SegmentLoc) -> Option<Vec<u8>> {
        let pool = self.pool_tiers().ok()?;
        let recorded = pool.iter().find(|t| t.id() == loc.tier);
        let (buf, _) = match recorded.and_then(|t| t.get(&loc.container)) {
            Some(hit) => hit,
            None => pool
                .iter()
                .filter(|t| Some(t.id()) != recorded.map(|r| r.id()))
                .find_map(|t| t.get(&loc.container))?,
        };
        // Checked bounds: a corrupt index entry must degrade to a miss
        // (then the header rebuild), never a slice panic. The last 4
        // container bytes are the trailing CRC, never payload.
        let end = loc.offset.checked_add(loc.len)?;
        if end.checked_add(4)? > buf.len() {
            return None;
        }
        let data = &buf[loc.offset..end];
        if crc32fast::hash(data) != loc.crc {
            return None;
        }
        Some(data.to_vec())
    }

    /// Restore one rank's encoded checkpoint payload. Resolution order:
    /// the rank's still-buffered segment, the in-memory index, the index
    /// persisted on the target tier, and finally a full rebuild from
    /// container headers (the lost-index path).
    pub fn restore(&self, name: &str, version: u64, rank: usize) -> Result<Option<Vec<u8>>> {
        // Still buffered: serve straight from memory.
        let g = self.group_of(rank);
        {
            let buf = self.groups[g].lock().unwrap();
            if let Some(p) = buf
                .pending
                .iter()
                .find(|p| p.rank == rank && p.version == version && p.name == name)
            {
                return Ok(Some(p.data.to_vec()));
            }
        }
        let lookup = |this: &Self| -> Option<SegmentLoc> {
            this.index.lock().unwrap().get(name, version, rank).cloned()
        };
        if let Some(loc) = lookup(self) {
            if let Some(data) = self.fetch(&loc) {
                return Ok(Some(data));
            }
        }
        // Cold-start fallbacks, once per aggregator and synchronized: the
        // first restorer merges the persisted index and, if that does not
        // resolve its segment, rebuilds from container headers; racers
        // block here until the sync completes, then retry their lookup.
        // Afterwards the in-memory index is authoritative (drains keep it
        // current), so later misses return immediately instead of
        // rescanning every container.
        {
            let mut synced = self.cold_sync.lock().unwrap();
            if !*synced {
                let mut resolved = false;
                if self.load_persisted_index().is_ok() {
                    if let Some(loc) = lookup(self) {
                        resolved = self.fetch(&loc).is_some();
                    }
                }
                // Even when the persisted index resolved this segment it
                // can be *stale*: index persists are best-effort, so a
                // drain that failed over while the metadata tier was
                // unwritable left containers no index entry points at.
                // Detect that from tier listings (metadata-only — no
                // container bodies are read) instead of assuming it.
                let stale = resolved && {
                    let known = self.index.lock().unwrap().container_keys();
                    self.pool_tiers()?
                        .iter()
                        .any(|t| t.list("agg.g").into_iter().any(|k| !known.contains(&k)))
                };
                if !resolved || stale {
                    // Persisted index lost, corrupt or stale: rebuild.
                    self.rebuild_index()?;
                }
                *synced = true;
            }
        }
        if let Some(loc) = lookup(self) {
            return Ok(self.fetch(&loc));
        }
        Ok(None)
    }

    /// Merge the persisted index object: the metadata tier first, then —
    /// placement only — any pool tier holding one (the metadata tier may
    /// have been down when the last drain persisted).
    fn load_persisted_index(&self) -> Result<()> {
        let mut candidates = vec![self.index_tier()?];
        for t in self.pool_tiers()? {
            if candidates.iter().all(|c| c.id() != t.id()) {
                candidates.push(t);
            }
        }
        let (bytes, _) = candidates
            .iter()
            .find_map(|t| t.get(INDEX_KEY))
            .ok_or_else(|| anyhow!("no persisted aggregation index"))?;
        let j = Json::parse(std::str::from_utf8(&bytes)?)
            .map_err(|e| anyhow!("aggregation index: {e}"))?;
        self.index.lock().unwrap().load_json(&j)
    }

    /// Rebuild the segment index by scanning container headers on every
    /// candidate tier (the containers are self-describing, so a lost index
    /// is never fatal — and placement may have scattered them across the
    /// pool). Scan results *merge over* the in-memory index rather than
    /// replacing it: entries whose tier is currently down are unreachable
    /// to the scan but still legitimate (fetchers validate CRCs, so a
    /// genuinely stale survivor degrades to a miss, never to bad data).
    /// Re-persists the merged index on the metadata tier. Returns how
    /// many segments the scan found.
    pub fn rebuild_index(&self) -> Result<usize> {
        let mut rebuilt = SegmentIndex::new();
        for tier in self.pool_tiers()? {
            for key in tier.list("agg.") {
                if key == INDEX_KEY {
                    continue;
                }
                let Some((bytes, _)) = tier.get(&key) else {
                    continue;
                };
                let Ok(header) = container::decode_header(&bytes) else {
                    continue; // unreadable container: skip, salvage the rest
                };
                for (i, m) in header.segments.iter().enumerate() {
                    rebuilt.insert(
                        &m.name,
                        m.version,
                        m.rank,
                        SegmentLoc {
                            container: key.clone(),
                            offset: header.segment_offset(i),
                            len: m.len,
                            encoding: m.encoding.clone(),
                            crc: m.crc,
                            tier: tier.id().to_string(),
                        },
                    );
                }
            }
        }
        let count = rebuilt.len();
        {
            let mut idx = self.index.lock().unwrap();
            idx.merge_from(rebuilt);
            if let Ok(t) = self.index_tier() {
                let _ = t.put(INDEX_KEY, idx.to_json().to_string().as_bytes());
            }
        }
        if let Some(m) = &self.metrics {
            m.incr("agg.index.rebuilds", 1);
        }
        Ok(count)
    }

    /// Drop a version from the in-memory index only (index hygiene; the
    /// persisted index and containers are untouched — see [`gc_version`]
    /// for actual space reclamation).
    ///
    /// [`gc_version`]: Aggregator::gc_version
    pub fn forget_version(&self, name: &str, version: u64) {
        self.index.lock().unwrap().remove_version(name, version);
    }

    /// Garbage-collect a version: drop its segments from the index and
    /// delete containers no segment references anymore (a container with a
    /// mix of live and stale versions survives until all go stale). The
    /// version module calls this when it prunes old versions, bounding
    /// shared-tier growth the same way the file-per-rank path does.
    pub fn gc_version(&self, name: &str, version: u64) -> Result<()> {
        // Durability ordering: while any segment of this name is still
        // buffered, the newer versions justifying the GC are not durable
        // yet — reclaiming older containers now could leave no restorable
        // version after a failure. Defer; the next GC pass reclaims.
        if self.has_pending(name) {
            return Ok(());
        }
        let pool = self.pool_tiers()?;
        let orphans = {
            let mut idx = self.index.lock().unwrap();
            let candidates = idx.containers_of_version(name, version);
            if candidates.is_empty() {
                return Ok(());
            }
            idx.remove_version(name, version);
            let orphans: Vec<(String, String)> = candidates
                .into_iter()
                .filter(|(k, tier)| !idx.references_container(k, tier))
                .collect();
            if let Ok(t) = self.index_tier() {
                let _ = t.put(INDEX_KEY, idx.to_json().to_string().as_bytes());
            }
            orphans
        };
        // Delete each orphan only where the index says it lives: a
        // container sequence restarted behind a down tier can produce the
        // same key on two tiers, and a pool-wide sweep would destroy the
        // other tier's still-live container. Entries without a recorded
        // tier (pre-placement indexes) fall back to the whole pool —
        // those indexes were written when only one target tier existed.
        for (key, tier_id) in &orphans {
            match pool.iter().find(|t| t.id() == tier_id.as_str()) {
                Some(tier) => {
                    tier.delete(key);
                }
                None => {
                    for tier in &pool {
                        tier.delete(key);
                    }
                }
            }
        }
        if let Some(m) = &self.metrics {
            m.incr("agg.containers.gc", orphans.len() as u64);
        }
        Ok(())
    }

    /// Model a node failure: segments still buffered for ranks of that
    /// node die with it — the write-combining buffer is node memory, so a
    /// restore must not be able to serve them (resilience fidelity).
    pub fn fail_node(&self, node: usize) {
        for g in &self.groups {
            let mut guard = g.lock().unwrap();
            let buf = &mut *guard;
            buf.pending
                .retain(|p| self.topology.node_of(p.rank) != node);
            buf.bytes = buf.pending.iter().map(|p| p.data.len() as u64).sum();
            if buf.pending.is_empty() {
                buf.first_at = None;
            }
        }
    }

    /// Model a full-system failure: every buffered segment is lost.
    pub fn fail_all_buffers(&self) {
        for g in &self.groups {
            let mut buf = g.lock().unwrap();
            buf.pending.clear();
            buf.bytes = 0;
            buf.first_at = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::FabricConfig;

    fn fabric(nodes: usize) -> Arc<StorageFabric> {
        Arc::new(
            StorageFabric::build(&FabricConfig {
                nodes,
                ..Default::default()
            })
            .unwrap(),
        )
    }

    fn agg(nodes: usize, rpn: usize, cfg: AggregationConfig) -> Arc<Aggregator> {
        Aggregator::new(Topology::new(nodes, rpn), fabric(nodes), cfg, None, None)
    }

    fn payload(rank: usize, version: u64) -> Bytes {
        Bytes::from(vec![(rank as u8) ^ (version as u8); 4096])
    }

    #[test]
    fn grouping_per_node_and_per_n_ranks() {
        let a = agg(4, 2, AggregationConfig::default());
        assert_eq!(a.group_of(0), 0);
        assert_eq!(a.group_of(3), 1);
        assert_eq!(a.group_size(0), 2);
        let cfg = AggregationConfig {
            group_ranks: 3,
            ..Default::default()
        };
        let a = agg(4, 2, cfg); // 8 ranks in groups of 3 -> 3 groups
        assert_eq!(a.groups.len(), 3);
        assert_eq!(a.group_of(5), 1);
        assert_eq!(a.group_size(0), 3);
        assert_eq!(a.group_size(2), 2, "tail group holds the remainder");
    }

    #[test]
    fn version_barrier_drains_when_group_completes() {
        let a = agg(2, 2, AggregationConfig::default());
        let s = a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        assert!(!s.drained, "half the group: keep buffering");
        assert_eq!(a.pending_bytes(), 4096);
        let s = a.submit("app", 1, 1, "raw", payload(1, 1)).unwrap();
        assert!(s.drained, "group complete for v1: drain");
        assert_eq!(a.pending_bytes(), 0);
        assert_eq!(a.report().containers, 1);
        assert_eq!(a.report().segments, 2);
    }

    #[test]
    fn size_threshold_drains() {
        let cfg = AggregationConfig {
            version_barrier: false,
            flush_bytes: 10_000,
            ..Default::default()
        };
        let a = agg(1, 4, cfg);
        assert!(!a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap().drained);
        assert!(!a.submit("app", 1, 1, "raw", payload(1, 1)).unwrap().drained);
        assert!(a.submit("app", 1, 2, "raw", payload(2, 1)).unwrap().drained);
    }

    #[test]
    fn flush_all_drains_stragglers() {
        let cfg = AggregationConfig {
            version_barrier: false,
            ..Default::default()
        };
        let a = agg(2, 1, cfg);
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        a.submit("app", 1, 1, "raw", payload(1, 1)).unwrap();
        assert_eq!(a.report().containers, 0);
        let stat = a.flush_all().unwrap();
        assert_eq!(stat.containers, 2, "one per node group");
        assert_eq!(a.pending_bytes(), 0);
    }

    #[test]
    fn restore_roundtrip_and_buffered_hit() {
        let a = agg(2, 1, AggregationConfig::default());
        // ranks_per_node = 1 => barrier quorum is 1, drains immediately.
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        let got = a.restore("app", 1, 0).unwrap().unwrap();
        assert_eq!(got, *payload(0, 1));
        // A buffered (undrained) segment is served from memory.
        let cfg = AggregationConfig {
            version_barrier: false,
            ..Default::default()
        };
        let a = agg(2, 1, cfg);
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        assert_eq!(a.report().containers, 0);
        assert_eq!(a.restore("app", 1, 0).unwrap().unwrap(), *payload(0, 1));
    }

    #[test]
    fn duplicate_submit_replaces_pending() {
        let cfg = AggregationConfig {
            version_barrier: false,
            ..Default::default()
        };
        let a = agg(1, 2, cfg);
        a.submit("app", 1, 0, "raw", Bytes::from(vec![1u8; 100])).unwrap();
        a.submit("app", 1, 0, "raw", Bytes::from(vec![2u8; 200])).unwrap();
        assert_eq!(a.pending_bytes(), 200);
        a.flush_all().unwrap();
        assert_eq!(a.restore("app", 1, 0).unwrap().unwrap(), vec![2u8; 200]);
    }

    #[test]
    fn cold_aggregator_restores_via_persisted_index() {
        let f = fabric(2);
        let topo = Topology::new(2, 1);
        let a = Aggregator::new(topo, Arc::clone(&f), AggregationConfig::default(), None, None);
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        a.submit("app", 1, 1, "raw", payload(1, 1)).unwrap();
        // Fresh aggregator over the same fabric: empty in-memory index.
        let b = Aggregator::new(topo, Arc::clone(&f), AggregationConfig::default(), None, None);
        assert_eq!(b.restore("app", 1, 1).unwrap().unwrap(), *payload(1, 1));
    }

    #[test]
    fn missing_index_rebuilt_from_headers() {
        let f = fabric(2);
        let topo = Topology::new(2, 1);
        let a = Aggregator::new(topo, Arc::clone(&f), AggregationConfig::default(), None, None);
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        assert!(f.pfs().delete(INDEX_KEY), "index object must exist");
        let b = Aggregator::new(topo, Arc::clone(&f), AggregationConfig::default(), None, None);
        assert_eq!(b.restore("app", 1, 0).unwrap().unwrap(), *payload(0, 1));
        // The rebuild re-persisted the index.
        assert!(f.pfs().exists(INDEX_KEY));
    }

    /// Placement-routed drains: a down primary fails the container over
    /// to the burst buffer, the index records the destination, and both
    /// warm and cold restores (header rebuild across the pool) serve it.
    #[test]
    fn placement_failover_drains_and_restores_across_pool() {
        use crate::storage::{FabricConfig, PlacementConfig, PlacementEngine};
        let f = Arc::new(
            StorageFabric::build(&FabricConfig {
                nodes: 2,
                with_burst_buffer: true,
                ..Default::default()
            })
            .unwrap(),
        );
        let topo = Topology::new(2, 1);
        let placement = || {
            PlacementEngine::new(
                f.shared_tiers(),
                PlacementConfig {
                    enabled: true,
                    ..Default::default()
                },
                None,
            )
            .unwrap()
        };
        let a = Aggregator::with_placement(
            topo,
            Arc::clone(&f),
            AggregationConfig::default(),
            None,
            None,
            None,
            Some(placement()),
        );
        f.pfs().set_down(true);
        // rpn=1 => barrier quorum 1: the submit drains immediately and
        // must land on the burst buffer.
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        assert_eq!(f.pfs().list("agg.g").len(), 0);
        assert_eq!(f.burst_buffer().unwrap().list("agg.g").len(), 1);
        assert_eq!(a.restore("app", 1, 0).unwrap().unwrap(), *payload(0, 1));
        // Cold aggregator with the primary still down: no persisted
        // index reachable, so the rebuild must scan the whole pool.
        let b = Aggregator::with_placement(
            topo,
            Arc::clone(&f),
            AggregationConfig::default(),
            None,
            None,
            None,
            Some(placement()),
        );
        assert_eq!(b.restore("app", 1, 0).unwrap().unwrap(), *payload(0, 1));
        // Primary back up: a later drain goes to the pfs again and both
        // containers stay restorable.
        f.pfs().set_down(false);
        a.submit("app", 2, 0, "raw", payload(0, 2)).unwrap();
        assert_eq!(f.pfs().list("agg.g").len(), 1);
        assert_eq!(a.restore("app", 2, 0).unwrap().unwrap(), *payload(0, 2));
        assert_eq!(a.restore("app", 1, 0).unwrap().unwrap(), *payload(0, 1));
    }

    #[test]
    fn burst_buffer_target_requires_tier() {
        let cfg = AggregationConfig {
            target: AggTarget::BurstBuffer,
            ..Default::default()
        };
        let a = agg(2, 1, cfg); // default fabric has no burst buffer
        assert!(a.submit("app", 1, 0, "raw", payload(0, 1)).is_err());
    }

    #[test]
    fn forget_version_removes_index_entries() {
        let a = agg(2, 1, AggregationConfig::default());
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        a.forget_version("app", 1);
        // In-memory miss, but the persisted index still resolves it; this
        // is a pure index-hygiene hook, not a data deletion.
        assert!(a.restore("app", 1, 0).unwrap().is_some());
    }

    #[test]
    fn gc_version_deletes_orphaned_containers() {
        let f = fabric(2);
        let topo = Topology::new(2, 1);
        let a = Aggregator::new(
            topo,
            Arc::clone(&f),
            AggregationConfig::default(),
            None,
            None,
        );
        // rpn=1 => barrier quorum 1: one container per submit.
        for v in 1..=2u64 {
            for r in 0..2 {
                a.submit("app", v, r, "raw", payload(r, v)).unwrap();
            }
        }
        assert_eq!(f.pfs().list("agg.g").len(), 4);
        a.gc_version("app", 1).unwrap();
        assert_eq!(
            f.pfs().list("agg.g").len(),
            2,
            "v1 containers must be reclaimed"
        );
        assert!(a.restore("app", 1, 0).unwrap().is_none());
        assert_eq!(a.restore("app", 2, 0).unwrap().unwrap(), *payload(0, 2));
    }

    #[test]
    fn gc_spares_containers_with_live_versions() {
        // version_barrier off + big thresholds: v1 and v2 of one rank end
        // up packed into the same container by flush_all.
        let cfg = AggregationConfig {
            version_barrier: false,
            ..Default::default()
        };
        let a = agg(2, 1, cfg);
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        a.submit("app", 2, 0, "raw", payload(0, 2)).unwrap();
        a.flush_all().unwrap();
        assert_eq!(a.report().containers, 1);
        a.gc_version("app", 1).unwrap();
        // Mixed container survives (v2 fetch succeeds through it); the
        // stale v1 segment inside may remain readable via a header
        // rebuild — GC is space reclamation, not secure deletion.
        assert_eq!(a.restore("app", 2, 0).unwrap().unwrap(), *payload(0, 2));
    }

    #[test]
    fn node_failure_drops_buffered_segments() {
        let cfg = AggregationConfig {
            version_barrier: false,
            ..Default::default()
        };
        let a = agg(2, 1, cfg);
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        a.submit("app", 1, 1, "raw", payload(1, 1)).unwrap();
        assert_eq!(a.pending_bytes(), 8192);
        a.fail_node(0);
        assert_eq!(a.pending_bytes(), 4096, "only node 0's segment dies");
        assert!(
            a.restore("app", 1, 0).unwrap().is_none(),
            "a buffered segment must not survive its node"
        );
        assert!(a.restore("app", 1, 1).unwrap().is_some());
        a.fail_all_buffers();
        assert_eq!(a.pending_bytes(), 0);
    }

    #[test]
    fn pre_index_crash_leaves_rebuildable_container() {
        use std::sync::atomic::AtomicBool;
        let f = fabric(2);
        let topo = Topology::new(2, 1);
        let a = Aggregator::new(topo, Arc::clone(&f), AggregationConfig::default(), None, None);
        // First wave drains and persists a healthy index.
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        // Arm a one-shot pre-index crash for the next drain.
        let fired = Arc::new(AtomicBool::new(false));
        let fired2 = Arc::clone(&fired);
        a.set_fault_hook(Some(Arc::new(move |point: &str| {
            point == FAULT_PRE_INDEX && !fired2.swap(true, Ordering::SeqCst)
        })));
        a.submit("app", 2, 0, "raw", payload(0, 2)).unwrap();
        assert!(fired.load(Ordering::SeqCst), "fault point must fire");
        // Buffer cleared (the writer died after publishing the container).
        assert_eq!(a.pending_bytes(), 0);
        // Container durable; index (in-memory and persisted) stale.
        assert_eq!(f.pfs().list("agg.g").len(), 2);
        // Same-process restore: the stale persisted index does not resolve
        // v2, so the cold-sync path rebuilds from container headers.
        assert_eq!(a.restore("app", 2, 0).unwrap().unwrap(), *payload(0, 2));
        // A cold aggregator resolves it too (rebuild re-persisted).
        let b = Aggregator::new(topo, Arc::clone(&f), AggregationConfig::default(), None, None);
        assert_eq!(b.restore("app", 2, 0).unwrap().unwrap(), *payload(0, 2));
    }

    #[test]
    fn corrupt_index_offsets_degrade_to_rebuild_not_panic() {
        let f = fabric(2);
        let topo = Topology::new(2, 1);
        let a = Aggregator::new(
            topo,
            Arc::clone(&f),
            AggregationConfig::default(),
            None,
            None,
        );
        a.submit("app", 1, 0, "raw", payload(0, 1)).unwrap();
        // Poison the persisted index with an overflowing offset, then ask
        // a cold aggregator: fetch must miss cleanly and the header
        // rebuild must serve the real bytes.
        let poisoned = format!(
            r#"{{"segments":[{{"name":"app","version":1,"rank":0,"container":"agg.g0.c0","offset":{},"len":4096,"encoding":"raw","crc":0}}]}}"#,
            usize::MAX - 1
        );
        f.pfs().put(INDEX_KEY, poisoned.as_bytes()).unwrap();
        let b = Aggregator::new(topo, Arc::clone(&f), AggregationConfig::default(), None, None);
        assert_eq!(b.restore("app", 1, 0).unwrap().unwrap(), *payload(0, 1));
    }
}
