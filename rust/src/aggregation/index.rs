//! Segment index: `(name, version, rank) → (container, offset, len)`.
//!
//! The index is the fast path for single-rank restores out of aggregated
//! containers (one `get` of the right container instead of scanning every
//! container header). It is persisted as a small JSON object next to the
//! containers; because the containers are self-describing, a lost or
//! corrupted index is never fatal — [`SegmentIndex::load_json`] failures
//! fall back to a rebuild from container headers (see
//! `Aggregator::rebuild_index`).

use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Storage key of the persisted index on the drain target tier.
pub const INDEX_KEY: &str = "agg.index.json";

/// Location of one rank's checkpoint payload inside a container.
#[derive(Clone, Debug, PartialEq)]
pub struct SegmentLoc {
    /// Storage key of the container holding the segment.
    pub container: String,
    /// Byte offset of the payload within the container.
    pub offset: usize,
    /// Payload length in bytes.
    pub len: usize,
    /// Payload encoding tag ("raw" or "zlib").
    pub encoding: String,
    /// CRC32 of the payload bytes.
    pub crc: u32,
    /// Id of the shared tier the container landed on. Empty in indexes
    /// written before adaptive placement (or rebuilt from an unknown
    /// tier); fetchers then probe the whole pool.
    pub tier: String,
}

/// In-memory index (callers serialize access; the aggregator wraps it in a
/// mutex).
#[derive(Default)]
pub struct SegmentIndex {
    entries: HashMap<(String, u64, usize), SegmentLoc>,
}

impl SegmentIndex {
    /// Empty index.
    pub fn new() -> Self {
        SegmentIndex::default()
    }

    /// Number of indexed segments.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the index empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert (or replace) a segment location.
    pub fn insert(&mut self, name: &str, version: u64, rank: usize, loc: SegmentLoc) {
        self.entries
            .insert((name.to_string(), version, rank), loc);
    }

    /// Look up one rank's segment location.
    pub fn get(&self, name: &str, version: u64, rank: usize) -> Option<&SegmentLoc> {
        self.entries.get(&(name.to_string(), version, rank))
    }

    /// Drop every segment of one (name, version).
    pub fn remove_version(&mut self, name: &str, version: u64) {
        self.entries
            .retain(|(n, v, _), _| !(n == name && *v == version));
    }

    /// `(container key, recorded tier id)` pairs holding at least one
    /// segment of (name, version). The tier id is empty for entries from
    /// pre-placement indexes.
    pub fn containers_of_version(&self, name: &str, version: u64) -> Vec<(String, String)> {
        let mut v: Vec<(String, String)> = self
            .entries
            .iter()
            .filter(|((n, ver, _), _)| n == name && *ver == version)
            .map(|(_, loc)| (loc.container.clone(), loc.tier.clone()))
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Does any live segment still point into this container *on this
    /// tier*? A restarted sequence behind a down tier can produce the
    /// same container key on two tiers, so liveness is per (key, tier);
    /// empty tier ids (pre-placement indexes) match by key alone.
    pub fn references_container(&self, key: &str, tier: &str) -> bool {
        self.entries.values().any(|loc| {
            loc.container == key
                && (tier.is_empty() || loc.tier.is_empty() || loc.tier == tier)
        })
    }

    /// Drop everything.
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Merge another index's entries over this one (the other's entries
    /// win on conflicts — used by header rebuilds, whose scan of live
    /// containers is authoritative for everything it can reach).
    pub fn merge_from(&mut self, other: SegmentIndex) {
        self.entries.extend(other.entries);
    }

    /// Keys of every container the index references (staleness probes:
    /// a tier listing a container key the index does not know about
    /// means the index missed a drain).
    pub fn container_keys(&self) -> std::collections::BTreeSet<String> {
        self.entries.values().map(|l| l.container.clone()).collect()
    }

    /// Serialize for persistence alongside the containers.
    pub fn to_json(&self) -> Json {
        // Sort for a deterministic on-tier representation.
        let mut keys: Vec<_> = self.entries.keys().cloned().collect();
        keys.sort();
        let segments: Vec<Json> = keys
            .iter()
            .map(|k| {
                let loc = &self.entries[k];
                Json::obj()
                    .set("name", k.0.as_str())
                    .set("version", k.1)
                    .set("rank", k.2)
                    .set("container", loc.container.as_str())
                    .set("offset", loc.offset as u64)
                    .set("len", loc.len as u64)
                    .set("encoding", loc.encoding.as_str())
                    .set("crc", loc.crc as u64)
                    .set("tier", loc.tier.as_str())
            })
            .collect();
        Json::obj().set("segments", Json::Arr(segments))
    }

    /// Merge entries from a persisted index document. Fails on malformed
    /// documents (the caller then rebuilds from container headers).
    pub fn load_json(&mut self, j: &Json) -> Result<()> {
        for s in j
            .get("segments")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("index missing segments"))?
        {
            let name = s
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("index entry missing name"))?;
            let version = s
                .get("version")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("index entry missing version"))?;
            let rank = s
                .get("rank")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("index entry missing rank"))?;
            let loc = SegmentLoc {
                container: s
                    .get("container")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("index entry missing container"))?
                    .to_string(),
                offset: s
                    .get("offset")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("index entry missing offset"))?,
                len: s
                    .get("len")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("index entry missing len"))?,
                encoding: s.str_or("encoding", "raw").to_string(),
                crc: s.get("crc").and_then(Json::as_u64).unwrap_or(0) as u32,
                tier: s.str_or("tier", "").to_string(),
            };
            self.insert(name, version, rank, loc);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loc(container: &str, offset: usize) -> SegmentLoc {
        SegmentLoc {
            container: container.to_string(),
            offset,
            len: 64,
            encoding: "raw".to_string(),
            crc: 0xABCD,
            tier: "pfs".to_string(),
        }
    }

    #[test]
    fn insert_get_remove() {
        let mut idx = SegmentIndex::new();
        idx.insert("app", 1, 0, loc("agg.g0.c0", 32));
        idx.insert("app", 1, 1, loc("agg.g0.c0", 96));
        idx.insert("app", 2, 0, loc("agg.g0.c1", 32));
        assert_eq!(idx.len(), 3);
        assert_eq!(idx.get("app", 1, 1).unwrap().offset, 96);
        assert!(idx.get("app", 3, 0).is_none());
        idx.remove_version("app", 1);
        assert_eq!(idx.len(), 1);
        assert!(idx.get("app", 2, 0).is_some());
    }

    #[test]
    fn json_roundtrip() {
        let mut idx = SegmentIndex::new();
        idx.insert("app", 7, 3, loc("agg.g1.c4", 1024));
        let j = idx.to_json();
        let mut idx2 = SegmentIndex::new();
        idx2.load_json(&j).unwrap();
        assert_eq!(idx2.get("app", 7, 3), idx.get("app", 7, 3));
    }

    #[test]
    fn malformed_json_rejected() {
        let mut idx = SegmentIndex::new();
        assert!(idx.load_json(&Json::obj()).is_err());
        let j = Json::parse(r#"{"segments":[{"name":"a"}]}"#).unwrap();
        assert!(idx.load_json(&j).is_err());
    }
}
