//! Crash-durable flight recorder: a bounded on-disk ring of structured
//! records (spans, state transitions, injection events, queue edges,
//! signals snapshots) that survives the process that wrote it.
//!
//! The in-memory span ring and `/metrics` endpoint evaporate with the
//! daemon — precisely the moment an exascale operator needs them. The
//! flight recorder is the post-mortem twin: every record is appended to
//! `<dir>/<process>.vfr` as a CRC-trailed binary frame, the file is
//! bounded by segment rotation (`.vfr` → `.vfr.old`, one previous
//! generation kept), and the reader tolerates a torn tail the same way
//! the journal WAL does — it returns the valid prefix and names where it
//! stopped, never panicking and never allocating off an untrusted length
//! (the PR 9 hostile-parser contract; `rust/tests/hostile.rs` sweeps the
//! scanner with the full `sim/corrupt` mutation catalog).
//!
//! Frame layout, after the 8-byte file header (`b"VFR1"` + LE u32
//! format version):
//!
//! ```text
//! [u32 len][u8 kind][u64 t_us][body: len-9 bytes][u32 crc32]
//! ```
//!
//! `len` counts kind + timestamp + body and is bounded by
//! [`MAX_FRAME`]; the CRC covers the same range. Timestamps are unix
//! microseconds so streams from different processes merge into one
//! causal timeline. Bodies are UTF-8 JSON — self-describing enough for
//! `veloc postmortem` to render a dump from a build that no longer
//! matches the writer.

use crate::obs::signals::SignalsSnapshot;
use crate::obs::span::SpanRec;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{SystemTime, UNIX_EPOCH};

/// File header magic; bump the trailing digit on incompatible layout
/// changes.
pub const FLIGHT_MAGIC: &[u8; 4] = b"VFR1";
/// On-disk format version written after the magic.
pub const FLIGHT_VERSION: u32 = 1;
/// Hard bound on one frame's payload (kind + timestamp + body): a
/// hostile or torn length field can never drive a larger allocation.
pub const MAX_FRAME: usize = 1 << 20;
/// Default per-stream size bound before segment rotation.
pub const FLIGHT_MAX_BYTES_DEFAULT: u64 = 8 << 20;
/// Flight stream file extension.
pub const FLIGHT_EXT: &str = "vfr";

const HEADER_LEN: usize = 8;
/// kind byte + u64 timestamp.
const FRAME_FIXED: usize = 9;

/// Record kind discriminants (the frame's kind byte).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlightKind {
    /// Stream metadata: process name, pid, wall-clock start. Written at
    /// every open, so one file appended by two daemon incarnations
    /// carries one meta record per segment.
    Meta,
    /// A closed span mirrored from the in-memory [`super::TraceRecorder`].
    Span,
    /// A state transition / injection / queue edge instant.
    Event,
    /// A persisted [`SignalsSnapshot`].
    Signals,
}

impl FlightKind {
    fn from_byte(b: u8) -> Option<FlightKind> {
        match b {
            0 => Some(FlightKind::Meta),
            1 => Some(FlightKind::Span),
            2 => Some(FlightKind::Event),
            3 => Some(FlightKind::Signals),
            _ => None,
        }
    }

    fn byte(self) -> u8 {
        match self {
            FlightKind::Meta => 0,
            FlightKind::Span => 1,
            FlightKind::Event => 2,
            FlightKind::Signals => 3,
        }
    }

    /// Stable lowercase name (postmortem rendering, verify reports).
    pub fn name(self) -> &'static str {
        match self {
            FlightKind::Meta => "meta",
            FlightKind::Span => "span",
            FlightKind::Event => "event",
            FlightKind::Signals => "signals",
        }
    }
}

/// Current unix time in microseconds.
pub fn unix_us() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

#[derive(Debug)]
struct FlightFile {
    file: File,
    written: u64,
    /// Highest frame timestamp appended so far; appends clamp against it
    /// so the stream stays monotone even when writers race to the lock
    /// or a span's close is recorded after a later event.
    last_t: u64,
}

/// Append-only, size-bounded writer for one process's flight stream.
/// Cheap to share (`Arc`); all methods are best-effort — a full disk
/// must degrade observability, never the checkpoint path — with dropped
/// writes counted in [`FlightRecorder::lost`].
#[derive(Debug)]
pub struct FlightRecorder {
    dir: PathBuf,
    process: String,
    max_bytes: u64,
    inner: Mutex<FlightFile>,
    lost: AtomicU64,
}

impl FlightRecorder {
    /// Open (creating or appending) `<dir>/<process>.vfr` and write a
    /// meta record for this incarnation.
    pub fn open(dir: &Path, process: &str, max_bytes: u64) -> Result<Arc<FlightRecorder>> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("flight: create {}", dir.display()))?;
        let path = dir.join(format!("{process}.{FLIGHT_EXT}"));
        let fresh = !path.exists();
        let mut file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("flight: open {}", path.display()))?;
        if fresh {
            let mut header = Vec::with_capacity(HEADER_LEN);
            header.extend_from_slice(FLIGHT_MAGIC);
            header.extend_from_slice(&FLIGHT_VERSION.to_le_bytes());
            file.write_all(&header)?;
        }
        let written = file.metadata().map(|m| m.len()).unwrap_or(0);
        let rec = Arc::new(FlightRecorder {
            dir: dir.to_path_buf(),
            process: process.to_string(),
            max_bytes: max_bytes.max(4096),
            inner: Mutex::new(FlightFile {
                file,
                written,
                last_t: 0,
            }),
            lost: AtomicU64::new(0),
        });
        rec.append(
            FlightKind::Meta,
            unix_us(),
            &Json::obj()
                .set("process", process)
                .set("pid", std::process::id() as u64)
                .set("start_unix_us", unix_us()),
        );
        Ok(rec)
    }

    /// The stream this recorder appends to.
    pub fn path(&self) -> PathBuf {
        self.dir.join(format!("{}.{FLIGHT_EXT}", self.process))
    }

    /// The directory holding this stream (and its peers).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Records dropped because of I/O errors or oversized bodies.
    pub fn lost(&self) -> u64 {
        self.lost.load(Ordering::Relaxed)
    }

    /// Record an instantaneous event (state transition, injection,
    /// queue/backpressure edge).
    pub fn event(&self, name: &str, labels: &[(&str, &str)]) {
        let mut body = Json::obj().set("name", name);
        for (k, v) in labels {
            body = body.set(k, *v);
        }
        self.append(FlightKind::Event, unix_us(), &body);
    }

    /// Record an event from a pre-built JSON body (the sim runner mirrors
    /// its trace events this way). A body carrying an `ev` key but no
    /// `name` is normalized to `name = "sim.<ev>"`, so the post-mortem
    /// timeline renders trace events uniformly.
    pub fn event_json(&self, body: &Json) {
        let named = match (body.get("name"), body.get("ev").and_then(Json::as_str)) {
            (None, Some(ev)) => body.clone().set("name", format!("sim.{ev}")),
            _ => body.clone(),
        };
        self.append(FlightKind::Event, unix_us(), &named);
    }

    /// Mirror one span — an open edge (no `end_us`) or a finished span.
    /// `unix_offset_us` converts the recorder's epoch-relative
    /// microseconds to unix microseconds (the tracer computes it once
    /// when the sink is attached). The frame is stamped at record time
    /// (close time for finished spans), so stream order stays monotone.
    pub fn span(&self, s: &SpanRec, unix_offset_us: u64) {
        let start = s.start_us.saturating_add(unix_offset_us);
        let mut labels = Json::obj();
        for (k, v) in &s.labels {
            labels = labels.set(k, v.as_str());
        }
        let mut body = Json::obj()
            .set("id", s.id)
            .set("parent", s.parent)
            .set("name", s.name.as_str())
            .set("start_us", start)
            .set("tid", s.tid)
            .set("instant", s.instant)
            .set("labels", labels);
        let mut stamp = start;
        if let Some(end) = s.end_us {
            let end = end.saturating_add(unix_offset_us);
            body = body.set("end_us", end);
            stamp = end;
        }
        self.append(FlightKind::Span, stamp, &body);
    }

    /// Persist a signals snapshot into the stream.
    pub fn signals(&self, snap: &SignalsSnapshot) {
        self.append(FlightKind::Signals, snap.taken_us, &snap.to_json());
    }

    /// Flush and fsync the stream (the daemon calls this on crash and
    /// shutdown paths; records in between are one buffered write each).
    pub fn flush(&self) {
        let inner = self.inner.lock().unwrap();
        let _ = inner.file.sync_all();
    }

    fn append(&self, kind: FlightKind, t_us: u64, body: &Json) {
        let text = body.to_string().into_bytes();
        if FRAME_FIXED + text.len() > MAX_FRAME {
            self.lost.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let len = (FRAME_FIXED + text.len()) as u32;

        let mut inner = self.inner.lock().unwrap();
        if inner.written + (4 + len as u64 + 4) > self.max_bytes {
            if self.rotate(&mut inner).is_err() {
                self.lost.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        // Clamp the frame stamp monotone under the append lock: callers
        // compute their timestamps outside it, so two racing writers (or
        // a span close recorded after a later event) would otherwise
        // leave a regression for `verify` to trip on. Record bodies keep
        // their true times; only the frame ordering stamp is clamped.
        let t = t_us.max(inner.last_t);
        inner.last_t = t;
        let mut frame = Vec::with_capacity(4 + len as usize + 4);
        frame.extend_from_slice(&len.to_le_bytes());
        frame.push(kind.byte());
        frame.extend_from_slice(&t.to_le_bytes());
        frame.extend_from_slice(&text);
        let crc = crc32fast::hash(&frame[4..]);
        frame.extend_from_slice(&crc.to_le_bytes());
        match inner.file.write_all(&frame) {
            Ok(()) => inner.written += frame.len() as u64,
            Err(_) => {
                self.lost.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Segment rotation: the current stream becomes `.vfr.old` (replacing
    /// any previous generation) and a fresh segment starts with a header
    /// and meta record. Two generations bound the ring at ~2x
    /// `max_bytes` while always retaining the newest records.
    fn rotate(&self, inner: &mut FlightFile) -> Result<()> {
        let path = self.path();
        let old = path.with_extension(format!("{FLIGHT_EXT}.old"));
        let _ = inner.file.sync_all();
        std::fs::rename(&path, &old)?;
        let mut file = OpenOptions::new().create(true).append(true).open(&path)?;
        let mut header = Vec::with_capacity(HEADER_LEN);
        header.extend_from_slice(FLIGHT_MAGIC);
        header.extend_from_slice(&FLIGHT_VERSION.to_le_bytes());
        file.write_all(&header)?;
        inner.file = file;
        inner.written = HEADER_LEN as u64;
        // A fresh segment re-identifies its process.
        let meta_t = unix_us().max(inner.last_t);
        inner.last_t = meta_t;
        let meta = Json::obj()
            .set("process", self.process.as_str())
            .set("pid", std::process::id() as u64)
            .set("start_unix_us", meta_t);
        let text = meta.to_string().into_bytes();
        let len = (FRAME_FIXED + text.len()) as u32;
        let mut frame = Vec::with_capacity(4 + len as usize + 4);
        frame.extend_from_slice(&len.to_le_bytes());
        frame.push(FlightKind::Meta.byte());
        frame.extend_from_slice(&meta_t.to_le_bytes());
        frame.extend_from_slice(&text);
        let crc = crc32fast::hash(&frame[4..]);
        frame.extend_from_slice(&crc.to_le_bytes());
        inner.file.write_all(&frame)?;
        inner.written += frame.len() as u64;
        Ok(())
    }
}

// ------------------------------------------------------------- reader

/// One decoded record, tagged with the process that wrote it (from the
/// nearest preceding meta record in its stream).
#[derive(Clone, Debug)]
pub struct FlightEntry {
    /// Writing process (empty until the stream's first meta record).
    pub process: String,
    /// Writer pid from the same meta record.
    pub pid: u64,
    /// Record kind.
    pub kind: FlightKind,
    /// Unix microseconds.
    pub t_us: u64,
    /// Decoded JSON body.
    pub body: Json,
}

/// Result of scanning one stream: the valid prefix plus, when the scan
/// stopped early, the reason — a torn tail after a crash is expected and
/// is *not* an error.
#[derive(Clone, Debug, Default)]
pub struct FlightScan {
    /// Every record decoded before the first bad frame.
    pub entries: Vec<FlightEntry>,
    /// Why the scan stopped before the end of the input, if it did.
    pub truncated: Option<String>,
    /// Bytes consumed by valid frames (including the file header).
    pub bytes_scanned: u64,
}

/// Scan one stream image. Never panics; every allocation is bounded by
/// [`MAX_FRAME`] and the input length — hostile length fields stop the
/// scan instead of sizing a buffer.
pub fn scan_bytes(data: &[u8]) -> FlightScan {
    let mut scan = FlightScan::default();
    if data.len() < HEADER_LEN || &data[..4] != FLIGHT_MAGIC {
        scan.truncated = Some("missing VFR1 header".to_string());
        return scan;
    }
    let ver = u32::from_le_bytes(data[4..8].try_into().unwrap());
    if ver != FLIGHT_VERSION {
        scan.truncated = Some(format!("unsupported format version {ver}"));
        return scan;
    }
    let mut off = HEADER_LEN;
    let (mut process, mut pid) = (String::new(), 0u64);
    loop {
        if off == data.len() {
            break; // clean end
        }
        if data.len() - off < 4 {
            scan.truncated = Some(format!("torn length field at offset {off}"));
            break;
        }
        let len = u32::from_le_bytes(data[off..off + 4].try_into().unwrap()) as usize;
        if !(FRAME_FIXED..=MAX_FRAME).contains(&len) {
            scan.truncated = Some(format!("frame length {len} out of bounds at offset {off}"));
            break;
        }
        if data.len() - off < 4 + len + 4 {
            scan.truncated = Some(format!("torn frame at offset {off}"));
            break;
        }
        let payload = &data[off + 4..off + 4 + len];
        let stored = u32::from_le_bytes(data[off + 4 + len..off + 8 + len].try_into().unwrap());
        if crc32fast::hash(payload) != stored {
            scan.truncated = Some(format!("crc mismatch at offset {off}"));
            break;
        }
        let Some(kind) = FlightKind::from_byte(payload[0]) else {
            scan.truncated = Some(format!("unknown record kind {} at offset {off}", payload[0]));
            break;
        };
        let t_us = u64::from_le_bytes(payload[1..9].try_into().unwrap());
        let Ok(text) = std::str::from_utf8(&payload[9..]) else {
            scan.truncated = Some(format!("non-UTF-8 body at offset {off}"));
            break;
        };
        let Ok(body) = Json::parse(text) else {
            scan.truncated = Some(format!("malformed body at offset {off}"));
            break;
        };
        if kind == FlightKind::Meta {
            process = body.str_or("process", "").to_string();
            pid = body.get("pid").and_then(Json::as_u64).unwrap_or(0);
        }
        scan.entries.push(FlightEntry {
            process: process.clone(),
            pid,
            kind,
            t_us,
            body,
        });
        off += 4 + len + 4;
        scan.bytes_scanned = off as u64;
    }
    scan
}

/// Scan one stream file (I/O errors are the only hard failures).
pub fn scan_file(path: &Path) -> Result<FlightScan> {
    let data =
        std::fs::read(path).with_context(|| format!("flight: read {}", path.display()))?;
    Ok(scan_bytes(&data))
}

/// Read every flight stream under `dir` (the `.vfr.old` generation of a
/// stream is scanned before its current segment so rotation preserves
/// order). Returns `(path, scan)` per file, sorted by path.
pub fn read_dir(dir: &Path) -> Result<Vec<(PathBuf, FlightScan)>> {
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("flight: read dir {}", dir.display()))?
    {
        let p = entry?.path();
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.ends_with(&format!(".{FLIGHT_EXT}")) || name.ends_with(&format!(".{FLIGHT_EXT}.old"))
        {
            paths.push(p);
        }
    }
    // `<p>.vfr.old` sorts after `<p>.vfr` lexically; order by (stem, age)
    // so the old generation comes first.
    paths.sort_by_key(|p| {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
        let old = name.ends_with(".old");
        (name.trim_end_matches(".old").to_string(), !old)
    });
    let mut out = Vec::with_capacity(paths.len());
    for p in paths {
        let scan = scan_file(&p)?;
        out.push((p, scan));
    }
    Ok(out)
}

/// Merge scans into one cross-process timeline ordered by timestamp
/// (stable: ties keep per-stream order).
pub fn merge(scans: &[(PathBuf, FlightScan)]) -> Vec<FlightEntry> {
    let mut all: Vec<FlightEntry> = scans
        .iter()
        .flat_map(|(_, s)| s.entries.iter().cloned())
        .collect();
    all.sort_by_key(|e| e.t_us);
    all
}

/// Rebuild a [`SpanRec`] from a span-kind entry (postmortem analysis
/// feeds these straight into [`super::critpath`]).
pub fn entry_to_span(e: &FlightEntry) -> Option<SpanRec> {
    if e.kind != FlightKind::Span {
        return None;
    }
    let b = &e.body;
    let labels = b
        .get("labels")
        .and_then(Json::as_obj)
        .map(|m| {
            m.iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect()
        })
        .unwrap_or_default();
    Some(SpanRec {
        id: b.get("id").and_then(Json::as_u64)?,
        parent: b.get("parent").and_then(Json::as_u64).unwrap_or(0),
        name: b.str_or("name", "").to_string(),
        start_us: b.get("start_us").and_then(Json::as_u64)?,
        end_us: b.get("end_us").and_then(Json::as_u64),
        labels,
        tid: b.get("tid").and_then(Json::as_u64).unwrap_or(0),
        instant: b.bool_or("instant", false),
    })
}

/// `veloc postmortem --verify` report over one dump directory.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    /// Streams scanned.
    pub files: usize,
    /// Records across all streams.
    pub entries: usize,
    /// Span records.
    pub spans: usize,
    /// Event records.
    pub events: usize,
    /// Signals snapshots.
    pub snapshots: usize,
    /// Distinct writing processes.
    pub processes: Vec<String>,
    /// Streams that ended in a torn tail (expected after a crash).
    pub torn: usize,
    /// Acked submissions with no matching settle record — the work a
    /// crash left in flight (`backend.ack` without `backend.settle`).
    pub unsettled: Vec<Json>,
}

/// Check well-formedness of a dump: every stream leads with a meta
/// record, timestamps are monotonic within each meta segment, and span
/// parent/child links close (parents resolve within the stream and
/// children's intervals are sane). A torn tail is reported, not failed.
pub fn verify(scans: &[(PathBuf, FlightScan)]) -> Result<VerifyReport, String> {
    let mut report = VerifyReport {
        files: scans.len(),
        ..VerifyReport::default()
    };
    if scans.is_empty() {
        return Err("no flight streams found".to_string());
    }
    // Span ids per writing process, pooled across every segment: rotation
    // splits one logical stream over `.vfr.old` + `.vfr`, so a span's
    // parent may live in the previous generation.
    let mut span_ids: std::collections::BTreeMap<String, std::collections::BTreeSet<u64>> =
        std::collections::BTreeMap::new();
    for (_, scan) in scans {
        for e in &scan.entries {
            if e.kind == FlightKind::Span {
                if let Some(id) = e.body.get("id").and_then(Json::as_u64) {
                    span_ids.entry(e.process.clone()).or_default().insert(id);
                }
            }
        }
    }
    for (path, scan) in scans {
        let name = path.display();
        if scan.entries.is_empty() {
            return Err(format!("{name}: no decodable records"));
        }
        if scan.entries[0].kind != FlightKind::Meta {
            return Err(format!("{name}: stream does not lead with a meta record"));
        }
        if scan.truncated.is_some() {
            report.torn += 1;
        }
        let mut last_t = 0u64;
        for e in &scan.entries {
            if e.kind == FlightKind::Meta {
                // A new incarnation restarts the monotonic clock domain.
                last_t = e.t_us;
            } else if e.t_us < last_t {
                return Err(format!(
                    "{name}: timestamp regression {} -> {} ({})",
                    last_t,
                    e.t_us,
                    e.kind.name()
                ));
            } else {
                last_t = e.t_us;
            }
        }
        for e in &scan.entries {
            match e.kind {
                FlightKind::Span => {
                    report.spans += 1;
                    let s = entry_to_span(e)
                        .ok_or_else(|| format!("{name}: span record missing id/start"))?;
                    if let Some(end) = s.end_us {
                        if end < s.start_us {
                            return Err(format!(
                                "{name}: span {} ({}) ends before it starts",
                                s.id, s.name
                            ));
                        }
                    }
                    let resolved = s.parent == 0
                        || span_ids
                            .get(&e.process)
                            .is_some_and(|ids| ids.contains(&s.parent));
                    if !resolved {
                        return Err(format!(
                            "{name}: span {} ({}) has unresolved parent {}",
                            s.id, s.name, s.parent
                        ));
                    }
                }
                FlightKind::Event => report.events += 1,
                FlightKind::Signals => report.snapshots += 1,
                FlightKind::Meta => {}
            }
            report.entries += 1;
            if !e.process.is_empty() && !report.processes.contains(&e.process) {
                report.processes.push(e.process.clone());
            }
        }
    }
    report.unsettled = unsettled(&merge(scans));
    Ok(report)
}

/// Pair `backend.ack` events with their `backend.settle`: the leftovers
/// are the acked-but-unsettled submissions a crash stranded — exactly
/// what the journal replay must finish.
pub fn unsettled(entries: &[FlightEntry]) -> Vec<Json> {
    // Event labels arrive as strings; accept a numeric id too so hand-built
    // bodies pair the same way.
    fn id_of(body: &Json) -> Option<u64> {
        let id = body.get("id")?;
        id.as_u64().or_else(|| id.as_str()?.parse().ok())
    }
    let mut acked: std::collections::BTreeMap<u64, Json> = std::collections::BTreeMap::new();
    for e in entries {
        if e.kind != FlightKind::Event {
            continue;
        }
        match e.body.str_or("name", "") {
            "backend.ack" => {
                if let Some(id) = id_of(&e.body) {
                    acked.insert(id, e.body.clone());
                }
            }
            "backend.settle" => {
                if let Some(id) = id_of(&e.body) {
                    acked.remove(&id);
                }
            }
            _ => {}
        }
    }
    acked.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::signals::SignalsBus;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "veloc-flight-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn records_roundtrip_through_the_scanner() {
        let dir = tmp("roundtrip");
        let f = FlightRecorder::open(&dir, "daemon", FLIGHT_MAX_BYTES_DEFAULT).unwrap();
        f.event("backend.ack", &[("id", "7"), ("job", "train-a")]);
        let span = SpanRec {
            id: 3,
            parent: 0,
            name: "ckpt".to_string(),
            start_us: 10,
            end_us: Some(30),
            labels: vec![("rank".to_string(), "1".to_string())],
            tid: 1,
            instant: false,
        };
        f.span(&span, 1_000_000);
        let bus = SignalsBus::new(8);
        bus.sample("queue.depth", 4.0);
        f.signals(&bus.snapshot());
        f.flush();

        let scan = scan_file(&f.path()).unwrap();
        assert!(scan.truncated.is_none(), "{:?}", scan.truncated);
        assert_eq!(scan.entries.len(), 4); // meta + event + span + signals
        assert_eq!(scan.entries[0].kind, FlightKind::Meta);
        assert!(scan.entries.iter().all(|e| e.process == "daemon"));
        let ev = &scan.entries[1];
        assert_eq!(ev.body.str_or("name", ""), "backend.ack");
        let back = entry_to_span(&scan.entries[2]).unwrap();
        assert_eq!(back.name, "ckpt");
        assert_eq!(back.start_us, 1_000_010);
        assert_eq!(back.end_us, Some(1_000_030));
        assert_eq!(scan.entries[3].kind, FlightKind::Signals);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_yields_the_valid_prefix() {
        let dir = tmp("torn");
        let f = FlightRecorder::open(&dir, "client", FLIGHT_MAX_BYTES_DEFAULT).unwrap();
        for i in 0..5 {
            f.event("tick", &[("i", &i.to_string())]);
        }
        f.flush();
        let mut data = std::fs::read(f.path()).unwrap();
        data.truncate(data.len() - 3); // torn final frame
        let scan = scan_bytes(&data);
        assert_eq!(scan.entries.len(), 5); // meta + 4 intact ticks
        assert!(scan.truncated.is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotation_bounds_the_stream_and_keeps_one_old_generation() {
        let dir = tmp("rotate");
        let f = FlightRecorder::open(&dir, "sim", 4096).unwrap();
        let filler = "x".repeat(200);
        for _ in 0..200 {
            f.event("fill", &[("pad", &filler)]);
        }
        f.flush();
        let cur = std::fs::metadata(f.path()).unwrap().len();
        assert!(cur <= 4096, "current segment must stay bounded: {cur}");
        let old = f.path().with_extension(format!("{FLIGHT_EXT}.old"));
        assert!(old.exists(), "previous generation must be retained");

        // Both generations scan clean and the old one precedes the
        // current one in read_dir order.
        let scans = read_dir(&dir).unwrap();
        assert_eq!(scans.len(), 2);
        assert!(scans[0].0.to_string_lossy().ends_with(".old"));
        for (_, s) in &scans {
            assert!(s.truncated.is_none());
            assert_eq!(s.entries[0].kind, FlightKind::Meta);
        }
        assert_eq!(f.lost(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn second_incarnation_appends_a_new_meta_segment() {
        let dir = tmp("reopen");
        {
            let f = FlightRecorder::open(&dir, "daemon", FLIGHT_MAX_BYTES_DEFAULT).unwrap();
            f.event("daemon.start", &[]);
            f.flush();
        }
        let f2 = FlightRecorder::open(&dir, "daemon", FLIGHT_MAX_BYTES_DEFAULT).unwrap();
        f2.event("daemon.start", &[]);
        f2.flush();
        let scan = scan_file(&f2.path()).unwrap();
        let metas = scan
            .entries
            .iter()
            .filter(|e| e.kind == FlightKind::Meta)
            .count();
        assert_eq!(metas, 2, "one meta record per incarnation");
        let scans = vec![(f2.path(), scan)];
        verify(&scans).expect("two-segment stream must verify");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unsettled_pairs_acks_with_settles() {
        let dir = tmp("unsettled");
        let f = FlightRecorder::open(&dir, "daemon", FLIGHT_MAX_BYTES_DEFAULT).unwrap();
        f.event("backend.ack", &[("id", "1"), ("version", "5")]);
        f.event("backend.ack", &[("id", "2"), ("version", "6")]);
        f.event("backend.settle", &[("id", "1"), ("ok", "true")]);
        f.flush();
        let scans = read_dir(&dir).unwrap();
        let left = unsettled(&merge(&scans));
        assert_eq!(left.len(), 1);
        assert_eq!(left[0].str_or("id", ""), "2");
        assert_eq!(left[0].str_or("version", ""), "6");
        let report = verify(&scans).unwrap();
        assert_eq!(report.unsettled.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_rejects_unresolved_span_parents() {
        let dir = tmp("verify-parent");
        let f = FlightRecorder::open(&dir, "client", FLIGHT_MAX_BYTES_DEFAULT).unwrap();
        let orphan = SpanRec {
            id: 9,
            parent: 77, // never recorded
            name: "stage".to_string(),
            start_us: 5,
            end_us: Some(6),
            labels: Vec::new(),
            tid: 0,
            instant: false,
        };
        f.span(&orphan, 0);
        f.flush();
        let scans = read_dir(&dir).unwrap();
        let err = verify(&scans).unwrap_err();
        assert!(err.contains("unresolved parent"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hostile_length_fields_never_size_an_allocation() {
        // A frame claiming u32::MAX bytes must stop the scan, not drive
        // a huge Vec. Build a valid header + one bent length field.
        let mut data = Vec::new();
        data.extend_from_slice(FLIGHT_MAGIC);
        data.extend_from_slice(&FLIGHT_VERSION.to_le_bytes());
        data.extend_from_slice(&u32::MAX.to_le_bytes());
        data.extend_from_slice(&[0u8; 64]);
        let scan = scan_bytes(&data);
        assert!(scan.entries.is_empty());
        assert!(scan.truncated.unwrap().contains("out of bounds"));
    }
}
