//! Embedded HTTP exposition endpoint: `/metrics` (Prometheus text),
//! `/healthz` (liveness) and `/readyz` (readiness), served from a
//! background thread on a plain `std::net::TcpListener` — no HTTP
//! framework, the daemon only needs GET + fixed routes.
//!
//! The listener runs nonblocking with a short accept-poll sleep (the same
//! pattern as the daemon's IPC socket loop) so shutdown is prompt, and
//! binds `127.0.0.1:0`-style addresses for tests.

use crate::metrics::Metrics;
use crate::obs::prom;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Shared state the endpoint serves from.
#[derive(Clone)]
pub struct ObsState {
    /// Registry scraped by `/metrics`.
    pub metrics: Arc<Metrics>,
    /// Readiness flag for `/readyz` (daemon sets it after journal
    /// replay, once queues are accepting).
    pub ready: Arc<AtomicBool>,
}

/// Handle to a running observability HTTP server; dropping it stops the
/// accept loop.
pub struct ObsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ObsServer {
    /// Bind `bind` (e.g. `127.0.0.1:9090`, or port 0 for an ephemeral
    /// test port) and serve until stopped.
    pub fn start(bind: &str, state: ObsState) -> Result<ObsServer> {
        let listener =
            TcpListener::bind(bind).with_context(|| format!("obs: bind {bind}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("veloc-obs-http".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let _ = serve_one(stream, &state);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(10)),
                    }
                }
            })?;
        Ok(ObsServer {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves port 0 binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ObsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_one(mut stream: TcpStream, state: &ObsState) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    // Read until the end of the request head; GETs have no body.
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.split_whitespace();
    let method = parts.next().unwrap_or_default();
    let path = parts.next().unwrap_or_default();
    let (status, ctype, body) = route(method, path, state);
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    Ok(())
}

fn route(method: &str, path: &str, state: &ObsState) -> (&'static str, &'static str, String) {
    if method != "GET" {
        return ("405 Method Not Allowed", "text/plain", "method not allowed\n".into());
    }
    match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            prom::render(&state.metrics.snapshot()),
        ),
        "/healthz" => ("200 OK", "text/plain", "ok\n".into()),
        "/readyz" => {
            if state.ready.load(Ordering::Relaxed) {
                ("200 OK", "text/plain", "ready\n".into())
            } else {
                ("503 Service Unavailable", "text/plain", "not ready\n".into())
            }
        }
        _ => ("404 Not Found", "text/plain", "not found\n".into()),
    }
}

/// Minimal HTTP GET against the observability endpoint; returns
/// `(status code, body)`. Used by `veloc scrape`, tests and CI.
pub fn http_get(addr: &str, path: &str, timeout: Duration) -> Result<(u16, String)> {
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("obs: resolve {addr}"))?
        .next()
        .context("obs: no address")?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .context("obs: malformed HTTP response")?;
    let body = match resp.find("\r\n\r\n") {
        Some(i) => resp[i + 4..].to_string(),
        None => String::new(),
    };
    Ok((status, body))
}

/// Poll `/healthz` then `/readyz` until both return 200 or the deadline
/// passes. Returns an error naming the endpoint that never came up.
pub fn wait_ready(addr: &str, deadline: Duration) -> Result<()> {
    let t0 = Instant::now();
    let step = Duration::from_millis(50);
    for path in ["/healthz", "/readyz"] {
        loop {
            match http_get(addr, path, Duration::from_millis(500)) {
                Ok((200, _)) => break,
                _ if t0.elapsed() > deadline => {
                    anyhow::bail!("obs: {path} not 200 within {deadline:?}")
                }
                _ => std::thread::sleep(step),
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server() -> (ObsServer, Arc<Metrics>, Arc<AtomicBool>) {
        let metrics = Metrics::new();
        let ready = Arc::new(AtomicBool::new(false));
        let srv = ObsServer::start(
            "127.0.0.1:0",
            ObsState {
                metrics: Arc::clone(&metrics),
                ready: Arc::clone(&ready),
            },
        )
        .unwrap();
        (srv, metrics, ready)
    }

    #[test]
    fn healthz_is_up_immediately() {
        let (srv, _m, _r) = server();
        let (code, body) =
            http_get(&srv.addr().to_string(), "/healthz", Duration::from_secs(2)).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "ok\n");
    }

    #[test]
    fn readyz_tracks_the_flag() {
        let (srv, _m, ready) = server();
        let addr = srv.addr().to_string();
        let (code, _) = http_get(&addr, "/readyz", Duration::from_secs(2)).unwrap();
        assert_eq!(code, 503);
        ready.store(true, Ordering::Relaxed);
        let (code, body) = http_get(&addr, "/readyz", Duration::from_secs(2)).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "ready\n");
    }

    #[test]
    fn metrics_endpoint_serves_valid_exposition() {
        let (srv, m, _r) = server();
        m.incr("ckpt.requests", 4);
        m.observe_hist("ckpt.stage", &[("stage", "local"), ("level", "local")], 0.01);
        let (code, body) =
            http_get(&srv.addr().to_string(), "/metrics", Duration::from_secs(2)).unwrap();
        assert_eq!(code, 200);
        let fams = crate::obs::prom::parse_exposition(&body).unwrap();
        assert!(fams.iter().any(|f| f.name == "veloc_ckpt_requests"));
    }

    #[test]
    fn unknown_path_is_404_and_post_is_405() {
        let (srv, _m, _r) = server();
        let addr = srv.addr().to_string();
        let (code, _) = http_get(&addr, "/nope", Duration::from_secs(2)).unwrap();
        assert_eq!(code, 404);
        // Raw POST.
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"POST /metrics HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n")
            .unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 405"));
    }
}
