//! Per-wave critical-path attribution over a span timeline.
//!
//! Wave-level interference is where asynchronous checkpointing's real
//! overhead hides: the collective wave ends when its *slowest* rank
//! does, so one straggling rank — or one degraded tier behind it — costs
//! every rank the difference. Given a traced timeline (live from the
//! [`super::TraceRecorder`] or replayed from a flight dump), this module
//! finds, per wave: the critical rank (the one whose `ckpt` command
//! closed last), the per-stage blame shares along that rank's path, and
//! a straggler report — each rank's slowdown against the wave median
//! with its dominant stage and, when placement routed the flush, the
//! tier that served it.
//!
//! Surfaced by `veloc analyze` and as the
//! `ckpt.wave.critical_path{stage}` / `ckpt.wave.straggler_slowdown`
//! metrics (recorded on runtime drain when tracing is on).

use crate::metrics::Metrics;
use crate::obs::span::SpanRec;
use std::collections::BTreeMap;

/// A rank whose command ran notably slower than the wave median.
pub const STRAGGLER_THRESHOLD: f64 = 1.5;

/// One stage's share of the critical rank's command time.
#[derive(Clone, Debug)]
pub struct StageBlame {
    /// Stage name (`capture`, `local`, `partner`, `erasure`, `transfer`).
    pub stage: String,
    /// Stage duration on the critical path, microseconds.
    pub us: u64,
    /// Fraction of the critical command's stage time.
    pub share: f64,
    /// Tier that served the stage, when recorded (`tier` span label).
    pub tier: Option<String>,
}

/// One straggling rank.
#[derive(Clone, Debug)]
pub struct Straggler {
    /// Rank id (from the command span's `rank` label).
    pub rank: u64,
    /// Command duration / wave median command duration.
    pub slowdown: f64,
    /// The rank's dominant (longest) stage.
    pub stage: String,
    /// Tier label of that stage, when recorded.
    pub tier: Option<String>,
    /// Command duration, microseconds.
    pub dur_us: u64,
}

/// Full attribution for one traced wave.
#[derive(Clone, Debug)]
pub struct WaveAnalysis {
    /// Checkpoint version (the wave root's `version` label).
    pub version: u64,
    /// Wave wall-clock: root start to the last command close, µs.
    pub wall_us: u64,
    /// The rank whose command closed last.
    pub critical_rank: u64,
    /// Critical rank's stage blame, largest share first.
    pub blame: Vec<StageBlame>,
    /// Median command duration across ranks, µs.
    pub median_us: f64,
    /// Ranks at or past [`STRAGGLER_THRESHOLD`], worst first.
    pub stragglers: Vec<Straggler>,
}

fn label<'a>(s: &'a SpanRec, key: &str) -> Option<&'a str> {
    s.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn dur_us(s: &SpanRec) -> u64 {
    s.end_us.unwrap_or(s.start_us).saturating_sub(s.start_us)
}

/// Analyze every wave in a span timeline. Open spans and waves without
/// commands are skipped (a torn dump yields the analyses its valid
/// prefix supports).
pub fn analyze(spans: &[SpanRec]) -> Vec<WaveAnalysis> {
    let mut out = Vec::new();
    // A flight dump carries each span twice (open edge + close); keep one
    // root per id, preferring the closed record's final interval.
    let mut roots: std::collections::BTreeMap<u64, &SpanRec> = std::collections::BTreeMap::new();
    for s in spans.iter().filter(|s| s.parent == 0 && s.name.starts_with("wave v")) {
        let slot = roots.entry(s.id).or_insert(s);
        if s.end_us.is_some() {
            *slot = s;
        }
    }
    for root in roots.into_values() {
        let Some(version) = label(root, "version").and_then(|v| v.parse::<u64>().ok()) else {
            continue;
        };
        let cmds: Vec<&SpanRec> = spans
            .iter()
            .filter(|s| s.parent == root.id && s.name == "ckpt" && s.end_us.is_some())
            .collect();
        if cmds.is_empty() {
            continue;
        }
        let mut durs: Vec<f64> = cmds.iter().map(|c| dur_us(c) as f64).collect();
        durs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if durs.len() % 2 == 1 {
            durs[durs.len() / 2]
        } else {
            (durs[durs.len() / 2 - 1] + durs[durs.len() / 2]) / 2.0
        };

        // The critical rank ends the wave.
        let critical = cmds
            .iter()
            .max_by_key(|c| c.end_us.unwrap_or(0))
            .expect("non-empty cmds");
        let critical_rank = label(critical, "rank")
            .and_then(|r| r.parse::<u64>().ok())
            .unwrap_or(critical.tid);

        // Blame: the critical command's child stages, share of stage time.
        let stages: Vec<&SpanRec> = spans
            .iter()
            .filter(|s| s.parent == critical.id && !s.instant && s.end_us.is_some())
            .collect();
        let total: u64 = stages.iter().map(|s| dur_us(s)).sum();
        let mut blame: Vec<StageBlame> = stages
            .iter()
            .map(|s| StageBlame {
                stage: s.name.clone(),
                us: dur_us(s),
                share: if total > 0 {
                    dur_us(s) as f64 / total as f64
                } else {
                    0.0
                },
                tier: label(s, "tier").map(str::to_string),
            })
            .collect();
        blame.sort_by(|a, b| b.us.cmp(&a.us));

        // Stragglers: every rank against the wave median, dominant stage
        // carried for attribution.
        let mut stragglers = Vec::new();
        for cmd in &cmds {
            let d = dur_us(cmd);
            let slowdown = if median > 0.0 { d as f64 / median } else { 1.0 };
            if slowdown < STRAGGLER_THRESHOLD {
                continue;
            }
            let dominant = spans
                .iter()
                .filter(|s| s.parent == cmd.id && !s.instant && s.end_us.is_some())
                .max_by_key(|s| dur_us(s));
            stragglers.push(Straggler {
                rank: label(cmd, "rank")
                    .and_then(|r| r.parse::<u64>().ok())
                    .unwrap_or(cmd.tid),
                slowdown,
                stage: dominant.map(|s| s.name.clone()).unwrap_or_default(),
                tier: dominant.and_then(|s| label(s, "tier").map(str::to_string)),
                dur_us: d,
            });
        }
        stragglers.sort_by(|a, b| b.slowdown.partial_cmp(&a.slowdown).unwrap());

        let last_end = cmds.iter().map(|c| c.end_us.unwrap_or(0)).max().unwrap_or(0);
        out.push(WaveAnalysis {
            version,
            wall_us: last_end.saturating_sub(root.start_us),
            critical_rank,
            blame,
            median_us: median,
            stragglers,
        });
    }
    out.sort_by_key(|w| w.version);
    out
}

/// Record the wave metrics: per-stage critical-path seconds into
/// `ckpt.wave.critical_path{stage}` and each straggler's slowdown ratio
/// into `ckpt.wave.straggler_slowdown`.
pub fn record_metrics(metrics: &Metrics, waves: &[WaveAnalysis]) {
    for w in waves {
        for b in &w.blame {
            metrics.observe_hist(
                "ckpt.wave.critical_path",
                &[("stage", b.stage.as_str())],
                b.us as f64 / 1e6,
            );
        }
        for s in &w.stragglers {
            metrics.observe_hist("ckpt.wave.straggler_slowdown", &[], s.slowdown);
        }
    }
}

/// Render the human report `veloc analyze` prints.
pub fn render(waves: &[WaveAnalysis]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    if waves.is_empty() {
        out.push_str("no complete traced waves found\n");
        return out;
    }
    for w in waves {
        let _ = writeln!(
            out,
            "wave v{}: wall {:.2} ms, critical rank {}, median rank {:.2} ms",
            w.version,
            w.wall_us as f64 / 1e3,
            w.critical_rank,
            w.median_us / 1e3
        );
        let _ = writeln!(out, "  critical path blame:");
        for b in &w.blame {
            let tier = b.tier.as_deref().map(|t| format!(" tier={t}")).unwrap_or_default();
            let _ = writeln!(
                out,
                "    {:>10}  {:>9.2} ms  {:>5.1}%{}",
                b.stage,
                b.us as f64 / 1e3,
                b.share * 100.0,
                tier
            );
        }
        if w.stragglers.is_empty() {
            let _ = writeln!(out, "  stragglers: none (all ranks within {STRAGGLER_THRESHOLD}x of median)");
        } else {
            let _ = writeln!(out, "  stragglers (>= {STRAGGLER_THRESHOLD}x median):");
            for s in &w.stragglers {
                let tier = s.tier.as_deref().map(|t| format!(" tier={t}")).unwrap_or_default();
                let _ = writeln!(
                    out,
                    "    rank {:>3}  {:>5.2}x  {:>9.2} ms  dominant stage {}{}",
                    s.rank,
                    s.slowdown,
                    s.dur_us as f64 / 1e3,
                    s.stage,
                    tier
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(
        id: u64,
        parent: u64,
        name: &str,
        start: u64,
        end: u64,
        labels: &[(&str, &str)],
    ) -> SpanRec {
        SpanRec {
            id,
            parent,
            name: name.to_string(),
            start_us: start,
            end_us: Some(end),
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            tid: 0,
            instant: false,
        }
    }

    /// 3-rank wave: ranks 0/1 take 100 µs, rank 2 takes 400 µs with the
    /// transfer stage (tier "pfs") dominating.
    fn sample_wave() -> Vec<SpanRec> {
        let mut spans = vec![span(1, 0, "wave v7", 0, 500, &[("version", "7")])];
        for (i, (rank, end)) in [("0", 100u64), ("1", 110), ("2", 400)].iter().enumerate() {
            let cid = 10 + i as u64;
            spans.push(span(cid, 1, "ckpt", 0, *end, &[("rank", *rank)]));
            spans.push(span(cid * 10, cid, "capture", 0, 20, &[]));
            let t_end = if *rank == "2" { 390 } else { 60 };
            spans.push(span(
                cid * 10 + 1,
                cid,
                "transfer",
                25,
                t_end,
                &[("level", "pfs"), ("tier", "pfs")],
            ));
        }
        spans
    }

    #[test]
    fn critical_rank_blame_and_stragglers() {
        let waves = analyze(&sample_wave());
        assert_eq!(waves.len(), 1);
        let w = &waves[0];
        assert_eq!(w.version, 7);
        assert_eq!(w.critical_rank, 2);
        assert_eq!(w.wall_us, 400);
        assert_eq!(w.blame[0].stage, "transfer");
        assert!(w.blame[0].share > 0.9, "transfer dominates: {}", w.blame[0].share);
        assert_eq!(w.blame[0].tier.as_deref(), Some("pfs"));
        assert_eq!(w.stragglers.len(), 1);
        let s = &w.stragglers[0];
        assert_eq!(s.rank, 2);
        assert!(s.slowdown > 3.0, "{}", s.slowdown);
        assert_eq!(s.stage, "transfer");
        assert_eq!(s.tier.as_deref(), Some("pfs"));
    }

    #[test]
    fn uniform_wave_has_no_stragglers() {
        let mut spans = vec![span(1, 0, "wave v3", 0, 120, &[("version", "3")])];
        for i in 0..4u64 {
            let rank = i.to_string();
            spans.push(span(10 + i, 1, "ckpt", 0, 100 + i, &[("rank", rank.as_str())]));
        }
        let waves = analyze(&spans);
        assert_eq!(waves.len(), 1);
        assert!(waves[0].stragglers.is_empty());
        // Render still produces a readable report.
        assert!(render(&waves).contains("stragglers: none"));
    }

    #[test]
    fn metrics_record_blame_and_slowdowns() {
        let m = crate::metrics::Metrics::new();
        let waves = analyze(&sample_wave());
        record_metrics(&m, &waves);
        let h = m
            .histogram("ckpt.wave.critical_path", &[("stage", "transfer")])
            .expect("critical path histogram");
        assert_eq!(h.count(), 1);
        let s = m
            .histogram("ckpt.wave.straggler_slowdown", &[])
            .expect("slowdown histogram");
        assert_eq!(s.count(), 1);
    }

    #[test]
    fn open_spans_and_empty_waves_are_skipped() {
        let mut spans = sample_wave();
        spans.push(SpanRec {
            id: 99,
            parent: 0,
            name: "wave v9".to_string(),
            start_us: 0,
            end_us: None,
            labels: vec![("version".to_string(), "9".to_string())],
            tid: 0,
            instant: false,
        });
        let waves = analyze(&spans);
        assert_eq!(waves.len(), 1, "wave v9 has no commands and is skipped");
    }
}
