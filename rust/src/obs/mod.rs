//! Observability plane: span tracing ([`span`]), Prometheus text
//! exposition + validation ([`prom`]) and the embedded HTTP endpoint
//! serving `/metrics`, `/healthz` and `/readyz` ([`http`]).
//!
//! The span recorder threads through the checkpoint pipeline (capture →
//! checksum → delta → local → partner → erasure → transfer → daemon
//! settle) and the restore plane (cache hits, single-flight joins,
//! prefetch waves); whole waves export as Chrome trace-event JSON via
//! `veloc trace`. The exposition side renders the full `Metrics`
//! registry — counters, gauges, labeled histograms, reservoir summaries —
//! in the Prometheus text format, served by the daemon when
//! `obs.http` is configured.

pub mod http;
pub mod prom;
pub mod span;

pub use http::{http_get, wait_ready, ObsServer, ObsState};
pub use span::{stage_summary, ObsHandle, SpanId, SpanRec, TraceRecorder};

/// Observability configuration (the `obs` section of the config file).
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Record pipeline/restore spans (exportable via `veloc trace`).
    pub trace: bool,
    /// Bind address for the daemon's `/metrics`, `/healthz` and
    /// `/readyz` endpoint (e.g. `127.0.0.1:9090`); `None` disables it.
    pub http: Option<String>,
    /// Retained-span bound for the recorder.
    pub span_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            trace: false,
            http: None,
            span_capacity: span::SPAN_CAPACITY_DEFAULT,
        }
    }
}

impl ObsConfig {
    /// Reject inconsistent settings (called from `VelocConfig::validate`).
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.span_capacity == 0 {
            anyhow::bail!("obs.span_capacity must be > 0");
        }
        if let Some(h) = &self.http {
            if h.is_empty() {
                anyhow::bail!("obs.http must be a bind address like 127.0.0.1:9090");
            }
        }
        Ok(())
    }
}
