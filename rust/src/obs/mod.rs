//! Observability plane: span tracing ([`span`]), Prometheus text
//! exposition + validation ([`prom`]), the embedded HTTP endpoint
//! serving `/metrics`, `/healthz` and `/readyz` ([`http`]), the
//! crash-durable flight recorder ([`flight`]), wave critical-path
//! attribution ([`critpath`]) and the persisted signals bus
//! ([`signals`]).
//!
//! The span recorder threads through the checkpoint pipeline (capture →
//! checksum → delta → local → partner → erasure → transfer → daemon
//! settle) and the restore plane (cache hits, single-flight joins,
//! prefetch waves); whole waves export as Chrome trace-event JSON via
//! `veloc trace`. The exposition side renders the full `Metrics`
//! registry — counters, gauges, labeled histograms, reservoir summaries —
//! in the Prometheus text format, served by the daemon when
//! `obs.http` is configured.
//!
//! Everything above evaporates with the process; the post-mortem side
//! does not. With `obs.flight_dir` configured, closed spans, state
//! transitions, queue edges and signals snapshots also append to a
//! bounded on-disk ring that survives a crash — `veloc postmortem`
//! reconstructs the cross-process timeline from the dumps, and
//! `veloc analyze` attributes each wave's wall-clock to its critical
//! path and stragglers.

pub mod critpath;
pub mod flight;
pub mod http;
pub mod prom;
pub mod signals;
pub mod span;

pub use flight::{FlightEntry, FlightKind, FlightRecorder, FlightScan};
pub use http::{http_get, wait_ready, ObsServer, ObsState};
pub use signals::{SignalsBus, SignalsSnapshot, SignalsView};
pub use span::{stage_summary, ObsHandle, SpanId, SpanRec, TraceRecorder};

use std::path::PathBuf;

/// Observability configuration (the `obs` section of the config file).
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Record pipeline/restore spans (exportable via `veloc trace`).
    pub trace: bool,
    /// Bind address for the daemon's `/metrics`, `/healthz` and
    /// `/readyz` endpoint (e.g. `127.0.0.1:9090`); `None` disables it.
    pub http: Option<String>,
    /// Retained-span bound for the recorder.
    pub span_capacity: usize,
    /// Directory for crash-durable flight-recorder streams; `None`
    /// disables the flight recorder.
    pub flight_dir: Option<PathBuf>,
    /// Per-stream size bound before segment rotation (the ring keeps the
    /// current segment plus one previous generation).
    pub flight_max_bytes: u64,
    /// Retained points per signals-bus series.
    pub signals_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            trace: false,
            http: None,
            span_capacity: span::SPAN_CAPACITY_DEFAULT,
            flight_dir: None,
            flight_max_bytes: flight::FLIGHT_MAX_BYTES_DEFAULT,
            signals_capacity: signals::SIGNALS_CAPACITY_DEFAULT,
        }
    }
}

impl ObsConfig {
    /// Reject inconsistent settings (called from `VelocConfig::validate`).
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.span_capacity == 0 {
            anyhow::bail!("obs.span_capacity must be > 0");
        }
        if let Some(h) = &self.http {
            if h.is_empty() {
                anyhow::bail!("obs.http must be a bind address like 127.0.0.1:9090");
            }
        }
        if self.flight_max_bytes < 4096 {
            anyhow::bail!("obs.flight_max_bytes must be >= 4096 (one rotation segment)");
        }
        if self.signals_capacity == 0 {
            anyhow::bail!("obs.signals_capacity must be > 0");
        }
        Ok(())
    }
}
