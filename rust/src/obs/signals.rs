//! Signals bus: the small time-series registry ROADMAP item 4's control
//! loop will consume.
//!
//! Each series is a fixed-capacity ring of `(unix µs, f64)` points, so
//! the bus is bounded no matter how long the daemon runs. Producers are
//! scattered through the stack — the sim/cluster layer notes observed
//! failures ([`SignalsBus::note_failure`] turns them into inter-arrival
//! samples), the placement engine samples per-tier EWMA health
//! multipliers, the backend queue samples depth and backpressure, and
//! the runtime samples the delta plane's dedup ratio on drain.
//!
//! Snapshots ([`SignalsBus::snapshot`]) persist into the flight-recorder
//! stream, so the series survive the process: after a crash,
//! [`SignalsView::from_entries`] replays the dumped snapshots into the
//! same typed read API a live control loop would use — consumers never
//! touch collection internals.

use crate::util::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

/// Default ring capacity per series.
pub const SIGNALS_CAPACITY_DEFAULT: usize = 256;

/// Bounds on snapshot decode (hostile or torn dumps must not size
/// allocations): series per snapshot and points per series.
const MAX_SERIES: usize = 4096;
const MAX_POINTS: usize = 65_536;

/// Observed failure inter-arrival, seconds. The first failure samples
/// the time since the bus was created (process start).
pub const SIG_FAILURE_INTERARRIVAL: &str = "failure.interarrival_s";
/// Per-tier EWMA health multiplier (1.0 = spec speed); one series per
/// tier, `tier.health.<id>`.
pub const SIG_TIER_HEALTH_PREFIX: &str = "tier.health.";
/// Backend queue depth (queued, unsettled submissions).
pub const SIG_QUEUE_DEPTH: &str = "queue.depth";
/// Cumulative backpressure rejections at the admission gate.
pub const SIG_QUEUE_REJECTED: &str = "queue.rejected";
/// Delta plane logical/physical byte ratio (>= 1.0 once dedup bites).
pub const SIG_DEDUP_RATIO: &str = "dedup.ratio";

/// One sample: unix microseconds and a value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SignalPoint {
    /// Sample time, unix µs.
    pub t_us: u64,
    /// Sample value (units are per-series, see the `SIG_*` docs).
    pub value: f64,
}

/// One named series, oldest point first.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SignalSeries {
    /// Series name (`SIG_*` constants plus the tier-health family).
    pub name: String,
    /// Retained points, oldest first.
    pub points: Vec<SignalPoint>,
}

impl SignalSeries {
    /// The most recent value, if any.
    pub fn latest(&self) -> Option<f64> {
        self.points.last().map(|p| p.value)
    }
}

struct BusState {
    series: BTreeMap<String, VecDeque<SignalPoint>>,
    last_failure_us: Option<u64>,
}

/// The live registry (see the [module docs](self)). Cheap to share;
/// sampling takes one mutex over a bounded map.
pub struct SignalsBus {
    cap: usize,
    created_us: u64,
    state: Mutex<BusState>,
}

impl SignalsBus {
    /// Build a bus whose series each retain at most `cap` points.
    pub fn new(cap: usize) -> Arc<SignalsBus> {
        Arc::new(SignalsBus {
            cap: cap.max(2),
            created_us: super::flight::unix_us(),
            state: Mutex::new(BusState {
                series: BTreeMap::new(),
                last_failure_us: None,
            }),
        })
    }

    /// Append a sample stamped now.
    pub fn sample(&self, name: &str, value: f64) {
        self.sample_at(name, super::flight::unix_us(), value);
    }

    /// Append a sample with an explicit timestamp.
    pub fn sample_at(&self, name: &str, t_us: u64, value: f64) {
        let mut st = self.state.lock().unwrap();
        let ring = st.series.entry(name.to_string()).or_default();
        if ring.len() >= self.cap {
            ring.pop_front();
        }
        ring.push_back(SignalPoint { t_us, value });
    }

    /// Record an observed failure (rank/node loss, daemon crash): one
    /// inter-arrival sample measured against the previous failure, or —
    /// for the first — against bus creation.
    pub fn note_failure(&self) {
        let now = super::flight::unix_us();
        let since = {
            let mut st = self.state.lock().unwrap();
            let prev = st.last_failure_us.replace(now).unwrap_or(self.created_us);
            now.saturating_sub(prev)
        };
        self.sample_at(SIG_FAILURE_INTERARRIVAL, now, since as f64 / 1e6);
    }

    /// Point-in-time copy of every series.
    pub fn snapshot(&self) -> SignalsSnapshot {
        let st = self.state.lock().unwrap();
        SignalsSnapshot {
            taken_us: super::flight::unix_us(),
            series: st
                .series
                .iter()
                .map(|(name, ring)| SignalSeries {
                    name: name.clone(),
                    points: ring.iter().copied().collect(),
                })
                .collect(),
        }
    }

    /// Typed read view over the current state.
    pub fn view(&self) -> SignalsView {
        SignalsView::from_snapshot(self.snapshot())
    }
}

/// A persisted copy of the bus at one instant; this is what rides in the
/// flight-recorder stream.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SignalsSnapshot {
    /// When the snapshot was taken, unix µs.
    pub taken_us: u64,
    /// Every series, name-ordered.
    pub series: Vec<SignalSeries>,
}

impl SignalsSnapshot {
    /// Serialize (flight-record body, `veloc postmortem` rendering).
    pub fn to_json(&self) -> Json {
        let series: Vec<Json> = self
            .series
            .iter()
            .map(|s| {
                let pts: Vec<Json> = s
                    .points
                    .iter()
                    .map(|p| Json::obj().set("t", p.t_us).set("v", p.value))
                    .collect();
                Json::obj()
                    .set("name", s.name.as_str())
                    .set("points", Json::Arr(pts))
            })
            .collect();
        Json::obj()
            .set("taken_us", self.taken_us)
            .set("series", Json::Arr(series))
    }

    /// Decode with bounded allocation: series/point counts past the
    /// caps or missing fields are a typed error, never a panic.
    pub fn from_json(j: &Json) -> Result<SignalsSnapshot, String> {
        let taken_us = j
            .get("taken_us")
            .and_then(Json::as_u64)
            .ok_or("snapshot missing taken_us")?;
        let arr = j
            .get("series")
            .and_then(Json::as_arr)
            .ok_or("snapshot missing series")?;
        if arr.len() > MAX_SERIES {
            return Err(format!("snapshot claims {} series (cap {MAX_SERIES})", arr.len()));
        }
        let mut series = Vec::with_capacity(arr.len());
        for s in arr {
            let name = s
                .get("name")
                .and_then(Json::as_str)
                .ok_or("series missing name")?
                .to_string();
            let pts = s
                .get("points")
                .and_then(Json::as_arr)
                .ok_or("series missing points")?;
            if pts.len() > MAX_POINTS {
                return Err(format!(
                    "series {name} claims {} points (cap {MAX_POINTS})",
                    pts.len()
                ));
            }
            let mut points = Vec::with_capacity(pts.len());
            for p in pts {
                let t_us = p.get("t").and_then(Json::as_u64).ok_or("point missing t")?;
                let value = p.get("v").and_then(Json::as_f64).ok_or("point missing v")?;
                points.push(SignalPoint { t_us, value });
            }
            series.push(SignalSeries { name, points });
        }
        Ok(SignalsSnapshot { taken_us, series })
    }
}

/// Typed read API over a set of signals — live (from the bus) or
/// replayed from flight-recorder dumps. The future control loop codes
/// against this, not against collection internals.
#[derive(Clone, Debug, Default)]
pub struct SignalsView {
    series: BTreeMap<String, SignalSeries>,
}

impl SignalsView {
    /// View over one snapshot.
    pub fn from_snapshot(snap: SignalsSnapshot) -> SignalsView {
        let mut v = SignalsView::default();
        v.absorb(snap);
        v
    }

    /// Replay every signals record in a merged flight timeline. Later
    /// snapshots extend earlier ones (points are merged by timestamp and
    /// deduplicated), so the view spans daemon incarnations.
    pub fn from_entries(entries: &[super::flight::FlightEntry]) -> SignalsView {
        let mut v = SignalsView::default();
        for e in entries {
            if e.kind != super::flight::FlightKind::Signals {
                continue;
            }
            if let Ok(snap) = SignalsSnapshot::from_json(&e.body) {
                v.absorb(snap);
            }
        }
        v
    }

    fn absorb(&mut self, snap: SignalsSnapshot) {
        for s in snap.series {
            let dst = self.series.entry(s.name.clone()).or_insert_with(|| SignalSeries {
                name: s.name.clone(),
                points: Vec::new(),
            });
            for p in s.points {
                if !dst.points.contains(&p) {
                    dst.points.push(p);
                }
            }
            dst.points.sort_by(|a, b| {
                a.t_us.cmp(&b.t_us).then(a.value.partial_cmp(&b.value).unwrap_or(std::cmp::Ordering::Equal))
            });
        }
    }

    /// Every series name, ordered.
    pub fn names(&self) -> Vec<&str> {
        self.series.keys().map(String::as_str).collect()
    }

    /// One series by exact name.
    pub fn series(&self, name: &str) -> Option<&SignalSeries> {
        self.series.get(name)
    }

    /// Latest value of a series.
    pub fn latest(&self, name: &str) -> Option<f64> {
        self.series.get(name).and_then(SignalSeries::latest)
    }

    /// Observed failure inter-arrival series (seconds).
    pub fn failure_interarrival(&self) -> Option<&SignalSeries> {
        self.series(SIG_FAILURE_INTERARRIVAL)
    }

    /// Every per-tier health series (`tier.health.<id>`).
    pub fn tier_health(&self) -> Vec<&SignalSeries> {
        self.series
            .iter()
            .filter(|(k, _)| k.starts_with(SIG_TIER_HEALTH_PREFIX))
            .map(|(_, s)| s)
            .collect()
    }

    /// One tier's health series.
    pub fn tier_health_of(&self, tier: &str) -> Option<&SignalSeries> {
        self.series(&format!("{SIG_TIER_HEALTH_PREFIX}{tier}"))
    }

    /// Backend queue depth series.
    pub fn queue_depth(&self) -> Option<&SignalSeries> {
        self.series(SIG_QUEUE_DEPTH)
    }

    /// Cumulative admission rejections (backpressure) series.
    pub fn queue_rejected(&self) -> Option<&SignalSeries> {
        self.series(SIG_QUEUE_REJECTED)
    }

    /// Delta dedup ratio series.
    pub fn dedup_ratio(&self) -> Option<&SignalSeries> {
        self.series(SIG_DEDUP_RATIO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rings_are_bounded_and_keep_the_newest_points() {
        let bus = SignalsBus::new(4);
        for i in 0..10 {
            bus.sample_at(SIG_QUEUE_DEPTH, i, i as f64);
        }
        let v = bus.view();
        let s = v.queue_depth().unwrap();
        assert_eq!(s.points.len(), 4);
        assert_eq!(s.points[0].value, 6.0);
        assert_eq!(s.latest(), Some(9.0));
    }

    #[test]
    fn first_failure_samples_time_since_creation() {
        let bus = SignalsBus::new(8);
        bus.note_failure();
        bus.note_failure();
        let v = bus.view();
        let s = v.failure_interarrival().expect("series after failures");
        assert_eq!(s.points.len(), 2);
        assert!(s.points.iter().all(|p| p.value >= 0.0));
    }

    #[test]
    fn snapshot_roundtrips_through_json() {
        let bus = SignalsBus::new(8);
        bus.sample_at("tier.health.pfs", 100, 1.5);
        bus.sample_at("tier.health.pfs", 200, 2.5);
        bus.sample_at(SIG_DEDUP_RATIO, 150, 5.2);
        let snap = bus.snapshot();
        let j = Json::parse(&snap.to_json().to_string()).unwrap();
        let back = SignalsSnapshot::from_json(&j).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn from_json_rejects_inflated_counts_with_typed_errors() {
        // Claimed sizes are irrelevant (JSON arrays carry their real
        // length), but real oversize arrays must be refused, not
        // absorbed.
        let many: Vec<Json> = (0..MAX_SERIES + 1)
            .map(|i| {
                Json::obj()
                    .set("name", format!("s{i}"))
                    .set("points", Json::Arr(Vec::new()))
            })
            .collect();
        let j = Json::obj().set("taken_us", 1u64).set("series", Json::Arr(many));
        assert!(SignalsSnapshot::from_json(&j).unwrap_err().contains("cap"));
        assert!(SignalsSnapshot::from_json(&Json::obj()).is_err());
    }

    #[test]
    fn view_replays_and_merges_flight_snapshots() {
        use crate::obs::flight::{self, FlightRecorder};
        let dir = std::env::temp_dir().join(format!(
            "veloc-signals-replay-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let f = FlightRecorder::open(&dir, "daemon", flight::FLIGHT_MAX_BYTES_DEFAULT).unwrap();
        let bus = SignalsBus::new(8);
        bus.sample_at("tier.health.ssd", 10, 1.0);
        f.signals(&bus.snapshot());
        bus.sample_at("tier.health.ssd", 20, 3.0);
        bus.note_failure();
        f.signals(&bus.snapshot());
        f.flush();

        let scans = flight::read_dir(&dir).unwrap();
        let v = SignalsView::from_entries(&flight::merge(&scans));
        let health = v.tier_health_of("ssd").expect("replayed tier health");
        assert_eq!(health.points.len(), 2, "snapshots merge without duplicates");
        assert_eq!(health.latest(), Some(3.0));
        assert!(v.failure_interarrival().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
