//! Span tracing: a lightweight recorder for the checkpoint/restore
//! pipeline with monotonic timestamps, span ids and parent links.
//!
//! The hot path is free when tracing is off: every entry point loads one
//! relaxed atomic and returns [`SpanId::NONE`] without allocating. When
//! on, spans are appended to a capacity-bounded buffer under a mutex —
//! checkpoint pipelines produce a handful of spans per command, so the
//! lock is uncontended in practice (the `throughput_bench` overhead gate
//! holds the enabled path to <= 5% of the traced wave).
//!
//! Span timelines export as Chrome trace-event JSON (`chrome://tracing`,
//! Perfetto) via [`TraceRecorder::to_chrome_json`], and
//! [`TraceRecorder::validate`] asserts well-formedness (every span
//! closed, parents resolve, children nest inside their parents).

use crate::metrics::Metrics;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Identifier of one recorded span. `NONE` (id 0) is returned whenever
/// tracing is disabled, and is accepted (as a no-op) everywhere a span id
/// is consumed — callers never need to branch on the enabled state.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanId(pub u64);

impl SpanId {
    /// The null span: no recording happened.
    pub const NONE: SpanId = SpanId(0);

    /// Whether this id refers to a recorded span.
    pub fn is_some(&self) -> bool {
        self.0 != 0
    }
}

/// One recorded span (or instantaneous event, when `end_us == start_us`
/// and `instant` is set).
#[derive(Clone, Debug)]
pub struct SpanRec {
    /// Unique id (> 0).
    pub id: u64,
    /// Parent span id, 0 for roots.
    pub parent: u64,
    /// Stage name (`capture`, `local`, `erasure`, `settle`, ...).
    pub name: String,
    /// Microseconds since the recorder's epoch.
    pub start_us: u64,
    /// Close time; `None` while the span is open.
    pub end_us: Option<u64>,
    /// Dimensions (`rank`, `level`, `version`, ...).
    pub labels: Vec<(String, String)>,
    /// Chrome trace lane (rank id for pipeline spans).
    pub tid: u64,
    /// Instantaneous event (cache hit, single-flight join) — rendered as
    /// a Chrome `i` event instead of a complete `X` span.
    pub instant: bool,
}

/// Default bound on retained spans; past it new opens are counted as
/// dropped instead of growing memory.
pub const SPAN_CAPACITY_DEFAULT: usize = 65_536;

struct TraceState {
    spans: Vec<SpanRec>,
    /// Open wave roots by checkpoint version.
    waves: BTreeMap<u64, SpanId>,
    dropped: u64,
}

/// Crash-durable mirror: closed spans and instants forward here so they
/// survive the process (see [`crate::obs::flight`]).
struct FlightSink {
    flight: Arc<crate::obs::flight::FlightRecorder>,
    /// Converts this recorder's epoch-relative microseconds to unix
    /// microseconds (computed once when the sink is attached).
    unix_offset_us: u64,
}

/// The span recorder. One per runtime; shared by every rank's pipeline,
/// the restore plane and the daemon. Cheap to clone via `Arc`.
pub struct TraceRecorder {
    enabled: AtomicBool,
    next: AtomicU64,
    epoch: Instant,
    capacity: usize,
    state: Mutex<TraceState>,
    /// Set once the first span is dropped at the capacity bound, so the
    /// warning prints once per run (the count itself is surfaced as the
    /// `obs.spans.dropped` gauge).
    drop_warned: AtomicBool,
    has_sink: AtomicBool,
    sink: Mutex<Option<FlightSink>>,
}

impl TraceRecorder {
    /// Build a recorder; `enabled = false` makes every call a no-op until
    /// [`TraceRecorder::set_enabled`] flips it.
    pub fn new(enabled: bool) -> Arc<Self> {
        Self::with_capacity(enabled, SPAN_CAPACITY_DEFAULT)
    }

    /// Build with an explicit retained-span bound.
    pub fn with_capacity(enabled: bool, capacity: usize) -> Arc<Self> {
        Arc::new(TraceRecorder {
            enabled: AtomicBool::new(enabled),
            next: AtomicU64::new(1),
            epoch: Instant::now(),
            capacity: capacity.max(16),
            state: Mutex::new(TraceState {
                spans: Vec::new(),
                waves: BTreeMap::new(),
                dropped: 0,
            }),
            drop_warned: AtomicBool::new(false),
            has_sink: AtomicBool::new(false),
            sink: Mutex::new(None),
        })
    }

    /// Attach a flight-recorder sink: from now on every closed span and
    /// instant is also appended, crash-durably, to the flight stream.
    pub fn set_flight(&self, flight: Arc<crate::obs::flight::FlightRecorder>) {
        let unix_offset_us =
            crate::obs::flight::unix_us().saturating_sub(self.epoch.elapsed().as_micros() as u64);
        *self.sink.lock().unwrap() = Some(FlightSink {
            flight,
            unix_offset_us,
        });
        self.has_sink.store(true, Ordering::Relaxed);
    }

    /// Forward one finished span to the flight sink, if attached.
    fn sink_span(&self, rec: &SpanRec) {
        if !self.has_sink.load(Ordering::Relaxed) {
            return;
        }
        if let Some(sink) = self.sink.lock().unwrap().as_ref() {
            sink.flight.span(rec, sink.unix_offset_us);
        }
    }

    /// Count one dropped span and warn exactly once per run — silent
    /// overflow hides exactly the spans a post-mortem needs.
    fn note_drop(&self, st: &mut TraceState) {
        st.dropped += 1;
        if !self.drop_warned.swap(true, Ordering::Relaxed) {
            eprintln!(
                "veloc: obs: span ring full ({} retained); further spans are dropped \
                 (see the obs.spans.dropped metric)",
                self.capacity
            );
        }
    }

    /// Whether spans are currently recorded (one relaxed load).
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Turn recording on or off at runtime.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Open a span starting now.
    pub fn open(
        &self,
        name: &str,
        parent: SpanId,
        labels: &[(&str, &str)],
        tid: u64,
    ) -> SpanId {
        self.open_at_us(name, parent, labels, tid, None)
    }

    /// Open a span whose start was measured earlier (the capture span
    /// opens after the encode it times).
    pub fn open_at(
        &self,
        name: &str,
        parent: SpanId,
        labels: &[(&str, &str)],
        tid: u64,
        start: Instant,
    ) -> SpanId {
        let us = start
            .saturating_duration_since(self.epoch)
            .as_micros() as u64;
        self.open_at_us(name, parent, labels, tid, Some(us))
    }

    fn open_at_us(
        &self,
        name: &str,
        parent: SpanId,
        labels: &[(&str, &str)],
        tid: u64,
        start_us: Option<u64>,
    ) -> SpanId {
        if !self.is_enabled() {
            return SpanId::NONE;
        }
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        let rec = SpanRec {
            id,
            parent: parent.0,
            name: name.to_string(),
            start_us: start_us.unwrap_or_else(|| self.now_us()),
            end_us: None,
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            tid,
            instant: false,
        };
        {
            let mut st = self.state.lock().unwrap();
            if st.spans.len() >= self.capacity {
                self.note_drop(&mut st);
                return SpanId::NONE;
            }
            st.spans.push(rec.clone());
        }
        // Mirror the open edge too: a crash that never closes this span
        // must still leave a record for its already-mirrored children to
        // resolve their parent against.
        self.sink_span(&rec);
        SpanId(id)
    }

    /// Close a span. Closing [`SpanId::NONE`] is a no-op.
    pub fn close(&self, id: SpanId) {
        if !id.is_some() {
            return;
        }
        let end = self.now_us();
        let closed = {
            let mut st = self.state.lock().unwrap();
            match st.spans.iter_mut().rev().find(|s| s.id == id.0) {
                Some(s) if s.end_us.is_none() => {
                    s.end_us = Some(end.max(s.start_us));
                    Some(s.clone())
                }
                _ => None,
            }
        };
        if let Some(rec) = closed {
            self.sink_span(&rec);
        }
    }

    /// Attach one label to an already-open span (the pipeline engine
    /// adds the serving tier after a stage routed through placement).
    pub fn add_label(&self, id: SpanId, key: &str, value: &str) {
        if !id.is_some() {
            return;
        }
        let mut st = self.state.lock().unwrap();
        if let Some(s) = st.spans.iter_mut().rev().find(|s| s.id == id.0) {
            if let Some(l) = s.labels.iter_mut().find(|(k, _)| k == key) {
                l.1 = value.to_string();
            } else {
                s.labels.push((key.to_string(), value.to_string()));
            }
        }
    }

    /// Record an instantaneous event (cache hit/miss, single-flight join).
    pub fn event(&self, name: &str, parent: SpanId, labels: &[(&str, &str)], tid: u64) {
        if !self.is_enabled() {
            return;
        }
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        let now = self.now_us();
        let rec = SpanRec {
            id,
            parent: parent.0,
            name: name.to_string(),
            start_us: now,
            end_us: Some(now),
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            tid,
            instant: true,
        };
        {
            let mut st = self.state.lock().unwrap();
            if st.spans.len() >= self.capacity {
                self.note_drop(&mut st);
                return;
            }
            st.spans.push(rec.clone());
        }
        self.sink_span(&rec);
    }

    /// Get (or open) the root span of checkpoint wave `version`. All
    /// per-rank commands of one collective wave nest under a single
    /// shared root; the root stays open until
    /// [`TraceRecorder::close_open_waves`] (the runtime calls it on
    /// drain).
    pub fn wave_root(&self, version: u64) -> SpanId {
        if !self.is_enabled() {
            return SpanId::NONE;
        }
        let now = self.now_us();
        self.wave_root_at_us(version, now)
    }

    /// Like [`TraceRecorder::wave_root`], but the root — newly created or
    /// already open — is back-dated to `start` when that is earlier: a
    /// rank's capture begins before its submit reaches the recorder, and
    /// the wave root must still contain every child span.
    pub fn wave_root_at(&self, version: u64, start: Instant) -> SpanId {
        if !self.is_enabled() {
            return SpanId::NONE;
        }
        let us = start.saturating_duration_since(self.epoch).as_micros() as u64;
        self.wave_root_at_us(version, us)
    }

    fn wave_root_at_us(&self, version: u64, start_us: u64) -> SpanId {
        let mut st = self.state.lock().unwrap();
        if let Some(&id) = st.waves.get(&version) {
            if let Some(s) = st.spans.iter_mut().rev().find(|s| s.id == id.0) {
                if s.start_us > start_us {
                    s.start_us = start_us;
                }
            }
            return id;
        }
        if st.spans.len() >= self.capacity {
            self.note_drop(&mut st);
            return SpanId::NONE;
        }
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        let rec = SpanRec {
            id,
            parent: 0,
            name: format!("wave v{version}"),
            start_us,
            end_us: None,
            labels: vec![("version".to_string(), version.to_string())],
            tid: 0,
            instant: false,
        };
        st.spans.push(rec.clone());
        let sid = SpanId(id);
        st.waves.insert(version, sid);
        drop(st);
        // Open-edge mirror, same as open_at_us: children mirrored before
        // this root closes must find their parent in the flight stream.
        self.sink_span(&rec);
        sid
    }

    /// Close every open wave root (the collective wave has drained).
    pub fn close_open_waves(&self) {
        let roots: Vec<SpanId> = {
            let mut st = self.state.lock().unwrap();
            std::mem::take(&mut st.waves).into_values().collect()
        };
        for id in roots {
            self.close(id);
        }
    }

    /// Copy of every recorded span.
    pub fn snapshot(&self) -> Vec<SpanRec> {
        self.state.lock().unwrap().spans.clone()
    }

    /// Spans dropped at the capacity bound.
    pub fn dropped(&self) -> u64 {
        self.state.lock().unwrap().dropped
    }

    /// Discard all recorded spans (a fresh wave window).
    pub fn clear(&self) {
        let mut st = self.state.lock().unwrap();
        st.spans.clear();
        st.waves.clear();
        st.dropped = 0;
    }

    /// Assert timeline well-formedness: every span closed, every parent
    /// id resolves to a recorded span, and every child's interval nests
    /// inside its parent's. Returns the first violation found.
    pub fn validate(&self) -> Result<(), String> {
        let spans = self.snapshot();
        let by_id: BTreeMap<u64, &SpanRec> = spans.iter().map(|s| (s.id, s)).collect();
        for s in &spans {
            let end = s
                .end_us
                .ok_or_else(|| format!("span {} ({}) never closed", s.id, s.name))?;
            if s.parent != 0 {
                let p = by_id.get(&s.parent).ok_or_else(|| {
                    format!("span {} ({}) has unknown parent {}", s.id, s.name, s.parent)
                })?;
                let pend = p.end_us.ok_or_else(|| {
                    format!("parent {} ({}) of {} never closed", p.id, p.name, s.name)
                })?;
                if s.start_us < p.start_us || end > pend {
                    return Err(format!(
                        "span {} ({}) [{}..{}] escapes parent {} ({}) [{}..{}]",
                        s.id, s.name, s.start_us, end, p.id, p.name, p.start_us, pend
                    ));
                }
            }
        }
        Ok(())
    }

    /// Export as Chrome trace-event JSON (the `traceEvents` array format
    /// understood by `chrome://tracing` and Perfetto). Complete spans are
    /// `X` events with `ts`/`dur` in microseconds; instantaneous events
    /// are `i`. Span id and parent travel in `args` so external tools can
    /// rebuild the tree.
    pub fn to_chrome_json(&self) -> Json {
        let spans = self.snapshot();
        let mut events = Vec::with_capacity(spans.len());
        for s in &spans {
            let mut args = Json::obj()
                .set("id", s.id)
                .set("parent", s.parent);
            for (k, v) in &s.labels {
                args = args.set(k, v.as_str());
            }
            let end = s.end_us.unwrap_or(s.start_us);
            let mut ev = Json::obj()
                .set("name", s.name.as_str())
                .set("ph", if s.instant { "i" } else { "X" })
                .set("ts", s.start_us)
                .set("pid", 0usize)
                .set("tid", s.tid)
                .set("args", args);
            if s.instant {
                ev = ev.set("s", "t"); // thread-scoped instant
            } else {
                ev = ev.set("dur", end - s.start_us);
            }
            events.push(ev);
        }
        Json::obj()
            .set("traceEvents", Json::Arr(events))
            .set("displayTimeUnit", "ms")
    }
}

/// The observability handle a checkpoint command carries down the
/// pipeline: recorder + metrics + the parent span stage spans nest
/// under. Default is fully inert (no tracer, no metrics, null parent).
#[derive(Clone, Default)]
pub struct ObsHandle {
    /// Span recorder, when tracing is wired.
    pub tracer: Option<Arc<TraceRecorder>>,
    /// Metrics registry for per-stage histograms.
    pub metrics: Option<Arc<Metrics>>,
    /// Span the next stage spans nest under (the per-command span).
    pub parent: SpanId,
}

impl ObsHandle {
    /// Open a child span under the handle's parent.
    pub fn open(&self, name: &str, labels: &[(&str, &str)], tid: u64) -> SpanId {
        match &self.tracer {
            Some(t) => t.open(name, self.parent, labels, tid),
            None => SpanId::NONE,
        }
    }

    /// Close a span previously opened through this handle.
    pub fn close(&self, id: SpanId) {
        if let Some(t) = &self.tracer {
            t.close(id);
        }
    }

    /// Attach a label to an open span (no-op without a tracer).
    pub fn label(&self, id: SpanId, key: &str, value: &str) {
        if let Some(t) = &self.tracer {
            t.add_label(id, key, value);
        }
    }

    /// Record one per-stage latency observation into the labeled
    /// `ckpt.stage` histogram.
    pub fn stage_latency(&self, stage: &str, level: &str, d: std::time::Duration) {
        if let Some(m) = &self.metrics {
            m.observe_hist_duration("ckpt.stage", &[("stage", stage), ("level", level)], d);
        }
    }
}

/// Per-stage latency summary extracted from a span snapshot: for each
/// (span name, level label) the count and p50/p95/p99 over span
/// durations, in seconds. This is what `veloc report` prints.
pub fn stage_summary(spans: &[SpanRec]) -> Vec<(String, String, crate::util::stats::Samples)> {
    let mut acc: BTreeMap<(String, String), crate::util::stats::Samples> = BTreeMap::new();
    for s in spans {
        if s.instant {
            continue;
        }
        let Some(end) = s.end_us else { continue };
        let level = s
            .labels
            .iter()
            .find(|(k, _)| k == "level")
            .map(|(_, v)| v.clone())
            .unwrap_or_else(|| "-".to_string());
        acc.entry((s.name.clone(), level))
            .or_default()
            .push((end - s.start_us) as f64 / 1e6);
    }
    acc.into_iter().map(|((n, l), s)| (n, l, s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn disabled_recorder_is_inert() {
        let t = TraceRecorder::new(false);
        let id = t.open("x", SpanId::NONE, &[("k", "v")], 0);
        assert_eq!(id, SpanId::NONE);
        t.close(id);
        t.event("e", SpanId::NONE, &[], 0);
        assert_eq!(t.wave_root(1), SpanId::NONE);
        assert!(t.snapshot().is_empty());
        assert!(t.validate().is_ok());
    }

    #[test]
    fn spans_nest_and_validate() {
        let t = TraceRecorder::new(true);
        let root = t.wave_root(7);
        let cmd = t.open("ckpt", root, &[("rank", "0")], 0);
        let stage = t.open("local", cmd, &[("level", "local")], 0);
        std::thread::sleep(Duration::from_millis(1));
        t.close(stage);
        t.close(cmd);
        t.close_open_waves();
        assert!(t.validate().is_ok(), "{:?}", t.validate());
        let spans = t.snapshot();
        assert_eq!(spans.len(), 3);
        assert!(spans.iter().all(|s| s.end_us.is_some()));
    }

    #[test]
    fn unclosed_span_fails_validation() {
        let t = TraceRecorder::new(true);
        let _leak = t.open("leak", SpanId::NONE, &[], 0);
        let err = t.validate().unwrap_err();
        assert!(err.contains("never closed"), "{err}");
    }

    #[test]
    fn escaping_child_fails_validation() {
        let t = TraceRecorder::new(true);
        let parent = t.open("p", SpanId::NONE, &[], 0);
        t.close(parent); // parent closes first...
        std::thread::sleep(Duration::from_millis(1));
        let child = t.open("c", parent, &[], 0);
        t.close(child); // ...child starts after it ended
        let err = t.validate().unwrap_err();
        assert!(err.contains("escapes parent"), "{err}");
    }

    #[test]
    fn wave_root_is_shared_per_version() {
        let t = TraceRecorder::new(true);
        let a = t.wave_root(3);
        let b = t.wave_root(3);
        let c = t.wave_root(4);
        assert_eq!(a, b);
        assert_ne!(a, c);
        t.close_open_waves();
        assert!(t.validate().is_ok());
    }

    #[test]
    fn capacity_bound_drops_not_grows() {
        let t = TraceRecorder::with_capacity(true, 16);
        let mut open = Vec::new();
        for i in 0..40 {
            open.push(t.open(&format!("s{i}"), SpanId::NONE, &[], 0));
        }
        assert_eq!(t.snapshot().len(), 16);
        assert_eq!(t.dropped(), 24);
        for id in open {
            t.close(id);
        }
        assert!(t.validate().is_ok());
    }

    #[test]
    fn add_label_sets_and_replaces() {
        let t = TraceRecorder::new(true);
        let s = t.open("transfer", SpanId::NONE, &[("level", "pfs")], 0);
        t.add_label(s, "tier", "pfs");
        t.add_label(s, "tier", "ssd"); // replaced, not duplicated
        t.close(s);
        let spans = t.snapshot();
        let labels = &spans[0].labels;
        assert_eq!(labels.iter().filter(|(k, _)| k == "tier").count(), 1);
        assert!(labels.contains(&("tier".to_string(), "ssd".to_string())));
        // Labeling NONE or an unknown id is a no-op.
        t.add_label(SpanId::NONE, "x", "y");
        t.add_label(SpanId(999), "x", "y");
    }

    #[test]
    fn flight_sink_mirrors_closed_spans_and_instants() {
        use crate::obs::flight::{self, FlightKind, FlightRecorder};
        let dir = std::env::temp_dir().join(format!(
            "veloc-span-sink-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let t = TraceRecorder::new(true);
        let f = FlightRecorder::open(&dir, "client", flight::FLIGHT_MAX_BYTES_DEFAULT).unwrap();
        t.set_flight(Arc::clone(&f));
        let s = t.open("ckpt", SpanId::NONE, &[("rank", "0")], 0);
        t.event("cache.hit", s, &[], 0);
        t.close(s);
        f.flush();
        let scan = flight::scan_file(&f.path()).unwrap();
        let spans: Vec<_> = scan
            .entries
            .iter()
            .filter(|e| e.kind == FlightKind::Span)
            .collect();
        assert_eq!(
            spans.len(),
            3,
            "open edge + instant + closed span all mirrored"
        );
        let names: Vec<&str> = spans.iter().map(|e| e.body.str_or("name", "")).collect();
        assert!(names.contains(&"ckpt") && names.contains(&"cache.hit"));
        // The open-edge record carries no end; the close record does.
        let ckpt_ends: Vec<bool> = spans
            .iter()
            .filter(|e| e.body.str_or("name", "") == "ckpt")
            .map(|e| e.body.get("end_us").is_some())
            .collect();
        assert_eq!(ckpt_ends, vec![false, true]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn chrome_export_shape() {
        let t = TraceRecorder::new(true);
        let root = t.open("wave v1", SpanId::NONE, &[("version", "1")], 0);
        let c = t.open("capture", root, &[("rank", "2")], 2);
        t.close(c);
        t.event("cache.hit", root, &[("key", "k")], 2);
        t.close(root);
        let j = t.to_chrome_json();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        let x = &events[1];
        assert_eq!(x.str_or("ph", ""), "X");
        assert_eq!(x.at(&["args", "rank"]).unwrap().as_str(), Some("2"));
        assert!(x.get("dur").is_some());
        let i = &events[2];
        assert_eq!(i.str_or("ph", ""), "i");
    }

    #[test]
    fn stage_summary_groups_by_name_and_level() {
        let t = TraceRecorder::new(true);
        for _ in 0..3 {
            let s = t.open("local", SpanId::NONE, &[("level", "local")], 0);
            t.close(s);
        }
        let p = t.open("partner", SpanId::NONE, &[("level", "partner")], 0);
        t.close(p);
        let rows = stage_summary(&t.snapshot());
        assert_eq!(rows.len(), 2);
        let local = rows.iter().find(|(n, _, _)| n == "local").unwrap();
        assert_eq!(local.2.len(), 3);
    }
}
