//! Prometheus text exposition (format v0.0.4) and a strict hand-rolled
//! parser for validating it.
//!
//! [`render`] turns a [`MetricsSnapshot`] into the text format: every
//! dotted metric name is sanitized into the `veloc_*` namespace, each
//! family gets `# HELP` / `# TYPE` lines, label values are escaped,
//! histograms emit cumulative `_bucket{le=...}` series ending in `+Inf`
//! plus `_sum`/`_count`, and sample reservoirs export as summaries with
//! `quantile` labels.
//!
//! [`parse_exposition`] is the inverse direction used by tests, `veloc
//! scrape` and CI: it checks name legality, TYPE-before-samples ordering,
//! label syntax and escaping, bucket monotonicity and the
//! `+Inf == _count` invariant — with no regex dependency.

use crate::metrics::{Histogram, MetricsSnapshot, SeriesKey, DURATION_BUCKETS};
use std::collections::{BTreeMap, BTreeSet};

/// Map a dotted metric name into a legal Prometheus name in the
/// `veloc_` namespace: `backend.queue_depth` → `veloc_backend_queue_depth`.
pub fn sanitize_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 6);
    out.push_str("veloc_");
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphanumeric() || c == '_' || c == ':';
        let ok = ok && !(i == 0 && c.is_ascii_digit());
        out.push(if ok { c } else { '_' });
    }
    out
}

/// Escape a label value per the exposition format (`\` → `\\`,
/// `"` → `\"`, newline → `\n`).
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut pairs: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{}=\"{}\"", sanitize_label_key(k), escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        pairs.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if pairs.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", pairs.join(","))
    }
}

fn sanitize_label_key(k: &str) -> String {
    k.chars()
        .enumerate()
        .map(|(i, c)| {
            let ok = c.is_ascii_alphanumeric() || c == '_';
            let ok = ok && !(i == 0 && c.is_ascii_digit());
            if ok { c } else { '_' }
        })
        .collect()
}

fn fmt_f64(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 { "+Inf".into() } else { "-Inf".into() }
    } else {
        format!("{v}")
    }
}

/// Claim a unique family name: on collision across kinds (a counter and a
/// gauge sharing one dotted name) the later kind gets `suffix` appended.
fn claim(used: &mut BTreeSet<String>, base: String, suffix: &str) -> String {
    if used.insert(base.clone()) {
        return base;
    }
    let alt = format!("{base}{suffix}");
    used.insert(alt.clone());
    alt
}

fn render_simple(
    out: &mut String,
    used: &mut BTreeSet<String>,
    series: &[(SeriesKey, u64)],
    typ: &str,
    suffix: &str,
) {
    let mut by_family: BTreeMap<String, Vec<&(SeriesKey, u64)>> = BTreeMap::new();
    for s in series {
        by_family.entry(s.0.name.clone()).or_default().push(s);
    }
    for (family, rows) in by_family {
        let name = claim(used, sanitize_name(&family), suffix);
        out.push_str(&format!(
            "# HELP {name} veloc {} `{}`\n",
            typ,
            escape_help(&family)
        ));
        out.push_str(&format!("# TYPE {name} {typ}\n"));
        for (key, v) in rows {
            out.push_str(&format!("{name}{} {v}\n", label_block(&key.labels, None)));
        }
    }
}

fn render_histogram(out: &mut String, name: &str, key: &SeriesKey, h: &Histogram) {
    let counts = h.bucket_counts();
    let mut cum = 0u64;
    for (i, bound) in DURATION_BUCKETS.iter().enumerate() {
        cum += counts[i];
        out.push_str(&format!(
            "{name}_bucket{} {cum}\n",
            label_block(&key.labels, Some(("le", &fmt_f64(*bound))))
        ));
    }
    out.push_str(&format!(
        "{name}_bucket{} {}\n",
        label_block(&key.labels, Some(("le", "+Inf"))),
        h.count()
    ));
    out.push_str(&format!(
        "{name}_sum{} {}\n",
        label_block(&key.labels, None),
        fmt_f64(h.sum())
    ));
    out.push_str(&format!(
        "{name}_count{} {}\n",
        label_block(&key.labels, None),
        h.count()
    ));
}

/// Render a metrics snapshot as Prometheus text exposition v0.0.4.
pub fn render(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    let mut used: BTreeSet<String> = BTreeSet::new();

    render_simple(&mut out, &mut used, &snap.counters, "counter", "_total");
    render_simple(&mut out, &mut used, &snap.gauges, "gauge", "_current");

    let mut hist_families: BTreeMap<String, Vec<&(SeriesKey, Histogram)>> = BTreeMap::new();
    for s in &snap.histograms {
        hist_families.entry(s.0.name.clone()).or_default().push(s);
    }
    for (family, rows) in hist_families {
        let name = claim(&mut used, sanitize_name(&family), "_hist");
        out.push_str(&format!(
            "# HELP {name} veloc histogram `{}`\n",
            escape_help(&family)
        ));
        out.push_str(&format!("# TYPE {name} histogram\n"));
        for (key, h) in rows {
            render_histogram(&mut out, &name, key, h);
        }
    }

    for (family, s) in &snap.samples {
        let name = claim(&mut used, sanitize_name(family), "_summary");
        out.push_str(&format!(
            "# HELP {name} veloc summary `{}`\n",
            escape_help(family)
        ));
        out.push_str(&format!("# TYPE {name} summary\n"));
        for (q, v) in [(0.5, s.p50()), (0.95, s.p95()), (0.99, s.p99())] {
            out.push_str(&format!(
                "{name}{{quantile=\"{q}\"}} {}\n",
                fmt_f64(v)
            ));
        }
        out.push_str(&format!(
            "{name}_sum {}\n",
            fmt_f64(s.mean() * s.observed() as f64)
        ));
        out.push_str(&format!("{name}_count {}\n", s.observed()));
    }
    out
}

// ---------------------------------------------------------------------------
// Parsing / validation
// ---------------------------------------------------------------------------

/// One parsed sample line.
#[derive(Clone, Debug)]
pub struct PromSample {
    /// Full sample name (`veloc_ckpt_stage_bucket`).
    pub name: String,
    /// Parsed (unescaped) label pairs, in source order.
    pub labels: Vec<(String, String)>,
    /// Sample value (`+Inf`/`-Inf`/`NaN` accepted).
    pub value: f64,
}

/// One parsed metric family (a `# TYPE` block and its samples).
#[derive(Clone, Debug)]
pub struct PromFamily {
    /// Family name as declared by `# TYPE`.
    pub name: String,
    /// `counter`, `gauge`, `histogram`, `summary` or `untyped`.
    pub typ: String,
    /// Whether a `# HELP` line was seen.
    pub help: bool,
    /// Samples belonging to the family.
    pub samples: Vec<PromSample>,
}

fn legal_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn legal_label_key(k: &str) -> bool {
    let mut chars = k.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_value(tok: &str) -> Result<f64, String> {
    match tok {
        "+Inf" | "Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        _ => tok
            .parse::<f64>()
            .map_err(|_| format!("bad sample value `{tok}`")),
    }
}

/// Parse one `name{labels} value` line.
fn parse_sample_line(line: &str) -> Result<PromSample, String> {
    let bytes: Vec<char> = line.chars().collect();
    let mut i = 0;
    while i < bytes.len() && bytes[i] != '{' && !bytes[i].is_whitespace() {
        i += 1;
    }
    let name: String = bytes[..i].iter().collect();
    if !legal_name(&name) {
        return Err(format!("illegal metric name `{name}`"));
    }
    let mut labels = Vec::new();
    if i < bytes.len() && bytes[i] == '{' {
        i += 1;
        loop {
            while i < bytes.len() && bytes[i] == ' ' {
                i += 1;
            }
            if i < bytes.len() && bytes[i] == '}' {
                i += 1;
                break;
            }
            let kstart = i;
            while i < bytes.len() && bytes[i] != '=' {
                i += 1;
            }
            if i >= bytes.len() {
                return Err(format!("unterminated label key in `{line}`"));
            }
            let key: String = bytes[kstart..i].iter().collect();
            if !legal_label_key(&key) {
                return Err(format!("illegal label key `{key}` in `{line}`"));
            }
            i += 1; // '='
            if i >= bytes.len() || bytes[i] != '"' {
                return Err(format!("label value must be quoted in `{line}`"));
            }
            i += 1;
            let mut val = String::new();
            loop {
                if i >= bytes.len() {
                    return Err(format!("unterminated label value in `{line}`"));
                }
                match bytes[i] {
                    '"' => {
                        i += 1;
                        break;
                    }
                    '\\' => {
                        i += 1;
                        if i >= bytes.len() {
                            return Err(format!("dangling escape in `{line}`"));
                        }
                        match bytes[i] {
                            '\\' => val.push('\\'),
                            '"' => val.push('"'),
                            'n' => val.push('\n'),
                            c => return Err(format!("bad escape `\\{c}` in `{line}`")),
                        }
                        i += 1;
                    }
                    c => {
                        val.push(c);
                        i += 1;
                    }
                }
            }
            labels.push((key, val));
            if i < bytes.len() && bytes[i] == ',' {
                i += 1;
                continue;
            }
            if i < bytes.len() && bytes[i] == '}' {
                i += 1;
                break;
            }
            return Err(format!("expected `,` or `}}` after label in `{line}`"));
        }
    }
    let rest: String = bytes[i..].iter().collect();
    let mut toks = rest.split_whitespace();
    let value = parse_value(toks.next().ok_or_else(|| format!("missing value in `{line}`"))?)?;
    // An optional trailing timestamp is legal; anything further is not.
    if let Some(ts) = toks.next() {
        ts.parse::<i64>()
            .map_err(|_| format!("bad timestamp `{ts}` in `{line}`"))?;
    }
    if toks.next().is_some() {
        return Err(format!("trailing garbage in `{line}`"));
    }
    Ok(PromSample { name, labels, value })
}

/// Which declared family owns a sample named `name`?
fn owner<'a>(
    families: &'a mut BTreeMap<String, PromFamily>,
    order: &[String],
    name: &str,
) -> Option<&'a mut PromFamily> {
    // Exact match wins; otherwise histogram/summary suffix series.
    let mut pick: Option<&str> = None;
    for fam in order {
        let f = &families[fam];
        let hit = *fam == name
            || (f.typ == "histogram"
                && (name == format!("{fam}_bucket")
                    || name == format!("{fam}_sum")
                    || name == format!("{fam}_count")))
            || (f.typ == "summary"
                && (name == format!("{fam}_sum") || name == format!("{fam}_count")));
        let better = match pick {
            None => true,
            Some(p) => fam.len() > p.len(),
        };
        if hit && better {
            pick = Some(fam);
        }
    }
    let key = pick?.to_string();
    families.get_mut(&key)
}

/// Parse and validate a full exposition document. Checks, per family:
/// name legality, at most one `# TYPE` declared before its samples,
/// label syntax/escaping, histogram bucket monotonicity, `+Inf` bucket
/// equal to `_count`, and `_sum`/`_count` presence for histograms and
/// summaries. Returns the parsed families on success.
pub fn parse_exposition(text: &str) -> Result<Vec<PromFamily>, String> {
    let mut families: BTreeMap<String, PromFamily> = BTreeMap::new();
    let mut order: Vec<String> = Vec::new();
    let mut help: BTreeSet<String> = BTreeSet::new();

    for raw in text.lines() {
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or_default().to_string();
            if !legal_name(&name) {
                return Err(format!("illegal family name in HELP: `{name}`"));
            }
            help.insert(name);
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut toks = rest.split_whitespace();
            let name = toks.next().unwrap_or_default().to_string();
            let typ = toks.next().unwrap_or_default().to_string();
            if !legal_name(&name) {
                return Err(format!("illegal family name in TYPE: `{name}`"));
            }
            if !matches!(
                typ.as_str(),
                "counter" | "gauge" | "histogram" | "summary" | "untyped"
            ) {
                return Err(format!("unknown family type `{typ}` for `{name}`"));
            }
            if families.contains_key(&name) {
                return Err(format!("duplicate TYPE for `{name}`"));
            }
            families.insert(
                name.clone(),
                PromFamily {
                    name: name.clone(),
                    typ,
                    help: help.contains(&name),
                    samples: Vec::new(),
                },
            );
            order.push(name);
            continue;
        }
        if line.starts_with('#') {
            continue; // plain comment
        }
        let sample = parse_sample_line(line)?;
        match owner(&mut families, &order, &sample.name) {
            Some(f) => f.samples.push(sample),
            None => {
                return Err(format!(
                    "sample `{}` has no preceding TYPE declaration",
                    sample.name
                ))
            }
        }
    }

    for f in families.values() {
        validate_family(f)?;
    }
    Ok(order.into_iter().map(|n| families.remove(&n).unwrap()).collect())
}

fn labels_without(labels: &[(String, String)], drop: &str) -> Vec<(String, String)> {
    let mut out: Vec<(String, String)> =
        labels.iter().filter(|(k, _)| k != drop).cloned().collect();
    out.sort();
    out
}

fn validate_family(f: &PromFamily) -> Result<(), String> {
    if !f.help {
        return Err(format!("family `{}` is missing a HELP line", f.name));
    }
    match f.typ.as_str() {
        "histogram" => validate_histogram(f),
        "summary" => validate_summary(f),
        _ => {
            if f.samples.is_empty() {
                return Err(format!("family `{}` declared but has no samples", f.name));
            }
            Ok(())
        }
    }
}

fn validate_histogram(f: &PromFamily) -> Result<(), String> {
    let bucket = format!("{}_bucket", f.name);
    let sum = format!("{}_sum", f.name);
    let count = format!("{}_count", f.name);
    // Group by label set minus `le`.
    let mut groups: BTreeMap<Vec<(String, String)>, Vec<(f64, f64)>> = BTreeMap::new();
    let mut sums: BTreeSet<Vec<(String, String)>> = BTreeSet::new();
    let mut counts: BTreeMap<Vec<(String, String)>, f64> = BTreeMap::new();
    for s in &f.samples {
        let key = labels_without(&s.labels, "le");
        if s.name == bucket {
            let le = s
                .labels
                .iter()
                .find(|(k, _)| k == "le")
                .ok_or_else(|| format!("`{bucket}` sample without le label"))?;
            let bound = parse_value(&le.1)?;
            groups.entry(key).or_default().push((bound, s.value));
        } else if s.name == sum {
            sums.insert(key);
        } else if s.name == count {
            counts.insert(key, s.value);
        }
    }
    if groups.is_empty() {
        return Err(format!("histogram `{}` has no buckets", f.name));
    }
    for (key, mut rows) in groups {
        rows.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in rows.windows(2) {
            if w[1].1 < w[0].1 {
                return Err(format!(
                    "histogram `{}` buckets not monotonic at le={}",
                    f.name, w[1].0
                ));
            }
        }
        let last = rows.last().unwrap();
        if !last.0.is_infinite() {
            return Err(format!("histogram `{}` is missing the +Inf bucket", f.name));
        }
        let c = counts
            .get(&key)
            .ok_or_else(|| format!("histogram `{}` is missing `{count}`", f.name))?;
        if (last.1 - c).abs() > 1e-9 {
            return Err(format!(
                "histogram `{}`: +Inf bucket {} != _count {}",
                f.name, last.1, c
            ));
        }
        if !sums.contains(&key) {
            return Err(format!("histogram `{}` is missing `{sum}`", f.name));
        }
    }
    Ok(())
}

fn validate_summary(f: &PromFamily) -> Result<(), String> {
    let sum = format!("{}_sum", f.name);
    let count = format!("{}_count", f.name);
    let mut quantile_keys: BTreeSet<Vec<(String, String)>> = BTreeSet::new();
    let mut sums: BTreeSet<Vec<(String, String)>> = BTreeSet::new();
    let mut counts: BTreeSet<Vec<(String, String)>> = BTreeSet::new();
    for s in &f.samples {
        let key = labels_without(&s.labels, "quantile");
        if s.name == f.name {
            quantile_keys.insert(key);
        } else if s.name == sum {
            sums.insert(key);
        } else if s.name == count {
            counts.insert(key);
        }
    }
    for key in &quantile_keys {
        if !sums.contains(key) {
            return Err(format!("summary `{}` is missing `{sum}`", f.name));
        }
        if !counts.contains(key) {
            return Err(format!("summary `{}` is missing `{count}`", f.name));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;

    fn populated() -> std::sync::Arc<Metrics> {
        let m = Metrics::new();
        m.incr("ckpt.requests", 12);
        m.incr_with("backend.settled", &[("job", "jobA")], 3);
        m.set_with("backend.queue_depth", &[("job", "jobA")], 2);
        m.observe("restore.latency", 0.004);
        m.observe("restore.latency", 0.009);
        for i in 1..=50 {
            m.observe_hist(
                "ckpt.stage",
                &[("stage", "local"), ("level", "local")],
                i as f64 * 1e-4,
            );
        }
        m
    }

    #[test]
    fn render_is_valid_exposition() {
        let text = render(&populated().snapshot());
        let fams = parse_exposition(&text).expect("render must self-validate");
        assert!(fams.iter().any(|f| f.name == "veloc_ckpt_requests"));
        assert!(fams
            .iter()
            .any(|f| f.name == "veloc_ckpt_stage" && f.typ == "histogram"));
        assert!(fams
            .iter()
            .any(|f| f.name == "veloc_restore_latency" && f.typ == "summary"));
    }

    #[test]
    fn round_trip_values_survive() {
        let m = populated();
        let text = render(&m.snapshot());
        let fams = parse_exposition(&text).unwrap();
        let settled = fams
            .iter()
            .find(|f| f.name == "veloc_backend_settled")
            .unwrap();
        assert_eq!(settled.samples.len(), 1);
        assert_eq!(settled.samples[0].value, 3.0);
        assert_eq!(
            settled.samples[0].labels,
            vec![("job".to_string(), "jobA".to_string())]
        );
        let hist = fams.iter().find(|f| f.name == "veloc_ckpt_stage").unwrap();
        let count = hist
            .samples
            .iter()
            .find(|s| s.name == "veloc_ckpt_stage_count")
            .unwrap();
        assert_eq!(count.value, 50.0);
    }

    #[test]
    fn names_are_sanitized() {
        assert_eq!(sanitize_name("backend.queue_depth"), "veloc_backend_queue_depth");
        assert_eq!(sanitize_name("agg.bytes.payload"), "veloc_agg_bytes_payload");
        assert!(legal_name(&sanitize_name("weird-name with spaces")));
        assert!(legal_name(&sanitize_name("9starts.with.digit")));
    }

    #[test]
    fn label_escaping_round_trips() {
        let m = Metrics::new();
        m.incr_with("c", &[("path", "a\\b\"c\nd")], 1);
        let text = render(&m.snapshot());
        let fams = parse_exposition(&text).unwrap();
        let f = fams.iter().find(|f| f.name == "veloc_c").unwrap();
        assert_eq!(f.samples[0].labels[0].1, "a\\b\"c\nd");
    }

    #[test]
    fn counter_gauge_collision_gets_suffix() {
        let m = Metrics::new();
        m.incr("depth", 1);
        m.set("depth", 9);
        let text = render(&m.snapshot());
        let fams = parse_exposition(&text).unwrap();
        let counter = fams.iter().find(|f| f.name == "veloc_depth").unwrap();
        assert_eq!(counter.typ, "counter");
        let gauge = fams.iter().find(|f| f.name == "veloc_depth_current").unwrap();
        assert_eq!(gauge.typ, "gauge");
    }

    #[test]
    fn parser_rejects_malformed_documents() {
        for (doc, why) in [
            ("veloc_x 1\n", "sample without TYPE"),
            (
                "# HELP veloc_x h\n# TYPE veloc_x counter\n# TYPE veloc_x counter\nveloc_x 1\n",
                "duplicate TYPE",
            ),
            (
                "# HELP 9bad h\n# TYPE 9bad counter\n9bad 1\n",
                "illegal name",
            ),
            (
                "# HELP veloc_x h\n# TYPE veloc_x counter\nveloc_x{k=unquoted} 1\n",
                "unquoted label",
            ),
            (
                "# HELP veloc_x h\n# TYPE veloc_x counter\nveloc_x notanumber\n",
                "bad value",
            ),
            (
                "# TYPE veloc_x counter\nveloc_x 1\n",
                "missing HELP",
            ),
        ] {
            assert!(parse_exposition(doc).is_err(), "should reject: {why}");
        }
    }

    #[test]
    fn parser_rejects_broken_histograms() {
        let head = "# HELP veloc_h x\n# TYPE veloc_h histogram\n";
        // Non-monotonic buckets.
        let doc = format!(
            "{head}veloc_h_bucket{{le=\"0.1\"}} 5\nveloc_h_bucket{{le=\"1\"}} 3\n\
             veloc_h_bucket{{le=\"+Inf\"}} 5\nveloc_h_sum 1\nveloc_h_count 5\n"
        );
        assert!(parse_exposition(&doc).unwrap_err().contains("monotonic"));
        // Missing +Inf.
        let doc = format!(
            "{head}veloc_h_bucket{{le=\"0.1\"}} 5\nveloc_h_sum 1\nveloc_h_count 5\n"
        );
        assert!(parse_exposition(&doc).unwrap_err().contains("+Inf"));
        // +Inf != _count.
        let doc = format!(
            "{head}veloc_h_bucket{{le=\"+Inf\"}} 4\nveloc_h_sum 1\nveloc_h_count 5\n"
        );
        assert!(parse_exposition(&doc).unwrap_err().contains("_count"));
        // Missing _sum.
        let doc = format!("{head}veloc_h_bucket{{le=\"+Inf\"}} 5\nveloc_h_count 5\n");
        assert!(parse_exposition(&doc).unwrap_err().contains("_sum"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_inf() {
        let m = Metrics::new();
        for v in [1e-5, 1e-4, 1e-3, 10.0, 1e4] {
            m.observe_hist("lat", &[], v);
        }
        let text = render(&m.snapshot());
        let fams = parse_exposition(&text).unwrap();
        let f = fams.iter().find(|f| f.name == "veloc_lat").unwrap();
        let buckets: Vec<&PromSample> = f
            .samples
            .iter()
            .filter(|s| s.name == "veloc_lat_bucket")
            .collect();
        assert_eq!(buckets.len(), DURATION_BUCKETS.len() + 1);
        let last = buckets.last().unwrap();
        assert_eq!(last.labels.iter().find(|(k, _)| k == "le").unwrap().1, "+Inf");
        assert_eq!(last.value, 5.0, "+Inf bucket counts everything");
    }
}
