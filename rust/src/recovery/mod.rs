//! Restart orchestration: find the freshest version any level can serve,
//! validate it, and report which level served it (E3/E9).
//!
//! Probe order is the pipeline's priority order, i.e. fastest level first:
//! local -> partner -> erasure rebuild -> PFS -> KV. Every candidate is
//! CRC-validated by the VCKP decode and, when the checksum module recorded
//! a digest, re-verified against the registry before being accepted.
//!
//! When the aggregated flush is enabled, the PFS probe transparently reads
//! a single rank's checkpoint back out of the shared containers through
//! the segment index (rebuilding the index from container headers when the
//! index object itself is lost); [`Recovery::restore_aggregated`] exposes
//! that path directly for tooling and tests.

use crate::modules::checksum::{digest, ChecksumBackend};
use crate::modules::{Env, VersionRegistry};
use crate::pipeline::context::LEVEL_PFS;
use crate::pipeline::{Engine, RestoreContext};
use crate::util::bytes::Checkpoint;
use anyhow::Result;
use std::sync::Arc;

/// A successful restore.
pub struct Restored {
    pub version: u64,
    /// Resilience level that served the copy (1..=5).
    pub level: u8,
    pub ckpt: Checkpoint,
}

pub struct Recovery {
    env: Arc<Env>,
    checksum: ChecksumBackend,
}

impl Recovery {
    pub fn new(env: Arc<Env>, checksum: ChecksumBackend) -> Self {
        Recovery { env, checksum }
    }

    pub fn registry(&self) -> &Arc<VersionRegistry> {
        &self.env.registry
    }

    /// Validate a candidate against the recorded checksum (if any).
    ///
    /// This is explicitly digest-**after**-decompress: the recorded digest
    /// covers the canonical captured container (checksum runs at priority
    /// 5, before compression/delta swap what the remote levels store), so
    /// the candidate reaching here has already been zlib-inflated or
    /// delta-reassembled and CRC-decoded. The VCKP encode is deterministic,
    /// so re-encoding the decoded checkpoint reproduces the exact container
    /// bytes the checksum module digested — corruption of a *compressed*
    /// stored copy either fails the decode or fails this digest.
    fn validate(&self, name: &str, version: u64, rank: usize, ckpt: &Checkpoint) -> bool {
        let Some(info) = self.env.registry.info(name, version, rank) else {
            return true; // no record: nothing to compare against
        };
        let Some(expected) = info.checksum else {
            return true;
        };
        match digest(&self.checksum, &ckpt.encode()) {
            Ok(actual) => actual == expected,
            Err(_) => false,
        }
    }

    /// Restore a specific version for one rank through its engine.
    pub fn restore_version(
        &self,
        engine: &Engine,
        name: &str,
        rank: usize,
        version: u64,
    ) -> Result<Option<Restored>> {
        let node = self.env.topology.node_of(rank);
        let ctx = RestoreContext {
            name: name.to_string(),
            rank,
            node,
            version: Some(version),
        };
        if let Some((level, ckpt)) = engine.restore(&ctx)? {
            if self.validate(name, version, rank, &ckpt) {
                return Ok(Some(Restored {
                    version,
                    level,
                    ckpt,
                }));
            }
        }
        Ok(None)
    }

    /// Restore one rank's checkpoint straight out of the aggregated
    /// containers, bypassing the per-level probe (diagnostics / cold
    /// tooling). Validation matches the probed path: VCKP CRC plus the
    /// registry digest when one was recorded.
    pub fn restore_aggregated(
        &self,
        name: &str,
        rank: usize,
        version: u64,
    ) -> Result<Option<Restored>> {
        let Some(agg) = &self.env.aggregator else {
            return Ok(None);
        };
        // Delta containers reassemble through the aggregated copies of
        // their chain ancestors; raw/zlib containers pass straight through.
        // With the restore plane enabled, container extraction (a segment
        // index lookup plus a shared-tier read per call) goes through the
        // read-through cache and single-flight table under the "agg"
        // source identity.
        let ckpt = if let Some(eng) = &self.env.restore {
            let node = self.env.topology.node_of(rank);
            let fetch = |v: u64| -> Result<Option<Vec<u8>>> { agg.restore(name, v, rank) };
            match eng.materialize("agg", name, rank, node, version, None, &fetch)? {
                Some(c) => c,
                None => return Ok(None),
            }
        } else {
            let Some(data) = agg.restore(name, version, rank)? else {
                return Ok(None);
            };
            let fetch_at =
                |v: u64| -> Option<Vec<u8>> { agg.restore(name, v, rank).ok().flatten() };
            crate::delta::materialize(data, None, &fetch_at)?
        };
        if !self.validate(name, version, rank, &ckpt) {
            return Ok(None);
        }
        Ok(Some(Restored {
            version,
            level: LEVEL_PFS,
            ckpt,
        }))
    }

    /// Restore the freshest version available at any level for one rank.
    pub fn restore_latest(
        &self,
        engine: &Engine,
        name: &str,
        rank: usize,
    ) -> Result<Option<Restored>> {
        for version in self.env.registry.versions(name) {
            if let Some(r) = self.restore_version(engine, name, rank, version)? {
                return Ok(Some(r));
            }
        }
        Ok(None)
    }

    /// Find the freshest version *all* ranks can restore — the globally
    /// consistent restart frontier (checkpoints are collective; a version
    /// only some ranks can recover is useless).
    pub fn restorable_frontier(
        &self,
        engines: &[Arc<Engine>],
        name: &str,
    ) -> Result<Option<u64>> {
        'versions: for version in self.env.registry.versions(name) {
            for (rank, engine) in engines.iter().enumerate() {
                if self
                    .restore_version(engine, name, rank, version)?
                    .is_none()
                {
                    continue 'versions;
                }
            }
            return Ok(Some(version));
        }
        Ok(None)
    }
}
